"""Dynamic fault schedules and the crash-safe retry/resume sweep runner.

Three differential contracts anchor the fault subsystem:

* a single-epoch :class:`FaultSchedule` is bitwise-identical to the static
  ``links`` (+ ``g_converge`` on the loop engine) path it generalizes;
* mixed static/flapping campaigns fused onto one megabatch dispatch equal
  serial per-point simulation bitwise, on both engines;
* a campaign killed mid-run and finished via ``resume=True`` produces a
  byte-identical ``results.jsonl`` to an uninterrupted run.
"""
import json
import pathlib

import numpy as np
import pytest

from repro.core import lb_schemes as lbs
from repro.core.retry import retry_call
from repro.faults import FaultSchedule, LinkEvent
from repro.net import fastsim, loopsim, workloads
from repro.net.topology import FatTree, LinkState
from repro.obs.report import render_report
from repro.obs.trace import TraceWriter
from repro.sweep import runner as runner_mod
from repro.sweep.results import ResultStore
from repro.sweep.runner import run_campaign
from repro.sweep.spec import Campaign, FailureSpec, WorkloadSpec


@pytest.fixture(scope="module")
def tree():
    return FatTree(4)


@pytest.fixture(scope="module")
def wl(tree):
    return workloads.permutation(tree, 24, np.random.default_rng(1),
                                 inter_pod_only=True)


CFG = loopsim.LoopConfig(max_slots=4000)

FLAP = FaultSchedule.flap(layer="ea", pod=0, i=0, j=1, t0=20, period=60,
                          cycles=1, host_react=8, switch_react=16)


def _failing_seed(tree, p=0.15):
    for s in range(60):
        if LinkState.random_failures(tree, p, seed=s).any_failure():
            return s
    raise RuntimeError("no failures sampled")


# ---- schedule object ------------------------------------------------------

def test_event_validation():
    with pytest.raises(ValueError):
        LinkEvent(10, "xx", 0, 0, 0, up=False)
    with pytest.raises(ValueError):
        LinkEvent(-1, "ea", 0, 0, 0, up=False)
    with pytest.raises(ValueError):
        FaultSchedule.flap(period=0)
    with pytest.raises(ValueError):
        FaultSchedule.burst([("ea", 0, 0, 0)], t_down=10, t_up=5)
    with pytest.raises(ValueError):        # coordinates checked vs the tree
        FaultSchedule(events=(LinkEvent(5, "ea", 0, 3, 0, up=False),)
                      ).compile(FatTree(4))


def test_compile_epoch_timeline(tree):
    comp = FLAP.compile(tree)
    assert comp.ep_start == (0, 20, 80)
    assert comp.n_epochs == 3
    # epoch 0 all up, epoch 1 the link is down, epoch 2 back up
    assert not comp.links[0].any_failure()
    assert comp.links[0].ea[0, 0, 1]
    assert not comp.links[1].ea[0, 0, 1]
    assert comp.links[2].ea[0, 0, 1]
    # reaction delays saturate instead of overflowing
    host = comp.react_starts("host")
    sw = comp.react_starts("switch")
    assert host.tolist() == [8, 28, 88]
    assert sw.tolist() == [16, 36, 96]
    assert host.dtype == np.int32


def test_schedule_json_roundtrip():
    for sched in (FLAP,
                  FaultSchedule.static(0.1, 7, host_react=64, switch_react=64),
                  FaultSchedule.burst([("ea", 1, 0, 0), ("ac", 1, 1, 1)],
                                      t_down=100, t_up=300, p_fail=0.05)):
        d = sched.to_dict()
        assert d["kind"] == "schedule"
        assert FaultSchedule.from_dict(json.loads(json.dumps(d))) == sched
        assert FaultSchedule.from_dict(d).label() == sched.label()


def test_labels_distinguish_schedules():
    a = FaultSchedule.flap(t0=10, period=20)
    b = FaultSchedule.flap(t0=10, period=30)
    assert a.label() != b.label()
    assert FaultSchedule.static(0.1).label() \
        != FaultSchedule.static(0.1, legacy_rng=True).label()


# ---- satellite: entropy-keyed random failures -----------------------------

def test_random_failures_entropy_keyed(tree):
    a = LinkState.random_failures(tree, 0.2, seed=3)
    b = LinkState.random_failures(tree, 0.2, seed=3)
    assert (a.ea == b.ea).all() and (a.ac == b.ac).all()
    c = LinkState.random_failures(tree, 0.2, seed=4)
    assert not ((a.ea == c.ea).all() and (a.ac == c.ac).all())
    legacy = LinkState.random_failures(tree, 0.2,
                                       np.random.default_rng(3))
    # different stream by design; both are valid patterns of the same rate
    assert legacy.ea.shape == a.ea.shape


# ---- differential (a): single epoch == static path ------------------------

def test_single_epoch_equals_static_fast(tree, wl):
    s = _failing_seed(tree)
    links = LinkState.random_failures(tree, 0.15, seed=s)
    sched = FaultSchedule.static(0.15, s)
    for name in ("host_pkt", "host_dr", "ofan", "jsq", "flow_ecmp"):
        scheme = lbs.by_name(name)
        ref = fastsim.simulate(tree, wl, scheme, seed=0, links=links)
        got = fastsim.simulate(tree, wl, scheme, seed=0, fault=sched)
        np.testing.assert_array_equal(np.asarray(ref.delivery),
                                      np.asarray(got.delivery),
                                      err_msg=name)
        assert ref.cct == got.cct, name


def test_single_epoch_equals_static_loop(tree, wl):
    s = _failing_seed(tree)
    links = LinkState.random_failures(tree, 0.15, seed=s)
    G = 64
    sched = FaultSchedule.static(0.15, s, host_react=G, switch_react=G)
    for name in ("host_pkt_ar", "ofan"):        # one host-, one switch-class
        scheme = lbs.by_name(name)
        ref = loopsim.simulate(tree, wl, scheme, CFG, seed=0, links=links,
                               g_converge=G)
        got = loopsim.simulate(tree, wl, scheme, CFG, seed=0, fault=sched)
        np.testing.assert_array_equal(ref.delivered_slot, got.delivered_slot,
                                      err_msg=name)
        assert ref.cct_slots == got.cct_slots, name
        assert ref.retransmissions == got.retransmissions, name


def test_fault_excludes_static_operands(tree, wl):
    links = LinkState.all_up(tree)
    with pytest.raises(ValueError):
        loopsim.simulate(tree, wl, lbs.ofan(), CFG, fault=FLAP, links=links)
    with pytest.raises(ValueError):
        loopsim.simulate(tree, wl, lbs.ofan(), CFG, fault=FLAP, g_converge=8)
    with pytest.raises(ValueError):
        fastsim.simulate(tree, wl, lbs.ofan(), fault=FLAP, links=links)


def test_flap_perturbs_reactive_schemes_only(tree, wl):
    """A flap whose reaction window overlaps the release span must change
    link-aware routing (fastsim binds a packet's routing epoch at its
    release slot), and must be inert for link-oblivious schemes (RR / JSQ
    ignore link state)."""
    quick = FaultSchedule.flap(layer="ea", pod=0, i=0, j=1, t0=4, period=12,
                               cycles=1, host_react=0, switch_react=0)
    for reactive in ("ofan", "host_pkt"):
        scheme = lbs.by_name(reactive)
        base = fastsim.simulate(tree, wl, scheme, seed=0)
        flap = fastsim.simulate(tree, wl, scheme, seed=0, fault=quick)
        assert not np.array_equal(np.asarray(base.delivery),
                                  np.asarray(flap.delivery)), reactive
    for inert in ("simple_rr", "jsq"):
        scheme = lbs.by_name(inert)
        base = fastsim.simulate(tree, wl, scheme, seed=0)
        flap = fastsim.simulate(tree, wl, scheme, seed=0, fault=quick)
        np.testing.assert_array_equal(np.asarray(base.delivery),
                                      np.asarray(flap.delivery),
                                      err_msg=inert)


def test_flap_perturbs_loop_engine(tree, wl):
    base = loopsim.simulate(tree, wl, lbs.ofan(), CFG, seed=0)
    flap = loopsim.simulate(tree, wl, lbs.ofan(), CFG, seed=0, fault=FLAP)
    assert base.finished and flap.finished
    assert not np.array_equal(base.delivered_slot, flap.delivered_slot)


# ---- differential (b): fused mixed campaign == serial ---------------------

def test_megabatch_mixed_faults_fast(tree, wl):
    s = _failing_seed(tree)
    static = LinkState.random_failures(tree, 0.15, seed=s)
    items = [
        (tree, wl, lbs.host_pkt(), [0, 1], None, None),
        (tree, wl, lbs.host_pkt(), [0, 1], static, None),
        (tree, wl, lbs.host_pkt(), [0, 1], None, FLAP),
        (tree, wl, lbs.host_pkt(), [0], None,
         FaultSchedule.burst([("ea", 0, 0, 0), ("ac", 0, 1, 0)],
                             t_down=30, t_up=90, host_react=12)),
    ]
    fused = fastsim.simulate_megabatch(items, n_shards=1)
    for (t, w, scheme, seeds, links, fz), results in zip(items, fused):
        for seed, got in zip(seeds, results):
            ref = fastsim.simulate(t, w, scheme, seed=seed, links=links,
                                   fault=fz)
            np.testing.assert_array_equal(np.asarray(ref.delivery),
                                          np.asarray(got.delivery))


def test_megabatch_mixed_faults_loop(tree, wl):
    s = _failing_seed(tree)
    static = LinkState.random_failures(tree, 0.15, seed=s)
    items = [
        (tree, wl, lbs.host_pkt_ar(), CFG, [0, 1], None, None, None),
        (tree, wl, lbs.host_pkt_ar(), CFG, [0, 1], static, 64, None),
        (tree, wl, lbs.host_pkt_ar(), CFG, [0, 1], None, None, FLAP),
        (tree, wl, lbs.host_pkt_ar(), CFG, [0], None, None,
         FaultSchedule.burst([("ea", 0, 0, 0), ("ac", 0, 1, 0)],
                             t_down=30, t_up=90, host_react=12,
                             switch_react=24)),
    ]
    fused = loopsim.simulate_megabatch(items, n_shards=1)
    for (t, w, scheme, cfg, seeds, links, g, fz), results in zip(items,
                                                                 fused):
        for seed, got in zip(seeds, results):
            ref = loopsim.simulate(t, w, scheme, cfg, seed=seed, links=links,
                                   g_converge=g, fault=fz)
            np.testing.assert_array_equal(ref.delivered_slot,
                                          got.delivered_slot)
            assert ref.cct_slots == got.cct_slots


# ---- runner: retry / degradation ladder / resume --------------------------

MIXED = Campaign(
    name="faults-mixed", schemes=("host_pkt", "simple_rr", "ofan"),
    loads=(WorkloadSpec("permutation", 24, inter_pod_only=True),),
    trees=(4,), seeds=(0, 1),
    failures=(None, FailureSpec(0.08, 42), FLAP),
    engine="fast", shard="off")


def test_mixed_campaign_fuses_to_plan_shapes():
    """Static, flapping and failure-free rows plan onto the same fused
    dispatches: n_dispatches == n_shapes (the acceptance bar)."""
    from repro.sweep.planner import plan
    p = plan(MIXED)
    assert p.n_dispatches == p.n_shapes
    assert p.n_points == 18


def test_retry_call_backoff_and_exhaustion():
    slept, tries = [], {"n": 0}

    def boom():
        tries["n"] += 1
        raise RuntimeError("always")

    cleanup = []
    with pytest.raises(RuntimeError):
        retry_call(boom, max_retries=3, backoff_s=0.5, sleep=slept.append,
                   on_exhausted=cleanup.append)
    assert tries["n"] == 4
    assert slept == [0.5, 1.0, 2.0]         # exponential, no sleep after last
    assert len(cleanup) == 1

    tries["n"] = 0

    def flaky():
        tries["n"] += 1
        if tries["n"] < 3:
            raise RuntimeError("transient")
        return "ok"

    assert retry_call(flaky, max_retries=5, backoff_s=1.0,
                      sleep=slept.append) == "ok"


def test_runner_retry_recovers_transient(monkeypatch):
    real = runner_mod._run_fast_mega
    calls = {"n": 0}

    def flaky(mega, campaign, cache):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("injected transient")
        return real(mega, campaign, cache)

    monkeypatch.setattr(runner_mod, "_run_fast_mega", flaky)
    trace = TraceWriter(None)
    slept = []
    recs, _ = run_campaign(MIXED, trace=trace, compile_cache_dir=False,
                           retry=2, backoff_s=0.25, sleep=slept.append)
    assert len(recs) == 18                  # nothing lost
    retries = [s for s in trace.spans if s["kind"] == "retry"]
    assert len(retries) == 1 and retries[0]["stage"] == "megabatch"
    assert slept == [0.25]
    assert not any(s["kind"] == "error" for s in trace.spans)


def test_runner_degrades_and_reports(monkeypatch):
    """A poisoned member exhausts its budget, the dispatch degrades member
    -> serial, only the poisoned points are lost, and the report surfaces
    all of it."""
    real = runner_mod._run_fast_mega

    def poison(mega, campaign, cache):
        if any(b.scheme == "ofan" and 1 in b.seeds for b in mega.members):
            raise RuntimeError("poisoned member")
        return real(mega, campaign, cache)

    monkeypatch.setattr(runner_mod, "_run_fast_mega", poison)
    trace = TraceWriter(None)
    recs, _ = run_campaign(MIXED, trace=trace, compile_cache_dir=False,
                           retry=0, sleep=lambda s: None)
    lost = 18 - len(recs)
    assert 0 < lost <= 3                    # only ofan seed-1 points
    assert all(not (r["scheme"] == "ofan" and r["seed"] == 1)
               for r in recs)
    kinds = [s["kind"] for s in trace.spans]
    assert "error" in kinds and "degrade" in kinds
    point_errors = [s for s in trace.spans
                    if s["kind"] == "error" and s.get("stage") == "point"]
    assert len(point_errors) == lost
    rep = render_report(trace.spans, recs)
    assert "robustness" in rep
    assert "LOST point" in rep and "degraded" in rep


def test_resume_byte_identical(tmp_path):
    """Differential (c): kill-and-resume reproduces the uninterrupted run's
    results JSONL byte-for-byte, including a torn final line."""
    a = tmp_path / "a"
    store = ResultStore(a / "results.jsonl")
    run_campaign(MIXED, store=store, compile_cache_dir=False)
    store.close()
    golden = (a / "results.jsonl").read_bytes()

    lines = golden.decode().splitlines(keepends=True)
    for cut in (0, 5, len(lines) - 1):      # crash early / mid / late
        b = tmp_path / f"b{cut}"
        b.mkdir()
        partial = "".join(lines[:cut]) + lines[cut][: len(lines[cut]) // 2]
        (b / "results.jsonl").write_text(partial)   # torn tail, no newline

        store = ResultStore(b / "results.jsonl", overwrite=False)
        trace = TraceWriter(None)
        run_campaign(MIXED, store=store, compile_cache_dir=False,
                     resume=True, trace=trace)
        store.close()
        assert (b / "results.jsonl").read_bytes() == golden, f"cut={cut}"
        resume_spans = [s for s in trace.spans if s["kind"] == "resume"]
        assert len(resume_spans) == 1
        assert resume_spans[0]["records_kept"] <= cut


def test_resume_noop_when_complete(tmp_path):
    """Resuming a finished campaign re-runs nothing and rewrites nothing."""
    out = tmp_path / "done"
    store = ResultStore(out / "results.jsonl")
    run_campaign(MIXED, store=store, compile_cache_dir=False)
    store.close()
    golden = (out / "results.jsonl").read_bytes()

    store = ResultStore(out / "results.jsonl", overwrite=False)
    trace = TraceWriter(None)
    recs, _ = run_campaign(MIXED, store=store, compile_cache_dir=False,
                           resume=True, trace=trace)
    store.close()
    assert recs == []                       # no new records
    assert (out / "results.jsonl").read_bytes() == golden
    span = next(s for s in trace.spans if s["kind"] == "resume")
    assert span["records_kept"] == 18


def test_loop_campaign_with_schedule_rows():
    """Loop-engine campaign mixing static and schedule rows: schedule rows
    drop g_converge (reaction delays come from the schedule), static rows
    keep it, and everything fuses."""
    camp = Campaign(
        name="faults-loop", schemes=("host_pkt_ar", "ofan"),
        loads=(WorkloadSpec("permutation", 16, inter_pod_only=True),),
        trees=(4,), seeds=(0,),
        failures=(FailureSpec(0.08, 42), FLAP),
        g_converge=(64,), engine="loop", max_slots=4000, shard="off",
        loop_opts=(("rho", "auto"),))
    from repro.sweep.planner import plan
    p = plan(camp)
    assert p.n_dispatches == p.n_shapes
    recs, _ = run_campaign(camp, compile_cache_dir=False)
    assert len(recs) == 4
    by_fail = {(r["failure"], r["scheme"]): r for r in recs}
    sched_label = FLAP.label()
    assert by_fail[(sched_label, "ofan")]["g_converge"] is None
    assert by_fail[("fail0.08-r42", "ofan")]["g_converge"] == 64
