"""Observability layer: probe invariants, bitwise probes-off safety, trace
determinism, logger/report rendering, and schema tolerance."""
import dataclasses
import json

import numpy as np
import pytest

from repro.net.topology import FatTree, LAYER_NAMES
from repro.net import workloads, fastsim, loopsim
from repro.core import lb_schemes as lbs
from repro import sweep
from repro.obs import (ProbeSpec, QueueProbe, SweepLogger, TIMING_KEYS,
                       TraceWriter, dispatch_line, load_trace, probe_shape,
                       render_report, strip_timing)

SEEDS = (0, 1)
PROBES = ProbeSpec(stride=8, samples=64)


def _fast_campaign(**kw):
    base = dict(name="obs", schemes=("host_pkt", "simple_rr"),
                loads=(sweep.WorkloadSpec("permutation", 32,
                                          inter_pod_only=True),),
                trees=(4,), seeds=SEEDS)
    base.update(kw)
    return sweep.Campaign(**base)


def _loop_campaign(**kw):
    base = dict(name="obs_loop", schemes=("host_pkt",),
                loads=(sweep.WorkloadSpec("permutation", 16,
                                          inter_pod_only=True),),
                trees=(4,), seeds=SEEDS, engine="loop", max_slots=8000)
    base.update(kw)
    return sweep.Campaign(**base)


@pytest.fixture(scope="module")
def fast_off():
    return sweep.run_campaign(_fast_campaign(), keep_full=True)


@pytest.fixture(scope="module")
def fast_on():
    return sweep.run_campaign(_fast_campaign(probes=PROBES), keep_full=True)


@pytest.fixture(scope="module")
def loop_off():
    return sweep.run_campaign(_loop_campaign(), keep_full=True)


@pytest.fixture(scope="module")
def loop_on():
    return sweep.run_campaign(_loop_campaign(probes=PROBES), keep_full=True)


# ---------------------------------------------------------------------------
# Probe spec plumbing
# ---------------------------------------------------------------------------

def test_probe_spec_validation():
    with pytest.raises(ValueError):
        ProbeSpec(stride=0)
    with pytest.raises(ValueError):
        ProbeSpec(stride=4, samples=0)
    assert ProbeSpec(stride=4, samples=16).horizon_slots == 64
    assert probe_shape(None) == (0, 0)
    assert probe_shape(PROBES) == (8, 64)
    assert probe_shape((8, 64)) == (8, 64)


def test_campaign_probes_json_roundtrip():
    c = _fast_campaign(probes=PROBES)
    c2 = sweep.Campaign.from_dict(json.loads(json.dumps(c.to_dict())))
    assert c2 == c
    assert c2.probes == PROBES
    # probes-off specs round-trip too (and old spec files lack the key)
    d = _fast_campaign().to_dict()
    del d["probes"]
    assert sweep.Campaign.from_dict(d).probes is None


def test_probe_shape_in_fused_key():
    """Probes are part of the compiled identity: a probed campaign plans to
    the same dispatch count but different fused keys."""
    k_off = {m.key for m in sweep.plan(_fast_campaign()).megabatches}
    k_on = {m.key for m in sweep.plan(
        _fast_campaign(probes=PROBES)).megabatches}
    assert len(k_off) == len(k_on)
    assert k_off.isdisjoint(k_on)


# ---------------------------------------------------------------------------
# Bitwise invariance: probes off == pre-telemetry behavior
# ---------------------------------------------------------------------------

def test_probes_off_records_byte_identical_with_observers(fast_off, tmp_path):
    """Telemetry observers (trace + debug logger) must not perturb a single
    output byte of a probes-off run."""
    base_records, _ = fast_off
    lines = []
    tw = TraceWriter(tmp_path / "trace.jsonl")
    records, _ = sweep.run_campaign(
        _fast_campaign(), trace=tw, log=SweepLogger("debug",
                                                    sink=lines.append),
        keep_full=False)
    tw.close()
    assert [sweep.encode_record(r) for r in records] \
        == [sweep.encode_record(r) for r in base_records]
    assert not any(k.startswith("probe_") for r in records for k in r)
    assert lines  # the logger did observe the run
    assert (tmp_path / "trace.jsonl").exists()


def test_probes_on_non_probe_fields_identical(fast_off, fast_on):
    off_records, _ = fast_off
    on_records, _ = fast_on
    for a, b in zip(off_records, on_records):
        assert a == {k: v for k, v in b.items()
                     if not k.startswith("probe_")}
        assert b["probe_stride"] == PROBES.stride


def test_loop_probes_on_non_probe_fields_identical(loop_off, loop_on):
    off_records, _ = loop_off
    on_records, _ = loop_on
    for a, b in zip(off_records, on_records):
        assert a == {k: v for k, v in b.items()
                     if not k.startswith("probe_")}


# ---------------------------------------------------------------------------
# Probe series semantics: window maxima reduce to the engine scalars
# ---------------------------------------------------------------------------

def test_fast_probe_layer_max_equals_max_queue(fast_on):
    _, full = fast_on
    assert full
    for point, res in full.items():
        assert isinstance(res.probe, QueueProbe)
        assert res.probe.series.shape == (len(LAYER_NAMES), PROBES.samples)
        lm = res.probe.layer_max()
        for i, name in enumerate(LAYER_NAMES):
            assert lm[i] == res.layers[name].max_queue, (point, name)
        assert res.probe.overall_max() == res.max_queue


def test_loop_probe_overall_max_equals_max_queue(loop_on):
    _, full = loop_on
    assert full
    for point, res in full.items():
        assert res.probe.series.shape == (5, PROBES.samples)
        assert res.probe.overall_max() == res.max_queue, point


def test_fast_probe_series_matches_serial(fast_on):
    """The fused megabatch carries the same series a standalone probed
    simulate produces."""
    _, full = fast_on
    tree = FatTree(4)
    wl = workloads.permutation(tree, 32, np.random.default_rng(1),
                               inter_pod_only=True)
    for point, res in full.items():
        serial = fastsim.simulate(tree, wl, lbs.by_name(point.scheme),
                                  seed=point.seed, probes=PROBES)
        np.testing.assert_array_equal(res.probe.series, serial.probe.series)


def test_loop_probe_series_matches_serial(loop_on):
    _, full = loop_on
    tree = FatTree(4)
    wl = workloads.permutation(tree, 16, np.random.default_rng(1),
                               inter_pod_only=True)
    cfg = _loop_campaign().loop_config()
    for point, res in full.items():
        serial = loopsim.simulate(tree, wl, lbs.by_name(point.scheme), cfg,
                                  seed=point.seed, probes=PROBES)
        np.testing.assert_array_equal(res.probe.series, serial.probe.series)


# ---------------------------------------------------------------------------
# Trace determinism and rendering
# ---------------------------------------------------------------------------

def test_trace_deterministic_modulo_timing(tmp_path):
    traces = []
    for i in range(2):
        tw = TraceWriter(tmp_path / f"t{i}.jsonl")
        sweep.run_campaign(_fast_campaign(), trace=tw)
        tw.close()
        traces.append([strip_timing(s)
                       for s in load_trace(tmp_path / f"t{i}.jsonl")])
    assert traces[0] == traces[1]
    kinds = [s["kind"] for s in traces[0]]
    assert kinds[0] == "plan" and kinds[-1] == "campaign"
    assert kinds.count("dispatch") == sweep.plan(_fast_campaign()).n_dispatches
    for s in traces[0]:
        assert s["schema"] == 1
        assert not TIMING_KEYS & set(s)


def test_dispatch_spans_carry_cost_fields(tmp_path):
    tw = TraceWriter()
    sweep.run_campaign(_fast_campaign(), trace=tw, timing_split=True)
    disp = [s for s in tw.spans if s["kind"] == "dispatch"]
    assert disp
    for s in disp:
        assert 0 < s["pkt_fill"] <= 1.0
        assert s["pkt_rows_real"] <= s["pkt_rows_padded"]
        assert s["cache"] in ("hit", "miss")
        assert s["wall_s"] > 0
        assert s["execute_s"] > 0 and s["compile_s"] >= 0
    end = tw.spans[-1]
    assert end["kind"] == "campaign" and end["emit_s"] >= 0


def test_loop_dispatch_span_slot_budget(tmp_path):
    tw = TraceWriter()
    records, _ = sweep.run_campaign(_loop_campaign(), trace=tw)
    disp = [s for s in tw.spans if s["kind"] == "dispatch"]
    assert all(s["slot_budget"] == 8000 for s in disp)
    slots_run = max(s["slots_run"] for s in disp)
    assert slots_run == int(max(r["cct_acked"] for r in records))
    assert 0 < disp[0]["slot_fill"] <= 1.0


def test_report_renders_trace_and_probes(fast_on, tmp_path):
    records, _ = fast_on
    tw = TraceWriter()
    sweep.run_campaign(_fast_campaign(probes=PROBES), trace=tw)
    text = render_report(tw.spans, records, top=2)
    assert "dispatch timeline" in text
    assert "top queue trajectories" in text
    assert "padding:" in text
    no_probe = render_report(tw.spans, [
        {k: v for k, v in r.items() if not k.startswith("probe_")}
        for r in records])
    assert "no probe series" in no_probe


def test_dispatch_line_format():
    span = {"dispatch": 0, "engine": "fast", "schemes": ["host_pkt"],
            "trees": [4, 8], "n_points": 6, "pkt_fill": 0.75,
            "wall_s": 1.5, "cache": "hit"}
    line = dispatch_line(span, 3)
    assert "[1/3]" in line and "k={4,8}" in line
    assert "x6" in line and "fill=0.75" in line and "[cached]" in line


# ---------------------------------------------------------------------------
# Schema tolerance
# ---------------------------------------------------------------------------

def test_summarize_tolerates_extra_and_foreign_records(fast_off):
    records, _ = fast_off
    base = sweep.summarize(records)
    extra = [dict(r, probe_queue=[[1, 2]], future_key="x") for r in records]
    mixed = extra + [{"kind": "note"}, {"campaign": "obs"}]
    rows = sweep.summarize(mixed)
    assert [{k: v for k, v in r.items()} for r in rows] == base


def test_ratio_label_bad_samples():
    """Non-finite / non-positive ratios are bad data, not absurd slowdowns
    (a failed bench run writing 0.0 used to render as
    '1000000000.0x slower')."""
    from repro.obs.report import ratio_label
    for bad in (0.0, -1.0, float("nan"), float("inf"), float("-inf")):
        assert ratio_label(bad) == "n/a (bad sample)"
    assert ratio_label(2.0) == "2.00x speedup"
    label = ratio_label(0.5)
    assert "SLOWDOWN" in label and "2.0x slower" in label


def test_bench_json_merge(tmp_path, monkeypatch):
    sweep_bench = pytest.importorskip(
        "benchmarks.sweep_bench",
        reason="benchmarks/ needs the repo root on sys.path")
    path = tmp_path / "BENCH_sweep.json"
    path.write_text(json.dumps({"schema": 1, "other_tool": {"keep": True},
                                "megabatch_s": 99.0}))
    monkeypatch.setattr(sweep_bench, "BENCH_JSON", path)
    sweep_bench._merge_bench_json({"megabatch_s": 1.5, "plan": {"n": 2}})
    merged = json.loads(path.read_text())
    assert merged["schema"] == 2
    assert merged["other_tool"] == {"keep": True}   # foreign section survives
    assert merged["megabatch_s"] == 1.5             # ours overwrites
