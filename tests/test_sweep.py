"""Campaign subsystem: batched-vs-serial equivalence, planner grouping,
result-store determinism, and spec round-trips."""
import dataclasses
import json

import numpy as np
import pytest

from repro.net.topology import FatTree
from repro.net import workloads, fastsim
from repro.core import lb_schemes as lbs
from repro import sweep


SCHEMES = ("host_pkt", "simple_rr", "ofan")   # pre/pre, rr/rr, ofan/ofan
SEEDS = (0, 1, 2, 3)


@pytest.fixture(scope="module")
def tree():
    return FatTree(4)


@pytest.fixture(scope="module")
def perm_wl(tree):
    return workloads.permutation(tree, 32, np.random.default_rng(1),
                                 inter_pod_only=True)


@pytest.mark.parametrize("scheme", SCHEMES)
def test_batch_bitwise_identical_to_serial(tree, perm_wl, scheme):
    """simulate_batch must reproduce serial simulate exactly, per seed."""
    sch = lbs.by_name(scheme)
    serial = [fastsim.simulate(tree, perm_wl, sch, seed=s) for s in SEEDS]
    batch = fastsim.simulate_batch(tree, perm_wl, sch, SEEDS)
    for a, b in zip(serial, batch):
        np.testing.assert_array_equal(a.delivery, b.delivery)
        np.testing.assert_array_equal(a.flow_completion, b.flow_completion)
        assert a.cct == b.cct
        assert a.max_queue == b.max_queue
        np.testing.assert_array_equal(a.a_used, b.a_used)
        np.testing.assert_array_equal(a.c_used, b.c_used)
        for name in a.layers:
            np.testing.assert_array_equal(a.layers[name].counts,
                                          b.layers[name].counts)
            assert a.layers[name].max_queue == b.layers[name].max_queue
            assert a.layers[name].avg_wait == b.layers[name].avg_wait


def _campaign(**kw):
    base = dict(name="t", schemes=SCHEMES,
                loads=(sweep.WorkloadSpec("permutation", 32,
                                          inter_pod_only=True),),
                trees=(4,), seeds=SEEDS)
    base.update(kw)
    return sweep.Campaign(**base)


def test_campaign_matches_standalone_simulate(tree, perm_wl):
    """End-to-end: campaign point results == standalone fastsim calls."""
    _, full = sweep.run_campaign(_campaign(), keep_full=True)
    assert len(full) == len(SCHEMES) * len(SEEDS)
    for point, res in full.items():
        ref = fastsim.simulate(tree, perm_wl, lbs.by_name(point.scheme),
                               seed=point.seed)
        np.testing.assert_array_equal(res.delivery, ref.delivery)
        assert res.cct == ref.cct


def test_planner_fuses_schemes_into_megabatches():
    c = sweep.Campaign(
        name="t", schemes=("host_pkt", "simple_rr", "host_dr"),
        loads=(sweep.WorkloadSpec("permutation", 16),), trees=(4,),
        seeds=SEEDS)
    p = sweep.plan(c)
    assert p.n_points == 12
    # host_pkt and host_dr share the 'pre/pre' pipeline and fuse into ONE
    # dispatch; simple_rr compiles its own shape.
    assert p.n_dispatches == 2
    assert p.n_dispatches == p.n_shapes
    for b in p.batches:
        assert b.seeds == SEEDS
    fused = {frozenset(b.scheme for b in m.members) for m in p.megabatches}
    assert frozenset({"host_pkt", "host_dr"}) in fused


def test_planner_dispatches_equal_shapes_on_fig1_grid():
    """The fig1/table2 grid: the scheme axis is fully fused -- exactly one
    dispatch per compiled pipeline shape (pre/pre, rr_reset, jsq_quant,
    ofan), per traffic matrix."""
    c = sweep.preset("table2")
    p = sweep.plan(c)
    assert p.n_dispatches == p.n_shapes
    assert p.n_dispatches == 4 * len(c.loads)
    pre = [m for m in p.megabatches
           if {b.scheme for b in m.members} >= {"flow_ecmp", "host_pkt"}]
    assert len(pre) == len(c.loads)     # 4 pre/pre schemes fused per load


def test_planner_buckets_message_sizes_into_one_shape():
    """Loads whose packet counts land in one power-of-two bucket share a
    compiled shape and fuse into one dispatch."""
    c = sweep.Campaign(
        name="t", schemes=("host_pkt",),
        loads=(sweep.WorkloadSpec("permutation", 24),
               sweep.WorkloadSpec("permutation", 32)),
        trees=(4,), seeds=(0,))
    p = sweep.plan(c)
    assert sweep.bucket_packets(16 * 24) == sweep.bucket_packets(16 * 32)
    assert p.n_dispatches == 1
    assert p.megabatches[0].npk_pad == 512


def test_result_store_deterministic(tmp_path):
    """Re-running a campaign must produce byte-identical JSONL."""
    paths = []
    for i in (1, 2):
        path = tmp_path / f"run{i}.jsonl"
        sweep.run_campaign(_campaign(seeds=(0, 1)),
                           store=sweep.ResultStore(path))
        paths.append(path)
    b1, b2 = (p.read_bytes() for p in paths)
    assert b1 == b2
    assert len(b1.splitlines()) == len(SCHEMES) * 2


def test_summarize_aggregates_seeds():
    records, _ = sweep.run_campaign(_campaign(seeds=(0, 1)))
    rows = sweep.summarize(records)
    assert len(rows) == len(SCHEMES)
    for row in rows:
        assert row["n_seeds"] == 2
        assert row["cct_min"] <= row["cct_mean"] <= row["cct_max"]


def test_campaign_json_roundtrip():
    c = _campaign(failures=(sweep.FailureSpec(0.02, rng_seed=3), None),
                  loop_opts=(("g_converge", 0), ("max_slots", 1000)))
    c2 = sweep.Campaign.from_dict(json.loads(json.dumps(c.to_dict())))
    assert c2 == c


def test_campaign_rejects_unknown_scheme():
    with pytest.raises(KeyError):
        _campaign(schemes=("definitely_not_a_scheme",))


def _assert_bitwise_equal(res, ref):
    np.testing.assert_array_equal(res.delivery, ref.delivery)
    np.testing.assert_array_equal(res.flow_completion, ref.flow_completion)
    assert res.cct == ref.cct
    assert res.max_queue == ref.max_queue
    for name in ref.layers:
        np.testing.assert_array_equal(res.layers[name].counts,
                                      ref.layers[name].counts)
        assert res.layers[name].max_queue == ref.layers[name].max_queue
        assert res.layers[name].avg_wait == ref.layers[name].avg_wait


@pytest.mark.parametrize("scheme", ("host_pkt", "switch_pkt_ar", "ofan"))
def test_megabatch_bitwise_identical_to_serial(tree, perm_wl, scheme):
    """One fused dispatch over two workloads x seeds must reproduce serial
    simulate exactly, per point -- including shape-bucketing padding (the
    second workload is padded from 384 to 512 packets)."""
    sch = lbs.by_name(scheme)
    wl_b = workloads.permutation(tree, 24, np.random.default_rng(3))
    items = [(tree, perm_wl, sch, list(SEEDS), None),
             (tree, wl_b, sch, [0, 1], None)]
    out = fastsim.simulate_megabatch(items, npk_pad=512)
    for (t, w, s_, seeds, _), results in zip(items, out):
        for seed, res in zip(seeds, results):
            assert res.delivery.shape[0] == w.n_packets
            _assert_bitwise_equal(res, fastsim.simulate(t, w, s_, seed=seed))


def test_megabatch_fuses_schemes_bitwise(tree, perm_wl):
    """flow_ecmp / host_pkt / host_dr stack onto one fused axis; every
    (scheme, seed) cell stays bitwise-identical to standalone simulate."""
    items = [(tree, perm_wl, lbs.by_name(n), list(SEEDS), None)
             for n in ("flow_ecmp", "host_pkt", "host_dr")]
    out = fastsim.simulate_megabatch(items)
    for (t, w, s_, seeds, _), results in zip(items, out):
        for seed, res in zip(seeds, results):
            _assert_bitwise_equal(res, fastsim.simulate(t, w, s_, seed=seed))
            np.testing.assert_array_equal(
                res.a_used, fastsim.simulate(t, w, s_, seed=seed).a_used)


def test_megabatch_sharded_bitwise_identical(tree, perm_wl, two_devices):
    """shard_map over the fused axis (2 virtual devices from conftest's
    XLA_FLAGS) must not change results; the 3x3=9-element batch also forces
    the divisibility padding path (9 -> 10)."""
    items = [(tree, perm_wl, lbs.by_name(n), [0, 1, 2], None)
             for n in ("flow_ecmp", "host_pkt", "host_dr")]
    sharded = fastsim.simulate_megabatch(items, n_shards="auto")
    for (t, w, s_, seeds, _), results in zip(items, sharded):
        for seed, res in zip(seeds, results):
            _assert_bitwise_equal(res, fastsim.simulate(t, w, s_, seed=seed))


def test_padding_preserves_delivered_packet_counts(tree):
    """Shape-bucketing pad packets are inert: per-layer delivered-packet
    counts match the unpadded run exactly."""
    wl = workloads.permutation(tree, 24, np.random.default_rng(3))
    sch = lbs.by_name("switch_pkt")
    (padded,), = fastsim.simulate_megabatch([(tree, wl, sch, [0], None)],
                                            npk_pad=1024)
    ref = fastsim.simulate(tree, wl, sch, seed=0)
    for name in ref.layers:
        assert padded.layers[name].counts.sum() == ref.layers[name].counts.sum()
        np.testing.assert_array_equal(padded.layers[name].counts,
                                      ref.layers[name].counts)
    assert padded.delivery.shape[0] == wl.n_packets


def test_megabatch_jsq_overflow_retry_matches_serial(tree, perm_wl):
    """A tiny jsq_pad_factor forces the pad-overflow retry ladder; the
    megabatch must take exactly the serial retry decisions (per element)
    and land on bitwise-identical results."""
    sch = lbs.by_name("jsq")
    (results,) = fastsim.simulate_megabatch(
        [(tree, perm_wl, sch, [0, 1], None)], jsq_pad_factor=0.01)
    for seed, res in zip([0, 1], results):
        _assert_bitwise_equal(res, fastsim.simulate(
            tree, perm_wl, sch, seed=seed, jsq_pad_factor=0.01))


def test_campaign_shard_off_matches_auto(tree, perm_wl):
    recs_auto, _ = sweep.run_campaign(_campaign(seeds=(0, 1)))
    recs_off, _ = sweep.run_campaign(
        _campaign(seeds=(0, 1), shard="off"))
    assert recs_auto == recs_off


def test_g_converge_is_a_grid_axis():
    c = sweep.Campaign(
        name="g", schemes=("host_pkt_ar",),
        loads=(sweep.WorkloadSpec("permutation", 8, inter_pod_only=True),),
        trees=(4,), seeds=(0,), engine="loop",
        g_converge=(0, None),
        failures=(sweep.FailureSpec(0.05, rng_seed=3),),
        loop_opts=(("max_slots", 4000), ("rho", 0.9)))
    assert c.n_points == 2
    records, _ = sweep.run_campaign(c)
    gs = [r["g_converge"] for r in records]
    assert gs == [0, None]
    assert len({r["cct"] for r in records}) == 2   # G changes the outcome


def test_legacy_loop_opts_g_converge_migrates():
    c = sweep.Campaign(
        name="legacy", schemes=("host_pkt_ar",),
        loads=(sweep.WorkloadSpec("permutation", 8),), trees=(4,),
        engine="loop", loop_opts=(("g_converge", 7), ("max_slots", 100)))
    assert c.g_converge == (7,)
    assert "g_converge" not in dict(c.loop_opts)
    c2 = sweep.Campaign.from_dict(json.loads(json.dumps(c.to_dict())))
    assert c2 == c


def test_legacy_loop_opts_max_slots_migrates():
    """max_slots is a first-class Campaign field; legacy specs that carried
    it inside loop_opts auto-migrate and round-trip."""
    c = sweep.Campaign(
        name="legacy", schemes=("host_pkt_ar",),
        loads=(sweep.WorkloadSpec("permutation", 8),), trees=(4,),
        engine="loop", loop_opts=(("max_slots", 123), ("rto_slots", 50)))
    assert c.max_slots == 123
    assert dict(c.loop_opts) == {"rto_slots": 50}
    assert c.loop_config().max_slots == 123
    assert c.loop_config().rto_slots == 50
    c2 = sweep.Campaign.from_dict(json.loads(json.dumps(c.to_dict())))
    assert c2 == c
    # An explicit field value wins over a legacy loop_opts entry.
    c3 = sweep.Campaign(
        name="legacy2", schemes=("host_pkt_ar",),
        loads=(sweep.WorkloadSpec("permutation", 8),), trees=(4,),
        engine="loop", max_slots=777, loop_opts=(("max_slots", 123),))
    assert c3.max_slots == 777 and dict(c3.loop_opts) == {}


def _loop_campaign(**kw):
    base = dict(name="loop", schemes=("host_pkt", "host_dr", "ofan"),
                loads=(sweep.WorkloadSpec("permutation", 32,
                                          inter_pod_only=True),),
                trees=(4,), seeds=(0, 1), engine="loop", max_slots=4000)
    base.update(kw)
    return sweep.Campaign(**base)


def test_planner_fuses_loop_schemes_into_megabatches():
    """Loop-engine grids fuse like fast ones: host_pkt and host_dr share the
    'pre/pre' slotted engine (ONE dispatch); ofan compiles its own shape.
    g_converge and failure values ride as operands, not keys."""
    c = _loop_campaign(g_converge=(0, None),
                       failures=(None, sweep.FailureSpec(0.05, rng_seed=3)))
    p = sweep.plan(c)
    assert p.n_points == 3 * 2 * 2 * 2
    assert p.n_dispatches == p.n_shapes == 2
    fused = {frozenset(b.scheme for b in m.members) for m in p.megabatches}
    assert frozenset({"host_pkt", "host_dr"}) in fused


def test_planner_loop_keys_on_static_loop_config():
    """Static LoopConfig fields split compiled shapes; rho and bucketed
    max_slots do not."""
    base = _loop_campaign()
    assert sweep.plan(base).n_dispatches == 2
    sack = _loop_campaign(loop_opts=(("loss", "sack"),))
    k0 = sweep.plan(base).megabatches[0].key
    k1 = sweep.plan(sack).megabatches[0].key
    assert k0 != k1
    rho = _loop_campaign(loop_opts=(("rho", 0.9),), max_slots=4095)
    assert sweep.plan(rho).megabatches[0].key == k0


def test_fig12_preset_plans_one_dispatch_per_shape():
    """The acceptance grid: a fig12-style scheme x load x seed campaign on
    the loop engine runs as fused dispatches, one per compiled shape."""
    c = sweep.preset("fig12")
    p = sweep.plan(c)
    assert p.n_dispatches == p.n_shapes
    # host_pkt + host_dr fuse ('pre/pre'); switch_pkt_ar, host_pkt_ar and
    # ofan each compile their own slotted pipeline.
    assert p.n_dispatches == 4
    fused = {frozenset(b.scheme for b in m.members) for m in p.megabatches}
    assert frozenset({"host_pkt", "host_dr"}) in fused


def test_loop_campaign_matches_standalone_simulate(tree, perm_wl):
    """End-to-end: fused loop-engine campaign results == standalone
    loopsim.simulate calls (the acceptance bitwise-parity criterion)."""
    from repro.net import loopsim
    c = _loop_campaign(loop_opts=(("loss", "sack"),))
    p = sweep.plan(c)
    assert p.n_dispatches == p.n_shapes == 2
    _, full = sweep.run_campaign(c, keep_full=True)
    assert len(full) == 6
    cfg = c.loop_config()
    for point, res in full.items():
        ref = loopsim.simulate(tree, perm_wl, lbs.by_name(point.scheme),
                               cfg, seed=point.seed)
        np.testing.assert_array_equal(res.delivered_slot, ref.delivered_slot)
        np.testing.assert_array_equal(res.flow_complete_slot,
                                      ref.flow_complete_slot)
        assert res.cct_slots == ref.cct_slots
        assert res.drops == ref.drops
        assert res.retransmissions == ref.retransmissions


def test_compile_cache_persists_executables(tmp_path):
    cache_dir = tmp_path / "jax-cache"
    # Drop in-process compile reuse so the dispatch actually compiles (and
    # therefore writes a persistent entry) inside this test.
    fastsim._build_run.cache_clear()
    sweep.run_campaign(_campaign(seeds=(0,), schemes=("host_pkt",)),
                       compile_cache_dir=str(cache_dir))
    entries = list(cache_dir.iterdir())
    assert entries, "persistent compile cache left no entries"


def test_cross_k_grid_one_dispatch_per_engine():
    """Acceptance: a grid sweeping k in {4, 6, 8} with fixed schemes/loads
    runs as ONE fused dispatch per (engine, packet-bucket) -- n_dispatches
    no longer scales with the number of tree sizes (the whole bucket pads
    to k=8 and the packet bucket is taken at the bucket head)."""
    for extra in ({}, dict(engine="loop", max_slots=4000)):
        c = sweep.Campaign(name="kk", schemes=("host_pkt", "host_dr"),
                           loads=(sweep.WorkloadSpec("permutation", 4),),
                           trees=(4, 6, 8), seeds=(0,), **extra)
        p = sweep.plan(c)
        assert p.n_dispatches == p.n_shapes == 1
        assert {b.k for m in p.megabatches for b in m.members} == {4, 6, 8}
        assert p.megabatches[0].k_pad == 8


def test_cross_k_rand_jsq_loop_grid_one_dispatch_per_shape():
    """Acceptance (counter-stream randomness): a mixed-k loop campaign made
    ENTIRELY of rand/JSQ schemes -- the modes that used to key on raw k --
    plans to one dispatch per compiled shape, each fused across all three
    tree sizes at the bucket head."""
    c = sweep.Campaign(name="kk_rand",
                       schemes=("rsq", "jsq", "switch_pkt_ar"),
                       loads=(sweep.WorkloadSpec("permutation", 4),),
                       trees=(4, 6, 8), seeds=(0,),
                       engine="loop", max_slots=4000)
    p = sweep.plan(c)
    # rsq and jsq compile distinct port-choice branches; switch_pkt_ar is
    # jsq_quant.  Three shapes, three dispatches, each spanning all ks.
    assert p.n_dispatches == p.n_shapes == 3
    for m in p.megabatches:
        assert m.k_pad == 8
        assert {b.k for b in m.members} == {4, 6, 8}


def _axes_reversed(c):
    return dataclasses.replace(
        c, schemes=tuple(reversed(c.schemes)), loads=tuple(reversed(c.loads)),
        trees=tuple(reversed(c.trees)), seeds=tuple(reversed(c.seeds)),
        failures=tuple(reversed(c.failures)),
        g_converge=tuple(reversed(c.g_converge)))


@pytest.mark.parametrize("name", sorted(sweep.PRESETS))
def test_preset_planner_invariants(name):
    """Every CLI preset plans one dispatch per compiled shape, covers the
    full grid, and its fused keys are stable under grid permutation."""
    c = sweep.preset(name)
    p = sweep.plan(c)
    assert p.n_dispatches == p.n_shapes
    assert p.n_points == c.n_points
    assert sum(len(b.seeds) for m in p.megabatches
               for b in m.members) == c.n_points
    p2 = sweep.plan(_axes_reversed(c))
    assert {m.key for m in p2.megabatches} == {m.key for m in p.megabatches}
    assert p2.n_dispatches == p.n_dispatches


@pytest.mark.parametrize("name", sorted(sweep.PRESETS))
def test_preset_dispatches_independent_of_k_bucket_population(name):
    """How many k values share a bucket must not change the dispatch count:
    EVERY scheme (counter-stream randomness made rand/JSQ loop modes
    k-fusable too) keeps the *identical* fused keys whether the bucket
    holds one tree or three."""
    c = sweep.preset(name)
    base_k = max(c.trees)
    ks = tuple(k for k in (base_k, base_k - 2, base_k - 4)
               if k >= max(4, -(-base_k // 2)))
    p1 = sweep.plan(dataclasses.replace(c, trees=(base_k,)))
    pn = sweep.plan(dataclasses.replace(c, trees=ks))
    assert ({m.key for m in pn.megabatches}
            == {m.key for m in p1.megabatches})
    assert pn.n_dispatches == p1.n_dispatches


@pytest.mark.parametrize("name", sorted(sweep.PRESETS))
def test_preset_no_raw_k_fused_keys(name):
    """No fused key anywhere carries a raw tree size: every member's k maps
    to its campaign k-bucket head, which is what the key records -- even
    with rand/JSQ loop schemes spliced into the preset's grid."""
    c = sweep.preset(name)
    if c.engine == "loop":
        c = dataclasses.replace(
            c, schemes=tuple(c.schemes) + ("rsq", "jsq"))
    kmap = sweep.planner._kmap(c.trees)
    p = sweep.plan(c)
    assert p.n_dispatches == p.n_shapes
    for m in p.megabatches:
        assert {kmap[b.k] for b in m.members} == {m.k_pad}
    # The k recorded in a fused key is always a bucket head.
    heads = set(kmap.values())
    assert {m.k_pad for m in p.megabatches} <= heads


def test_scheme_shape_key_groups_pre_modes():
    assert lbs.host_pkt().shape_key() == lbs.ecmp().shape_key()
    assert lbs.host_pkt().shape_key() == lbs.host_dr().shape_key()
    assert lbs.simple_rr().shape_key() != lbs.host_pkt().shape_key()
    assert lbs.switch_pkt_ar().shape_key() != lbs.jsq().shape_key()
