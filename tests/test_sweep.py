"""Campaign subsystem: batched-vs-serial equivalence, planner grouping,
result-store determinism, and spec round-trips."""
import json

import numpy as np
import pytest

from repro.net.topology import FatTree
from repro.net import workloads, fastsim
from repro.core import lb_schemes as lbs
from repro import sweep


SCHEMES = ("host_pkt", "simple_rr", "ofan")   # pre/pre, rr/rr, ofan/ofan
SEEDS = (0, 1, 2, 3)


@pytest.fixture(scope="module")
def tree():
    return FatTree(4)


@pytest.fixture(scope="module")
def perm_wl(tree):
    return workloads.permutation(tree, 32, np.random.default_rng(1),
                                 inter_pod_only=True)


@pytest.mark.parametrize("scheme", SCHEMES)
def test_batch_bitwise_identical_to_serial(tree, perm_wl, scheme):
    """simulate_batch must reproduce serial simulate exactly, per seed."""
    sch = lbs.by_name(scheme)
    serial = [fastsim.simulate(tree, perm_wl, sch, seed=s) for s in SEEDS]
    batch = fastsim.simulate_batch(tree, perm_wl, sch, SEEDS)
    for a, b in zip(serial, batch):
        np.testing.assert_array_equal(a.delivery, b.delivery)
        np.testing.assert_array_equal(a.flow_completion, b.flow_completion)
        assert a.cct == b.cct
        assert a.max_queue == b.max_queue
        np.testing.assert_array_equal(a.a_used, b.a_used)
        np.testing.assert_array_equal(a.c_used, b.c_used)
        for name in a.layers:
            np.testing.assert_array_equal(a.layers[name].counts,
                                          b.layers[name].counts)
            assert a.layers[name].max_queue == b.layers[name].max_queue
            assert a.layers[name].avg_wait == b.layers[name].avg_wait


def _campaign(**kw):
    base = dict(name="t", schemes=SCHEMES,
                loads=(sweep.WorkloadSpec("permutation", 32,
                                          inter_pod_only=True),),
                trees=(4,), seeds=SEEDS)
    base.update(kw)
    return sweep.Campaign(**base)


def test_campaign_matches_standalone_simulate(tree, perm_wl):
    """End-to-end: campaign point results == standalone fastsim calls."""
    _, full = sweep.run_campaign(_campaign(), keep_full=True)
    assert len(full) == len(SCHEMES) * len(SEEDS)
    for point, res in full.items():
        ref = fastsim.simulate(tree, perm_wl, lbs.by_name(point.scheme),
                               seed=point.seed)
        np.testing.assert_array_equal(res.delivery, ref.delivery)
        assert res.cct == ref.cct


def test_planner_batches_seeds_and_groups_shapes():
    c = sweep.Campaign(
        name="t", schemes=("host_pkt", "simple_rr", "host_dr"),
        loads=(sweep.WorkloadSpec("permutation", 16),), trees=(4,),
        seeds=SEEDS)
    p = sweep.plan(c)
    assert p.n_points == 12
    assert p.n_dispatches == 3          # one per scheme, seeds batched
    for b in p.batches:
        assert b.seeds == SEEDS
    # host_pkt and host_dr share the 'pre/pre' pipeline shape and must be
    # adjacent so the second rides the first's compile.
    order = [b.scheme for b in p.batches]
    assert abs(order.index("host_pkt") - order.index("host_dr")) == 1


def test_result_store_deterministic(tmp_path):
    """Re-running a campaign must produce byte-identical JSONL."""
    paths = []
    for i in (1, 2):
        path = tmp_path / f"run{i}.jsonl"
        sweep.run_campaign(_campaign(seeds=(0, 1)),
                           store=sweep.ResultStore(path))
        paths.append(path)
    b1, b2 = (p.read_bytes() for p in paths)
    assert b1 == b2
    assert len(b1.splitlines()) == len(SCHEMES) * 2


def test_summarize_aggregates_seeds():
    records, _ = sweep.run_campaign(_campaign(seeds=(0, 1)))
    rows = sweep.summarize(records)
    assert len(rows) == len(SCHEMES)
    for row in rows:
        assert row["n_seeds"] == 2
        assert row["cct_min"] <= row["cct_mean"] <= row["cct_max"]


def test_campaign_json_roundtrip():
    c = _campaign(failures=(sweep.FailureSpec(0.02, rng_seed=3), None),
                  loop_opts=(("g_converge", 0), ("max_slots", 1000)))
    c2 = sweep.Campaign.from_dict(json.loads(json.dumps(c.to_dict())))
    assert c2 == c


def test_campaign_rejects_unknown_scheme():
    with pytest.raises(KeyError):
        _campaign(schemes=("definitely_not_a_scheme",))


def test_scheme_shape_key_groups_pre_modes():
    assert lbs.host_pkt().shape_key() == lbs.ecmp().shape_key()
    assert lbs.host_pkt().shape_key() == lbs.host_dr().shape_key()
    assert lbs.simple_rr().shape_key() != lbs.host_pkt().shape_key()
    assert lbs.switch_pkt_ar().shape_key() != lbs.jsq().shape_key()
