"""Fast (max-plus) engine: scheme behavior, paper-theory properties,
packet conservation, and determinism."""
import numpy as np
import pytest

from repro.net.topology import FatTree
from repro.net import workloads, fastsim
from repro.core import lb_schemes as lbs
from repro.core import theory


@pytest.fixture(scope="module")
def tree():
    return FatTree(4)


@pytest.fixture(scope="module")
def perm_wl(tree):
    return workloads.permutation(tree, 64, np.random.default_rng(1),
                                 inter_pod_only=True)


ALL_FAST = ["flow_ecmp", "subflow_mptcp", "host_pkt", "switch_pkt",
            "switch_pkt_ar", "simple_rr", "jsq", "rsq", "host_dr", "ofan"]


@pytest.mark.parametrize("scheme", ALL_FAST)
def test_all_packets_delivered(tree, perm_wl, scheme):
    res = fastsim.simulate(tree, perm_wl, lbs.by_name(scheme), seed=0)
    assert res.delivery.shape[0] == perm_wl.n_packets
    assert np.isfinite(res.delivery).all()
    # conservation: per-layer counts match expected traversals
    inter = (tree.host_pod(perm_wl.src) != tree.host_pod(perm_wl.dst))
    assert res.layers["A->C"].counts.sum() == inter.sum()
    assert res.layers["E->H"].counts.sum() == perm_wl.n_packets


@pytest.mark.parametrize("scheme", ALL_FAST)
def test_cct_at_least_lower_bound(tree, perm_wl, scheme):
    res = fastsim.simulate(tree, perm_wl, lbs.by_name(scheme), seed=0)
    # minimum possible: m slots of sending + pipeline through 5 queues
    assert res.cct >= 64 - 1


def test_feedback_scheme_rejected(tree, perm_wl):
    with pytest.raises(ValueError):
        fastsim.simulate(tree, perm_wl, lbs.by_name("host_pkt_ar"))


def test_queue_scaling_clusters(tree):
    """The paper's Table 3 clusters on a small tree: q(m) slope ~1 for
    SIMPLE RR, ~0.5 for random spraying, ~0 for DR schemes."""
    ms = [32, 128, 512]
    qs = {}
    for name in ["simple_rr", "host_pkt", "host_dr", "ofan"]:
        row = []
        for m in ms:
            wl = workloads.permutation(tree, m, np.random.default_rng(2),
                                       inter_pod_only=True)
            row.append(fastsim.simulate(tree, wl, lbs.by_name(name),
                                        seed=3).max_queue)
        qs[name] = row
    a_rr, _ = theory.fit_power_law(np.array(ms), np.array(qs["simple_rr"]))
    a_hp, _ = theory.fit_power_law(np.array(ms), np.array(qs["host_pkt"]))
    a_dr, _ = theory.fit_power_law(np.array(ms), np.array(qs["host_dr"]))
    a_of, _ = theory.fit_power_law(np.array(ms), np.array(qs["ofan"]))
    assert a_rr > 0.75, qs
    assert 0.25 < a_hp < 0.8, qs
    assert a_dr < 0.25, qs
    assert a_of < 0.25, qs


def test_ofan_beats_spraying_cct(tree):
    wl = workloads.permutation(tree, 256, np.random.default_rng(5),
                               inter_pod_only=True)
    cct_ofan = fastsim.simulate(tree, wl, lbs.ofan(), seed=0).cct
    cct_spray = fastsim.simulate(tree, wl, lbs.host_pkt(), seed=0).cct
    cct_rr = fastsim.simulate(tree, wl, lbs.simple_rr(), seed=0).cct
    assert cct_ofan <= cct_spray <= cct_rr


def test_ofan_uplink_and_downlink_balance(tree):
    """Fig. 7: DR balances both uplinks and downlinks; SIMPLE RR only
    uplinks."""
    wl = workloads.permutation(tree, 128, np.random.default_rng(7),
                               inter_pod_only=True)
    res_rr = fastsim.simulate(tree, wl, lbs.simple_rr(), seed=1)
    res_of = fastsim.simulate(tree, wl, lbs.ofan(), seed=1)

    def overload(res, layer):
        c = res.layers[layer].counts
        used = c[c > 0]
        return used.max() / max(used.mean(), 1)

    # uplinks: both balanced
    assert overload(res_rr, "E->A") < 1.15
    assert overload(res_of, "E->A") < 1.15
    # downlinks: OFAN balanced, RR can collide
    assert overload(res_of, "A->E") < 1.2
    assert overload(res_rr, "A->E") >= overload(res_of, "A->E") - 0.05


def test_determinism(tree, perm_wl):
    r1 = fastsim.simulate(tree, perm_wl, lbs.ofan(), seed=11)
    r2 = fastsim.simulate(tree, perm_wl, lbs.ofan(), seed=11)
    np.testing.assert_array_equal(r1.delivery, r2.delivery)


def test_ecmp_worse_than_packet_spraying(tree):
    wl = workloads.permutation(tree, 256, np.random.default_rng(9),
                               inter_pod_only=True)
    cct_ecmp = fastsim.simulate(tree, wl, lbs.ecmp(), seed=0).cct
    cct_pkt = fastsim.simulate(tree, wl, lbs.host_pkt(), seed=0).cct
    assert cct_pkt < cct_ecmp


def test_ata_packet_schemes_near_bound():
    """§5.1: in the all-to-all, packet schemes come within a few % of the
    lower bound (paper: ~1% at full scale; small tree is noisier)."""
    tree = FatTree(4)
    wl = workloads.all_to_all(tree, 16)
    per_host = wl.packets_per_host().max()
    res = fastsim.simulate(tree, wl, lbs.ofan(), seed=0)
    # bound: per-host serialization + pipeline latency through the fabric
    bound = per_host + 5 * (1 + 12.0)
    assert res.cct <= bound * 1.15   # k=4 is noisy; paper's ~1% is at k=8


# ---- zero-packet flows (msg_packets=0, degenerate phases) ------------------

def test_zero_packet_workload(tree):
    """An all-empty workload (every flow size 0) must not crash the
    max-plus pipeline (empty segmented scans) and reports CCT 0 with
    finite flow completions, not -inf."""
    wl = workloads.permutation(tree, 0, np.random.default_rng(1))
    assert wl.n_packets == 0 and wl.n_flows > 0
    for name in ("host_pkt", "flow_ecmp", "jsq", "ofan", "host_dr"):
        res = fastsim.simulate(tree, wl, lbs.by_name(name), seed=0)
        assert res.cct == 0.0, name
        assert res.delivery.shape == (0,)
        assert np.isfinite(res.flow_completion).all(), name
        assert (np.asarray(res.flow_completion) == 0.0).all(), name


def test_mixed_zero_flows_inert(tree):
    """Flows of size 0 mixed into a real workload keep the packet layout
    flow-contiguous, pace the nonzero flows exactly as if absent (zero
    flows never consume a release slot), and complete at 0."""
    fsize = np.array([3, 0, 2, 0, 1, 4, 0, 2])
    src = np.arange(8)
    dst = (np.arange(8) + 3) % tree.n_hosts
    mixed = workloads._packets_from_flows("mix", tree.n_hosts, src, dst,
                                          fsize)
    keep = fsize > 0
    dense = workloads._packets_from_flows("dense", tree.n_hosts, src[keep],
                                          dst[keep], fsize[keep])
    np.testing.assert_array_equal(
        np.asarray(mixed.flow), np.repeat(np.arange(8), fsize))
    np.testing.assert_array_equal(mixed.t_release, dense.t_release)
    np.testing.assert_array_equal(mixed.src, dense.src)
    res = fastsim.simulate(tree, mixed, lbs.by_name("host_pkt"), seed=0)
    fcomp = np.asarray(res.flow_completion)
    assert np.isfinite(fcomp).all()
    assert (fcomp[fsize == 0] == 0.0).all()
    assert (fcomp[fsize > 0] > 0.0).all()
