"""DR collective engine vs XLA references + compression + planner."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.collectives import engine, planner, compression

NDEV = len(jax.devices())


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((NDEV,), ("x",))


needs_multi = pytest.mark.skipif(
    NDEV < 2, reason="collective schedules need >1 device; covered by the "
                     "dry-run sweep at 512 fake devices")


def _ref_data(n, rows_per=4, cols=6, seed=0):
    r = np.random.default_rng(seed)
    return jnp.asarray(r.normal(size=(n * rows_per, cols)), jnp.float32)


@needs_multi
def test_ring_all_gather_matches_xla(mesh):
    x = _ref_data(NDEV)
    a = engine.all_gather(x, mesh, "x", impl="rotation")
    b = engine.all_gather(x, mesh, "x", impl="xla")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b))


@needs_multi
def test_rotation_a2a_matches_xla(mesh):
    x = _ref_data(NDEV, rows_per=NDEV)
    a = engine.all_to_all(x, mesh, "x", impl="rotation")
    b = engine.all_to_all(x, mesh, "x", impl="xla")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b))


@needs_multi
def test_ring_reduce_scatter_matches_xla(mesh):
    x = _ref_data(NDEV, rows_per=NDEV)
    a = engine.reduce_scatter(x, mesh, "x", impl="rotation")
    b = engine.reduce_scatter(x, mesh, "x", impl="xla")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


@needs_multi
def test_ring_all_reduce_matches_xla(mesh):
    x = _ref_data(NDEV, rows_per=NDEV)
    a = engine.all_reduce(x, mesh, "x", impl="rotation")
    b = engine.all_reduce(x, mesh, "x", impl="xla")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_single_device_degenerate(mesh):
    """n=1 axes: all schedules are identity/no-op and must still run."""
    m1 = jax.make_mesh((1,), ("x",))
    x = _ref_data(1)
    np.testing.assert_allclose(
        np.asarray(engine.all_gather(x, m1, "x", impl="rotation")),
        np.asarray(x))
    np.testing.assert_allclose(
        np.asarray(engine.all_to_all(x, m1, "x", impl="rotation")),
        np.asarray(x))


def test_int8_error_feedback_reduces_bias(rng):
    g = jnp.asarray(rng.normal(size=(256,)), jnp.float32) * 1e-3
    res = jnp.zeros_like(g)
    acc_plain = jnp.zeros_like(g)
    acc_ef = jnp.zeros_like(g)
    for _ in range(50):
        q, s, _ = compression.quantize_int8_ef(g, jnp.zeros_like(g))
        acc_plain = acc_plain + q.astype(jnp.float32) * s
        q, s, res = compression.quantize_int8_ef(g, res)
        acc_ef = acc_ef + q.astype(jnp.float32) * s
    true = g * 50
    err_plain = float(jnp.abs(acc_plain - true).mean())
    err_ef = float(jnp.abs(acc_ef - true).mean())
    assert err_ef <= err_plain + 1e-9


def test_planner_prefers_rotation_for_large_cross_pod():
    big = planner.plan_all_to_all(64 << 20, 16, intra_pod=False)
    small = planner.plan_all_to_all(4 << 10, 16, intra_pod=False)
    intra = planner.plan_all_to_all(64 << 20, 16, intra_pod=True)
    assert big.impl == "rotation"
    assert small.impl == "xla"
    assert intra.impl == "xla"


def test_planner_all_reduce_schedules():
    big = planner.plan_all_reduce(1 << 30, 2, intra_pod=False)
    assert big.impl in ("rs_ag", "xla")
    assert big.est_time_s > 0


def test_planner_degenerate_inputs():
    """n<=1 or zero/negative traffic must return an explicit empty plan
    (impl 'none', zero time) instead of dividing by zero -- the phase
    compiler maps these to empty phases."""
    for plan in (planner.plan_all_to_all(1 << 20, 1),
                 planner.plan_all_to_all(1 << 20, 0),
                 planner.plan_all_to_all(0, 16),
                 planner.plan_all_to_all(-5.0, 16),
                 planner.plan_all_reduce(1 << 30, 1),
                 planner.plan_all_reduce(0, 16),
                 planner.plan_all_reduce(-1.0, 16)):
        assert plan.impl == "none"
        assert plan.est_time_s == 0.0
        assert "degenerate" in plan.reason
