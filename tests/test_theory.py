"""Closed-form theory module tests (Theorems 1-5 machinery, bounds)."""
import math

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:            # fall back to a deterministic sample sweep
    from _hyp_fallback import given, settings, st

from repro.core import theory


def test_net_params_defaults_match_paper():
    net = theory.DEFAULT_NET
    assert net.frame_B == 4158          # 4096 + 62
    assert net.slot_B == 4178           # + 20 gap
    assert net.buffer_pkts == 191       # 800 KB / 4178
    # slot time ~41.78 ns at 800 Gbps
    assert abs(net.slot_s - 4178 * 8 / 800e9) < 1e-15


def test_ata_lower_bound_near_paper_value():
    """Paper §5: 'minimum possible completion time in our setup is ~1.3ms'
    for the 128-node ATA."""
    # paper's ATA at 1 MB per destination flow: 256 pkts x 127 dests
    b = theory.ata_cct_lower_bound_s(128, 1 << 20)
    assert 1.2e-3 < b < 1.5e-3


def test_permutation_bound_monotone_and_tight_region():
    b1 = theory.permutation_cct_lower_bound_s(64)
    b2 = theory.permutation_cct_lower_bound_s(256)
    b3 = theory.permutation_cct_lower_bound_s(1024)
    assert b1 < b2 < b3
    # App. B example: m=256 -> ~17.06 us
    assert abs(theory.permutation_cct_lower_bound_s(256) - 17.06e-6) < 0.4e-6


def test_optimal_packet_size_thm5():
    # P - H = sqrt(H D / alpha); paper uses H=82, alpha=10
    for D in [32 << 10, 1 << 20, 16 << 20]:
        p = theory.optimal_payload_B(D)
        assert abs(p - math.sqrt(82 * D / 10)) < 1e-9


@given(st.floats(1e4, 1e8))
@settings(max_examples=30, deadline=None)
def test_optimal_payload_minimizes_model(D):
    """Property: Thm 5's optimum beats nearby payloads under the CCT model."""
    p_star = theory.optimal_payload_B(D)
    c_star = theory.modeled_cct_slots(D, p_star)
    for f in (0.5, 0.8, 1.25, 2.0):
        assert c_star <= theory.modeled_cct_slots(D, p_star * f) + 1e-6


def test_sqrt_queue_payload_scaling_is_cube_root():
    Ds = np.array([1e5, 1e6, 1e7, 1e8])
    ps = theory.cube_root_payload_scaling(Ds)
    alpha, _ = theory.fit_power_law(Ds, ps)
    assert 0.25 < alpha < 0.42      # Theta(D^(1/3))


def test_fit_power_law_exact():
    m = np.array([10.0, 100.0, 1000.0])
    q = 3.0 * m ** 0.5
    a, c = theory.fit_power_law(m, q)
    assert abs(a - 0.5) < 1e-9 and abs(c - 3.0) < 1e-9


def test_q_laws_ordering():
    m = np.array([64, 256, 1024], float)
    lin = theory.q_linear(m)
    sq = theory.q_sqrt(m, 8)
    const = theory.q_nd_d_1(16, 1.0)
    assert (lin > sq).all()
    assert (sq > const).any()


def test_appc_probabilities_bounded():
    for k in (4, 8, 16, 32):
        assert 0.0 <= theory.p_hotspot(k) <= theory.p_northbound(k) <= 1.0
        assert theory.expected_collisions_rr(k) >= \
            theory.expected_collisions_jsq(k, 0.02)


def test_northbound_lower_bound_appd():
    # App. D: P_northbound >= 1 - (k-2)/(k^2-2) >= 6/7 for k=4
    for k in (4, 8, 16):
        assert theory.p_northbound(k) >= 1 - (k - 2) / (k ** 2 - 2) - 1e-9
