"""Differential-testing layer for the fused campaign engines.

Cross-tree-size fusion is the riskiest bitwise-parity surface in the repo: a
padded core switch that silently absorbs one packet skews queue-depth tails
without failing any coarse assertion.  Three independent oracles guard it:

  1. **Property-based parity** (hypothesis, with the ``_hyp_fallback``
     deterministic sweep when hypothesis isn't installed): randomized small
     campaigns -- mixed tree sizes, traffic matrices, schemes, failures,
     convergence times -- must produce bitwise-identical results through
     ``simulate_megabatch`` (via the planner/runner) and per-point serial
     ``simulate``, on BOTH engines.
  2. **Cross-engine agreement**: on contention-free workloads under the
     ideal fixed-rate CCA the two engines' timing models coincide exactly:
     ``loopsim.delivered_slot == floor(fastsim.delivery)`` packet-for-packet
     (hosts pace one packet/slot, queues never build, so the fractional
     phase is the only difference).  Run across a *fused mixed-k grid* this
     catches any padding bug one engine masks -- an absorbed or re-routed
     packet shifts a completion slot in one engine but not the other.
  3. **Sharded fusion**: the same mixed-k fused dispatch, ``shard_map``-ed
     over the two virtual CPU devices, must not perturb either engine.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                    # pragma: no cover
    from _hyp_fallback import given, settings, st

from repro.net.topology import FatTree, rho_max
from repro.net import workloads, fastsim, loopsim
from repro.core import lb_schemes as lbs
from repro import sweep
from repro.sweep.runner import build_links, build_workload


_TREES = (4, 6)


def _assert_fast_equal(res, ref):
    np.testing.assert_array_equal(res.delivery, ref.delivery)
    np.testing.assert_array_equal(res.flow_completion, ref.flow_completion)
    np.testing.assert_array_equal(res.a_used, ref.a_used)
    np.testing.assert_array_equal(res.c_used, ref.c_used)
    assert res.cct == ref.cct
    assert res.max_queue == ref.max_queue
    for name in ref.layers:
        np.testing.assert_array_equal(res.layers[name].counts,
                                      ref.layers[name].counts)
        assert res.layers[name].max_queue == ref.layers[name].max_queue
        assert res.layers[name].avg_wait == ref.layers[name].avg_wait


def _assert_loop_equal(res, ref):
    np.testing.assert_array_equal(res.delivered_slot, ref.delivered_slot)
    np.testing.assert_array_equal(res.flow_complete_slot,
                                  ref.flow_complete_slot)
    np.testing.assert_array_equal(res.flow_data_done_slot,
                                  ref.flow_data_done_slot)
    assert res.cct_slots == ref.cct_slots
    assert res.drops == ref.drops
    assert res.retransmissions == ref.retransmissions
    assert res.max_queue == ref.max_queue
    assert res.avg_queue == ref.avg_queue
    assert res.mean_cwnd == ref.mean_cwnd


# ---------------------------------------------------------------------------
# 1. Property-based megabatch-vs-serial parity (both engines).
# ---------------------------------------------------------------------------

# Schemes are drawn per-example but the compile universe stays bounded:
# message sizes and tree sizes come from small fixed pools so repeated
# examples reuse the in-process executable caches.

@settings(max_examples=6, deadline=None)
@given(st.sampled_from(("host_pkt", "host_dr", "switch_pkt", "ofan", "jsq")),
       st.sampled_from((2, 3)),
       st.integers(min_value=1, max_value=10_000),
       st.sampled_from((None, 0.05, 0.1)))
def test_random_fast_campaign_bitwise(scheme, msg, wl_seed, p_fail):
    """Random mixed-k fast-engine campaigns: the fused planner/runner path
    must reproduce per-point serial ``fastsim.simulate`` bitwise."""
    failures = (None if p_fail is None
                else sweep.FailureSpec(p_fail, rng_seed=wl_seed % 97))
    c = sweep.Campaign(
        name="diff_fast", schemes=(scheme,),
        loads=(sweep.WorkloadSpec("permutation", msg, rng_seed=wl_seed),),
        trees=_TREES, seeds=(0, 1), failures=(failures,))
    plan = sweep.plan(c)
    assert plan.n_dispatches == plan.n_shapes
    _, full = sweep.run_campaign(c, keep_full=True)
    assert len(full) == c.n_points
    for point, res in full.items():
        tree = FatTree(point.k)
        ref = fastsim.simulate(tree, build_workload(tree, point.load),
                               lbs.by_name(point.scheme), seed=point.seed,
                               links=build_links(tree, point.failure))
        _assert_fast_equal(res, ref)


@settings(max_examples=4, deadline=None)
@given(st.sampled_from(("host_pkt", "host_dr", "ofan", "host_pkt_ar",
                        "rsq")),
       st.integers(min_value=1, max_value=10_000),
       st.sampled_from((None, 0.05)),
       st.sampled_from((None, 0, 300)))
def test_random_loop_campaign_bitwise(scheme, wl_seed, p_fail, g):
    """Random mixed-k loop-engine campaigns (failures, convergence times and
    rho_max riding the fused axis): the fused path must reproduce per-point
    serial ``loopsim.simulate`` bitwise.  The scheme pool includes ``rsq``:
    in-loop rand draws now come from shape-independent counter streams, so
    randomized switch schemes fuse across tree sizes like everything else."""
    failures = (None if p_fail is None
                else sweep.FailureSpec(p_fail, rng_seed=wl_seed % 89))
    c = sweep.Campaign(
        name="diff_loop", schemes=(scheme,),
        loads=(sweep.WorkloadSpec("permutation", 4, inter_pod_only=True,
                                  rng_seed=wl_seed),),
        trees=_TREES, seeds=(0,), failures=(failures,), g_converge=(g,),
        engine="loop", max_slots=4000,
        loop_opts=(("rho", "auto"), ("rto_slots", 300)))
    plan = sweep.plan(c)
    assert plan.n_dispatches == plan.n_shapes == 1
    _, full = sweep.run_campaign(c, keep_full=True)
    assert len(full) == c.n_points
    for point, res in full.items():
        tree = FatTree(point.k)
        wl = build_workload(tree, point.load)
        links = build_links(tree, point.failure)
        rho = (rho_max(tree, links, wl.flow_src, wl.flow_dst)
               if links is not None else 1.0)
        ref = loopsim.simulate(tree, wl, lbs.by_name(point.scheme),
                               c.loop_config(rho), seed=point.seed,
                               links=links, g_converge=point.g_converge)
        _assert_loop_equal(res, ref)


def test_mixed_k_rand_jsq_loop_campaign_bitwise():
    """Acceptance for counter-stream randomness: a mixed-k loop campaign of
    ONLY rand/JSQ schemes -- the family the paper's host-vs-switch spraying
    comparison stresses, and the last one excluded from cross-tree-size
    fusion -- plans to one dispatch per compiled shape (no raw-k keys) and
    reproduces per-point serial ``loopsim.simulate`` bitwise, with the
    failure, g_converge and rho_max axes riding the fused batch.  Runs
    through the runner, so with two visible devices the fused dispatches
    are also shard_map-sharded."""
    c = sweep.Campaign(
        name="diff_rand_jsq", schemes=("rsq", "jsq", "switch_pkt_ar"),
        loads=(sweep.WorkloadSpec("permutation", 4, inter_pod_only=True,
                                  rng_seed=3),),
        trees=_TREES, seeds=(0,),
        failures=(None, sweep.FailureSpec(0.05, rng_seed=11)),
        g_converge=(300,),
        engine="loop", max_slots=4000,
        loop_opts=(("rho", "auto"), ("rto_slots", 300)))
    plan = sweep.plan(c)
    # One fused dispatch per port-choice branch (rand / jsq / jsq_quant),
    # each spanning every tree size of the campaign's k-bucket.
    assert plan.n_dispatches == plan.n_shapes == 3
    assert all({b.k for b in m.members} == set(_TREES)
               for m in plan.megabatches)
    _, full = sweep.run_campaign(c, keep_full=True)
    assert len(full) == c.n_points
    for point, res in full.items():
        tree = FatTree(point.k)
        wl = build_workload(tree, point.load)
        links = build_links(tree, point.failure)
        rho = (rho_max(tree, links, wl.flow_src, wl.flow_dst)
               if links is not None else 1.0)
        ref = loopsim.simulate(tree, wl, lbs.by_name(point.scheme),
                               c.loop_config(rho), seed=point.seed,
                               links=links, g_converge=point.g_converge)
        _assert_loop_equal(res, ref)


def test_mixed_k_mixed_shape_jsq_megabatch_bitwise():
    """One fused JSQ dispatch whose two members differ in tree size AND
    workload shape (permutation vs all-to-all: packet counts, flow counts,
    host_flows columns and pkt_base all pad): in-loop JSQ noise is keyed on
    logical packet/host ids, so every axis of padding must leave each
    member's draws -- and hence results -- bitwise-unchanged."""
    t4, t6 = FatTree(4), FatTree(6)
    wl_a = workloads.all_to_all(t4, 1)
    wl_p = workloads.permutation(t6, 4, np.random.default_rng(7))
    cfg = loopsim.LoopConfig(max_slots=4000)
    sch = lbs.by_name("jsq")
    items = [(t4, wl_a, sch, cfg, [0, 1], None, None),
             (t6, wl_p, sch, cfg, [0], None, None)]
    out = loopsim.simulate_megabatch(items)
    for (t, w, s_, c_, seeds, _, _), results in zip(items, out):
        for s, res in zip(seeds, results):
            assert res.delivered_slot.shape[0] == w.n_packets
            _assert_loop_equal(res, loopsim.simulate(t, w, s_, c_, seed=s))


# ---------------------------------------------------------------------------
# 2. Cross-engine agreement on per-packet completion slots.
# ---------------------------------------------------------------------------

def _single_flow(tree: FatTree, m: int):
    """One inter-pod flow: traverses all 5 queueing layers, no contention."""
    return workloads._packets_from_flows(
        "single", tree.n_hosts, np.array([0]),
        np.array([tree.n_hosts - 1]), np.array([m]))


def _same_edge_perm(tree: FatTree, m: int):
    """Every host sends to the next slot of its own edge switch: each DN_E
    queue serves exactly one 1-packet-per-slot flow -- contention-free."""
    src = np.arange(tree.n_hosts)
    dst = tree.host_id(tree.host_pod(src), tree.host_edge(src),
                       (tree.host_slot(src) + 1) % tree.hosts_per_edge)
    return workloads._packets_from_flows("same_edge", tree.n_hosts, src, dst,
                                         np.full(tree.n_hosts, m))


_XENGINE_CFG = loopsim.LoopConfig(rho=1.0, ack_cost=0.0, prop_slots=12,
                                  max_slots=4000)


@pytest.mark.parametrize("make_wl", (_single_flow, _same_edge_perm),
                         ids=("single_flow", "same_edge"))
@pytest.mark.parametrize("scheme", ("host_pkt", "host_dr", "ofan"))
def test_engines_agree_on_completion_slots_cross_k(scheme, make_wl):
    """Feedback-free schemes under the ideal fixed-rate CCA: on
    contention-free traffic the slotted engine's per-packet delivery slot
    equals floor() of the max-plus engine's delivery time, packet-for-packet
    -- asserted across a MIXED-k fused dispatch on both engines, so a padded
    switch absorbing or re-routing even one packet breaks the equality in
    exactly one engine."""
    sch = lbs.by_name(scheme)
    trees = [FatTree(k) for k in _TREES]
    wls = [make_wl(t, 12) for t in trees]
    fast = fastsim.simulate_megabatch(
        [(t, w, sch, [0], None) for t, w in zip(trees, wls)],
        prop_slots=12.0)
    loop = loopsim.simulate_megabatch(
        [(t, w, sch, _XENGINE_CFG, [0], None, None)
         for t, w in zip(trees, wls)])
    for t, w, (fres,), (lres,) in zip(trees, wls, fast, loop):
        # Premise: genuinely contention-free in both engines (the fast
        # engine's occupancies are f32 differences, so "empty" is ~1e-6).
        assert fres.max_queue < 0.5
        assert lres.max_queue <= 1 and lres.drops == 0
        np.testing.assert_array_equal(
            lres.delivered_slot,
            np.floor(fres.delivery).astype(lres.delivered_slot.dtype))


# ---------------------------------------------------------------------------
# 3. Mixed-k fusion through the sharded dispatch path.
# ---------------------------------------------------------------------------

def test_cross_k_sharded_megabatch_bitwise(two_devices):
    """shard_map over a fused axis whose rows span two tree sizes must not
    change results on either engine (3 rows also force the 3 -> 4 shard
    divisibility padding)."""
    trees = [FatTree(k) for k in _TREES]
    wls = [workloads.permutation(t, 4, np.random.default_rng(5))
           for t in trees]
    sch = lbs.by_name("host_dr")
    items_f = [(trees[0], wls[0], sch, [0, 1], None),
               (trees[1], wls[1], sch, [0], None)]
    for (t, w, s_, seeds, _), results in zip(
            items_f, fastsim.simulate_megabatch(items_f, n_shards="auto")):
        for seed, res in zip(seeds, results):
            _assert_fast_equal(res, fastsim.simulate(t, w, s_, seed=seed))
    cfg = loopsim.LoopConfig(max_slots=4000)
    items_l = [(trees[0], wls[0], sch, cfg, [0, 1], None, None),
               (trees[1], wls[1], sch, cfg, [0], None, None)]
    for (t, w, s_, c, seeds, _, _), results in zip(
            items_l, loopsim.simulate_megabatch(items_l, n_shards="auto")):
        for seed, res in zip(seeds, results):
            _assert_loop_equal(res, loopsim.simulate(t, w, s_, c, seed=seed))


# ---------------------------------------------------------------------------
# 4. Pallas slot-step impl: e2e campaign parity vs the inline lax engine.
# ---------------------------------------------------------------------------

def test_pallas_impl_e2e_campaign_bitwise(two_devices):
    """``LoopConfig.impl="pallas"`` end to end: a mixed-k fused loop
    campaign (JSQ + quantized-JSQ schemes, with and without failures, run
    through the planner/runner and shard_map-sharded over two devices) is
    bitwise-identical to the same campaign under ``impl="lax"`` -- integer
    outputs exactly, and the float outputs (avg_queue, mean_cwnd) exactly
    too, since both paths preserve f32 reduction order (the documented
    bound is therefore 0 ULP, asserted via strict equality).  The two impls
    carry distinct planner compile keys, each planning
    ``n_dispatches == n_shapes``."""
    def _campaign(impl):
        return sweep.Campaign(
            name=f"diff_impl_{impl}", schemes=("jsq", "switch_pkt_ar"),
            loads=(sweep.WorkloadSpec("permutation", 4, inter_pod_only=True,
                                      rng_seed=3),),
            trees=_TREES, seeds=(0,),
            failures=(None, sweep.FailureSpec(0.05, rng_seed=11)),
            g_converge=(300,),
            engine="loop", max_slots=4000,
            loop_opts=(("rho", "auto"), ("rto_slots", 300),
                       ("impl", impl)))

    c_lax, c_pal = _campaign("lax"), _campaign("pallas")
    p_lax, p_pal = sweep.plan(c_lax), sweep.plan(c_pal)
    # Mixed-impl grids stay fused per impl: each impl's grid plans one
    # dispatch per compiled shape, under *distinct* compile keys.
    assert p_lax.n_dispatches == p_lax.n_shapes == 2
    assert p_pal.n_dispatches == p_pal.n_shapes == 2
    assert ({m.key for m in p_lax.megabatches}
            != {m.key for m in p_pal.megabatches})

    _, full_lax = sweep.run_campaign(c_lax, keep_full=True)
    _, full_pal = sweep.run_campaign(c_pal, keep_full=True)
    assert len(full_pal) == c_pal.n_points
    ref_by_key = {(pt.scheme, pt.k, pt.failure.label() if pt.failure
                   else None, pt.seed): res
                  for pt, res in full_lax.items()}
    for pt, res in full_pal.items():
        ref = ref_by_key[(pt.scheme, pt.k, pt.failure.label() if pt.failure
                          else None, pt.seed)]
        _assert_loop_equal(res, ref)


def test_impl_auto_resolves_to_lax_off_tpu(monkeypatch):
    """``impl="auto"`` keeps the engine on the inline lax path off-TPU
    unless CI forces interpret kernels via REPRO_PALLAS=interpret."""
    from repro.kernels.slot_step import ops as slot_ops
    if slot_ops._on_tpu():
        pytest.skip("auto resolves to pallas on TPU by design")
    monkeypatch.delenv("REPRO_PALLAS", raising=False)
    assert slot_ops.resolve_impl("auto") == "lax"
    monkeypatch.setenv("REPRO_PALLAS", "interpret")
    assert slot_ops.resolve_impl("auto") == "pallas"
