"""Minimal stand-in for ``hypothesis`` so the suite collects (and the
property tests still exercise a deterministic sample sweep) when the real
package is not installed.

Only the tiny API surface these tests use is provided: ``given`` /
``settings`` decorators and the ``integers`` / ``floats`` / ``sampled_from``
strategies.  Values are drawn from a fixed-seed generator, so a fallback run
is reproducible; installing ``hypothesis`` (the ``dev`` extra in
pyproject.toml) restores full shrinking/edge-case search.
"""
from __future__ import annotations

import inspect

import numpy as np

_DEFAULT_EXAMPLES = 20


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: np.random.Generator):
        return self._draw(rng)


class _StrategiesModule:
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rng: int(rng.integers(min_value,
                                                      max_value + 1)))

    @staticmethod
    def floats(min_value, max_value):
        return _Strategy(
            lambda rng: float(min_value + (max_value - min_value)
                              * rng.random()))

    @staticmethod
    def sampled_from(values):
        seq = list(values)
        return _Strategy(lambda rng: seq[int(rng.integers(0, len(seq)))])


st = _StrategiesModule()


def settings(max_examples: int = _DEFAULT_EXAMPLES, deadline=None, **_kw):
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return deco


def given(*strategies):
    def deco(fn):
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_fallback_max_examples",
                        _DEFAULT_EXAMPLES)
            rng = np.random.default_rng(0)
            for i in range(n):
                vals = tuple(s.example(rng) for s in strategies)
                try:
                    fn(*args, *vals, **kwargs)
                except Exception as e:  # pragma: no cover - failure path
                    raise AssertionError(
                        f"property falsified on fallback example {i}: "
                        f"args={vals!r}") from e
        # Strategy-supplied parameters must not look like pytest fixtures:
        # expose a zero-argument signature instead of functools.wraps (which
        # would copy the inner signature and __wrapped__).
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__dict__.update(fn.__dict__)
        wrapper.__signature__ = inspect.Signature()
        return wrapper
    return deco
