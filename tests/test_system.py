"""End-to-end behaviour tests for the paper's system.

The top-level claims, executed against the real stack:
  1. the LB hierarchy (packet spraying > coarse; DR optimal) on both engines;
  2. no leading contender achieves O(1) queues; DR/OFAN do;
  3. OFAN's consolidation invariant (App. F Inv. 1) holds in simulation;
  4. the trainer integrates the discipline and trains/checkpoints/serves.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.net.topology import FatTree
from repro.net import workloads, fastsim
from repro.core import lb_schemes as lbs
from repro.core import theory


def test_performance_hierarchy_end_to_end():
    """Paper finding #1: packet spraying dominates flow/subflow granularity;
    DR dominates spraying."""
    tree = FatTree(4)
    wl = workloads.permutation(tree, 128, np.random.default_rng(0),
                               inter_pod_only=True)
    cct = {name: fastsim.simulate(tree, wl, lbs.by_name(name), seed=1).cct
           for name in ("flow_ecmp", "subflow_mptcp", "host_pkt", "ofan")}
    assert cct["ofan"] < cct["host_pkt"] < cct["subflow_mptcp"] \
        < cct["flow_ecmp"]


def test_queue_optimality_claim():
    """Paper findings #2+#3: no leading contender is O(1); DR is."""
    tree = FatTree(4)
    qs = {}
    for name in ("host_pkt", "switch_pkt_ar", "host_dr", "ofan"):
        row = []
        for m in (64, 512):
            wl = workloads.permutation(tree, m, np.random.default_rng(2),
                                       inter_pod_only=True)
            row.append(fastsim.simulate(tree, wl, lbs.by_name(name),
                                        seed=0).max_queue)
        qs[name] = row
    # contenders grow with m; DR stays flat
    assert qs["host_pkt"][1] > 1.5 * qs["host_pkt"][0]
    assert qs["switch_pkt_ar"][1] > 1.5 * qs["switch_pkt_ar"][0]
    assert qs["host_dr"][1] < 2 * qs["host_dr"][0] + 3
    assert qs["ofan"][1] < 2 * qs["ofan"][0] + 3


def test_ofan_consolidation_invariant():
    """Inv. 1 (App. F): per (source switch, destination group) traffic
    spreads equally across candidate links -- checked on A->C counts."""
    tree = FatTree(4)
    wl = workloads.permutation(tree, 240, np.random.default_rng(3),
                               inter_pod_only=True)
    res = fastsim.simulate(tree, wl, lbs.ofan(), seed=4)
    h = tree.half
    counts = res.layers["A->C"].counts.reshape(tree.n_pods, h, h)
    for p in range(tree.n_pods):
        for a in range(h):
            c = counts[p, a]
            if c.sum() == 0:
                continue
            assert c.max() - c.min() <= max(2, 0.1 * c.mean()), (p, a, c)


def test_trainer_integration_smoke():
    """Train a smoke model 3 steps, checkpoint, restore, decode."""
    from repro.configs.base import get_config
    from repro.models.registry import Model
    from repro.train import train_step as ts
    from repro.train import checkpoint as ckpt
    from repro.serve import serve_step
    import tempfile

    model = Model(get_config("yi-6b", smoke=True))
    params = model.init_params(jax.random.PRNGKey(0))
    tcfg = ts.TrainConfig(learning_rate=1e-3)
    state = ts.make_train_state(model, params, tcfg)
    step = jax.jit(ts.build_train_step(model, tcfg))
    r = np.random.default_rng(0)
    for i in range(3):
        batch = {"tokens": jnp.asarray(
            r.integers(0, model.cfg.vocab, (2, 16)), jnp.int32)}
        state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))

    with tempfile.TemporaryDirectory() as d:
        ckpt.save(state, d, step=3)
        target = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
        restored, _ = ckpt.restore(d, target)
        np.testing.assert_array_equal(
            np.asarray(restored["step"]), np.asarray(state["step"]))

    out = serve_step.greedy_decode(
        model, state["params"],
        jnp.asarray(r.integers(0, model.cfg.vocab, (1, 4)), jnp.int32),
        n_new=2)
    assert out.shape == (1, 2)


def test_paper_constants_coherent():
    """The slot/byte constants behind every normalized metric."""
    net = theory.DEFAULT_NET
    assert abs(net.prop_slots - 0.5e-6 / net.slot_s) < 1e-9
    # min RTT in the paper's ~6.25us zero-delay region
    assert 4e-6 < net.min_rtt_s < 9e-6
