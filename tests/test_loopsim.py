"""Slotted feedback engine: cross-validation against fastsim, transport
behavior (SACK/erasure/MSwift), and failure handling."""
import numpy as np
import pytest

from repro.net.topology import FatTree, LinkState, rho_max
from repro.net import workloads, fastsim, loopsim
from repro.core import lb_schemes as lbs


@pytest.fixture(scope="module")
def tree():
    return FatTree(4)


@pytest.fixture(scope="module")
def wl(tree):
    return workloads.permutation(tree, 32, np.random.default_rng(1),
                                 inter_pod_only=True)


CFG = loopsim.LoopConfig(max_slots=4000)


def test_all_flows_complete(tree, wl):
    res = loopsim.simulate(tree, wl, lbs.ofan(), CFG, seed=0)
    assert res.finished
    assert (res.flow_complete_slot >= 0).all()
    assert res.drops == 0


def test_engines_agree_on_ranking(tree, wl):
    """fastsim and loopsim must rank schemes identically (their dynamics
    differ in ACK modeling, so we compare orderings, not exact CCTs)."""
    ccts_fast, ccts_loop = {}, {}
    for name in ["simple_rr", "host_pkt", "ofan"]:
        ccts_fast[name] = fastsim.simulate(tree, wl, lbs.by_name(name),
                                           seed=2).cct
        ccts_loop[name] = loopsim.simulate(tree, wl, lbs.by_name(name),
                                           CFG, seed=2).cct_slots
    assert (ccts_fast["ofan"] < ccts_fast["host_pkt"]
            < ccts_fast["simple_rr"])
    assert (ccts_loop["ofan"] < ccts_loop["host_pkt"]
            < ccts_loop["simple_rr"])


def test_ofan_queue_bounded(tree, wl):
    res = loopsim.simulate(tree, wl, lbs.ofan(), CFG, seed=0)
    assert res.max_queue <= 6       # Theta(1) discipline


def test_sack_completes_and_counts_rtx(tree, wl):
    cfg = loopsim.LoopConfig(loss="sack", max_slots=4000, sack_thresh=8)
    res = loopsim.simulate(tree, wl, lbs.host_pkt(), cfg, seed=0)
    assert res.finished
    assert (res.delivered_slot >= 0).all()


def test_mswift_reins_in_rate(tree):
    """With a long message MSwift must keep queues near target (paper §8.3:
    the CCA throttles spraying schemes; OFAN needs no throttling)."""
    wl = workloads.permutation(tree, 256, np.random.default_rng(3),
                               inter_pod_only=True)
    cfg = loopsim.LoopConfig(cca="mswift", loss="sack", max_slots=20000,
                             sw_target_slots=80.0)
    spray = loopsim.simulate(tree, wl, lbs.host_pkt(), cfg, seed=0)
    ofan = loopsim.simulate(tree, wl, lbs.ofan(), cfg, seed=0)
    assert spray.finished and ofan.finished
    assert ofan.cct_slots <= spray.cct_slots
    assert ofan.mean_cwnd >= spray.mean_cwnd - 1e-6   # OFAN not throttled


def _links_with_failures(tree, p, seed0):
    for s in range(seed0, seed0 + 50):
        links = LinkState.random_failures(tree, p, np.random.default_rng(s))
        if links.any_failure():
            return links
    raise RuntimeError("no failures sampled")


def test_failures_blackhole_before_convergence(tree, wl):
    links = _links_with_failures(tree, 0.08, 4)
    res_inf = loopsim.simulate(tree, wl, lbs.host_pkt(),
                               loopsim.LoopConfig(max_slots=12000,
                                                  rto_slots=300),
                               seed=0, links=links, g_converge=None)
    res_0 = loopsim.simulate(tree, wl, lbs.host_pkt(),
                             loopsim.LoopConfig(max_slots=12000,
                                                rto_slots=300),
                             seed=0, links=links, g_converge=0)
    assert res_0.drops < res_inf.drops
    assert res_0.cct_slots <= res_inf.cct_slots


def test_host_ar_beats_switch_ar_under_slow_convergence(tree, wl):
    """§5.2 headline: HOST PKT AR (REPS) dominates SWITCH PKT AR at
    G = infinity because end-to-end label feedback routes around failures."""
    links = _links_with_failures(tree, 0.08, 7)
    cfg = loopsim.LoopConfig(max_slots=12000, rto_slots=250)
    host = loopsim.simulate(tree, wl, lbs.host_pkt_ar(), cfg, seed=1,
                            links=links, g_converge=None)
    switch = loopsim.simulate(tree, wl, lbs.switch_pkt_ar(), cfg, seed=1,
                              links=links, g_converge=None)
    assert host.finished
    assert host.cct_slots <= switch.cct_slots


def test_rho_max_prevents_overload(tree):
    links = LinkState.random_failures(tree, 0.15, np.random.default_rng(9))
    wl2 = workloads.permutation(tree, 48, np.random.default_rng(2),
                                inter_pod_only=True)
    rho = rho_max(tree, links, wl2.flow_src, wl2.flow_dst)
    if rho == 0.0:
        pytest.skip("disconnected flow in sampled failure")
    cfg = loopsim.LoopConfig(max_slots=20000, rho=float(rho), rto_slots=400)
    res = loopsim.simulate(tree, wl2, lbs.host_dr(), cfg, seed=0,
                           links=links, g_converge=0)
    assert res.finished


def test_ack_debt_slows_bidirectional_hosts(tree):
    """App. B: hosts that both send and receive pay the ACK serialization
    tax; CCT must exceed the pure one-way bound."""
    wl2 = workloads.permutation(tree, 64, np.random.default_rng(5),
                                inter_pod_only=True)
    res = loopsim.simulate(tree, wl2, lbs.ofan(), CFG, seed=0)
    # one-way send time is 64 slots; with ack debt ~2% and pipeline ~5 hops
    assert res.cct_slots >= 64 * 1.01


# ---------------------------------------------------------------------------
# Batched dispatch: bitwise parity with serial simulate.
# ---------------------------------------------------------------------------

def _assert_loop_equal(res, ref):
    np.testing.assert_array_equal(res.delivered_slot, ref.delivered_slot)
    np.testing.assert_array_equal(res.flow_complete_slot,
                                  ref.flow_complete_slot)
    np.testing.assert_array_equal(res.flow_data_done_slot,
                                  ref.flow_data_done_slot)
    assert res.cct_slots == ref.cct_slots
    assert res.cct_acked_slots == ref.cct_acked_slots
    assert res.drops == ref.drops
    assert res.retransmissions == ref.retransmissions
    assert res.max_queue == ref.max_queue
    assert res.avg_queue == ref.avg_queue
    assert res.finished == ref.finished
    assert res.mean_cwnd == ref.mean_cwnd


_CFGS = {
    "erasure": loopsim.LoopConfig(max_slots=4000),
    "sack": loopsim.LoopConfig(loss="sack", sack_thresh=8, max_slots=4000),
    "short_buffer": loopsim.LoopConfig(loss="sack", sack_thresh=8,
                                       buffer_pkts=20, max_slots=4000),
    "mswift": loopsim.LoopConfig(cca="mswift", loss="sack", max_slots=8000,
                                 sw_target_slots=80.0),
}


@pytest.mark.parametrize("cfg_name", sorted(_CFGS))
@pytest.mark.parametrize("scheme", ("host_pkt", "ofan"))
def test_batch_bitwise_identical_to_serial(tree, wl, cfg_name, scheme):
    """simulate_batch must reproduce serial simulate exactly per seed across
    the erasure / SACK / short-buffer / MSwift paths (rows finish at
    different slot counts; the fused while_loop masks finished rows)."""
    cfg = _CFGS[cfg_name]
    seeds = [0, 1, 2]
    batch = loopsim.simulate_batch(tree, wl, lbs.by_name(scheme), seeds, cfg)
    for s, res in zip(seeds, batch):
        _assert_loop_equal(res, loopsim.simulate(tree, wl,
                                                 lbs.by_name(scheme), cfg,
                                                 seed=s))


@pytest.mark.parametrize("cfg_name", ("sack", "mswift"))
def test_megabatch_bitwise_identical_to_serial(tree, wl, cfg_name):
    """One fused dispatch over two workloads with different packet AND flow
    counts (permutation vs all-to-all: the flow axis, host_flows columns and
    pkt_base all pad) must reproduce serial simulate exactly, per point."""
    cfg = _CFGS[cfg_name]
    wl_b = workloads.all_to_all(tree, 2)
    items = [(tree, wl, lbs.host_pkt(), cfg, [0, 1], None, None),
             (tree, wl_b, lbs.host_dr(), cfg, [0], None, None)]
    out = loopsim.simulate_megabatch(items, npk_pad=1024)
    for (t, w, sch, c, seeds, l, g), results in zip(items, out):
        for s, res in zip(seeds, results):
            assert res.delivered_slot.shape[0] == w.n_packets
            assert res.flow_complete_slot.shape[0] == w.n_flows
            _assert_loop_equal(res, loopsim.simulate(t, w, sch, c, seed=s))


def test_megabatch_fuses_failure_and_g_axes_bitwise(tree, wl):
    """Failure pattern, g_converge, rho and max_slots are per-row operands:
    points differing only in them share one fused dispatch and stay
    bitwise-identical to serial."""
    links = _links_with_failures(tree, 0.08, 4)
    cfg_a = loopsim.LoopConfig(max_slots=12000, rto_slots=300, rho=0.8)
    cfg_b = loopsim.LoopConfig(max_slots=9000, rto_slots=300, rho=1.0)
    items = [(tree, wl, lbs.host_pkt_ar(), cfg_a, [0], links, 0),
             (tree, wl, lbs.host_pkt_ar(), cfg_a, [0], links, None),
             (tree, wl, lbs.host_pkt_ar(), cfg_b, [0, 1], None, None)]
    out = loopsim.simulate_megabatch(items)
    for (t, w, sch, c, seeds, l, g), results in zip(items, out):
        for s, res in zip(seeds, results):
            _assert_loop_equal(res, loopsim.simulate(t, w, sch, c, seed=s,
                                                     links=l, g_converge=g))


def test_megabatch_sharded_bitwise_identical(tree, wl, two_devices):
    """shard_map over the fused axis (2 virtual devices from conftest's
    XLA_FLAGS) must not change results; the 3-element batch also forces the
    shard-divisibility padding path (3 -> 4)."""
    cfg = _CFGS["sack"]
    items = [(tree, wl, lbs.ofan(), cfg, [0, 1, 2], None, None)]
    (results,) = loopsim.simulate_megabatch(items, n_shards="auto")
    for s, res in zip([0, 1, 2], results):
        _assert_loop_equal(res, loopsim.simulate(tree, wl, lbs.ofan(), cfg,
                                                 seed=s))


def test_megabatch_rejects_mixed_pipeline_identities(tree, wl):
    with pytest.raises(ValueError, match="pipeline identities"):
        loopsim.simulate_megabatch(
            [(tree, wl, lbs.host_pkt(), _CFGS["erasure"], [0], None, None),
             (tree, wl, lbs.host_pkt(), _CFGS["sack"], [0], None, None)])


# ---- zero-packet flows (msg_packets=0, degenerate phases) ------------------

def test_zero_packet_workload(tree):
    """An all-empty workload (every flow size 0) runs, finishes, and
    reports CCT 0 -- not the pipeline latency of the first delivery
    check, and not a crash on the empty maxima."""
    wl = workloads.permutation(tree, 0, np.random.default_rng(1))
    assert wl.n_packets == 0 and wl.n_flows > 0
    res = loopsim.simulate(tree, wl, lbs.host_pkt(),
                           loopsim.LoopConfig(max_slots=500), seed=0)
    assert res.finished
    assert res.cct_slots == 0.0 and res.cct_acked_slots == 0.0
    assert res.delivered_slot.shape == (0,)
    assert (res.flow_complete_slot == 0).all()
    assert (res.flow_data_done_slot == 0).all()


def test_mixed_zero_flows_inert(tree):
    """Flows of size 0 mixed into a real workload are inert: they complete
    at slot 0 and the nonzero flows run exactly as if the empty ones were
    absent (same packet layout contract the phase compiler relies on)."""
    fsize = np.array([3, 0, 2, 0, 1, 4, 0, 2])
    src = np.arange(8)
    dst = (np.arange(8) + 3) % tree.n_hosts
    mixed = workloads._packets_from_flows("mix", tree.n_hosts, src, dst,
                                          fsize)
    np.testing.assert_array_equal(
        np.asarray(mixed.flow), np.repeat(np.arange(8), fsize))
    cfg = loopsim.LoopConfig(max_slots=500)
    res = loopsim.simulate(tree, mixed, lbs.host_pkt(), cfg, seed=0)
    assert res.finished
    assert (res.flow_complete_slot[fsize == 0] == 0).all()
    assert (res.flow_data_done_slot[fsize == 0] == 0).all()
    keep = fsize > 0
    dense = workloads._packets_from_flows("dense", tree.n_hosts, src[keep],
                                          dst[keep], fsize[keep])
    ref = loopsim.simulate(tree, dense, lbs.host_pkt(), cfg, seed=0)
    np.testing.assert_array_equal(res.delivered_slot, ref.delivered_slot)
    assert res.cct_slots == ref.cct_slots
