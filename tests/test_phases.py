"""Collective-phase training campaigns (``repro.phases``).

The PhaseSchedule axis follows the FaultSchedule contract: it rides the
fused campaign grid (phase shape folds into the fused key, so
``n_dispatches == n_shapes`` still holds), and its differential
obligations mirror the faults suite:

  (a) a single-phase schedule is **bitwise-identical** to the static
      workload path on BOTH engines;
  (b) a fused multi-phase mixed-k campaign -- including a phases x faults
      point -- reproduces per-point serial simulation bitwise;
  (c) phase/iteration record fields are only-when-set, so pre-phase
      campaign files stay byte-identical under ``--resume``.

Plus the schedule-level invariants: ``n_packets`` agrees with ``compile``
without materializing, compiled packets stay flow-contiguous (the loop
engine's layout contract), JSON round-trips preserve identity and label,
and degenerate collectives (n<=1, zero bytes) compile to empty phases
instead of dividing by zero.
"""
import json

import numpy as np
import pytest

from repro import sweep
from repro.core import lb_schemes as lbs
from repro.faults import FaultSchedule
from repro.net import fastsim, loopsim, workloads
from repro.net.topology import FatTree
from repro.obs.report import render_report
from repro.phases import Phase, PhaseSchedule, phases_from_dict
from repro.sweep.results import ResultStore, summarize
from repro.sweep.runner import build_workload, run_campaign
from repro.sweep.spec import PRESETS, Campaign, WorkloadSpec


@pytest.fixture(scope="module")
def tree():
    return FatTree(4)


def _model_sched(iterations=2):
    return PhaseSchedule.from_model("deepseek-v3-671b", ep=8, dp=8,
                                    iterations=iterations)


# ---- schedule-level invariants --------------------------------------------

def test_from_model_phase_structure():
    s = _model_sched()
    names = [p.name for p in s.phases]
    assert names == ["moe_dispatch", "moe_combine", "grad_allreduce",
                     "fsdp_allgather"]
    kinds = [p.collective for p in s.phases]
    assert kinds == ["all_to_all", "all_to_all", "all_reduce", "fsdp_ring"]
    assert all(p.bytes > 0 and p.n > 1 for p in s.phases)


def test_label_and_roundtrip():
    s = _model_sched()
    lab = s.label()
    assert lab.startswith("deepseek-v3-671b-4p2i-")
    d = json.loads(json.dumps(s.to_dict()))
    assert d["kind"] == "phases"
    back = phases_from_dict(d)
    assert back == s
    assert back.label() == lab
    # label discriminates on phase content, not just shape
    other = PhaseSchedule(s.name, s.phases[:-1] + (
        Phase("fsdp_allgather", "fsdp_ring", 1.0, s.phases[-1].n),),
        iterations=s.iterations)
    assert other.label() != lab
    assert phases_from_dict(None) is None


def test_n_packets_matches_compile(tree):
    s = _model_sched()
    cp = s.compile(tree, 8)
    assert s.n_packets(4, 8) == cp.workload.n_packets
    assert cp.n_instances == s.n_phases * s.iterations
    # starts are strictly increasing and packet ranges partition the axis
    assert (np.diff(cp.phase_start) > 0).all()
    assert cp.pkt_lo[0] == 0 and cp.pkt_hi[-1] == cp.workload.n_packets
    assert (cp.pkt_lo[1:] == cp.pkt_hi[:-1]).all()


def test_compiled_packets_flow_contiguous(tree):
    wl = _model_sched().compile(tree, 8).workload
    expect = np.repeat(np.arange(wl.n_flows), np.asarray(wl.flow_size))
    np.testing.assert_array_equal(np.asarray(wl.flow), expect)
    assert wl.flow_start is not None and wl.flow_start.shape == (wl.n_flows,)


def test_degenerate_phases_compile_empty(tree):
    s = PhaseSchedule("degen", (
        Phase("solo_a2a", "all_to_all", 1 << 20, 1),     # n=1: no pairs
        Phase("no_bytes", "all_reduce", 0.0, 16),        # no traffic
    ))
    cp = s.compile(tree, 8)
    assert cp.workload.n_packets == 0
    assert cp.workload.n_flows == 0
    assert cp.n_instances == 2


def test_iterations_replicate_phases(tree):
    one = _model_sched(iterations=1)
    two = _model_sched(iterations=2)
    assert two.n_packets(4, 8) == 2 * one.n_packets(4, 8)
    cp = two.compile(tree, 8)
    np.testing.assert_array_equal(cp.iter_of,
                                  np.repeat([0, 1], one.n_phases))


# ---- differential (a): single phase == static path ------------------------

def test_single_phase_equals_static_fast(tree):
    s = PhaseSchedule("a2a1", (Phase("a2a", "all_to_all", 1.0,
                                     tree.n_hosts),))
    assert s._impl_of(s.phases[0], s.plans()[0]) == "xla"
    wl_ph = s.compile(tree, 4).workload
    wl_st = workloads.all_to_all(tree, 4)
    for name in ("flow_ecmp", "host_pkt", "host_dr", "ofan", "jsq"):
        scheme = lbs.by_name(name)
        got = fastsim.simulate(tree, wl_ph, scheme, seed=3)
        ref = fastsim.simulate(tree, wl_st, scheme, seed=3)
        np.testing.assert_array_equal(np.asarray(got.delivery),
                                      np.asarray(ref.delivery), err_msg=name)
        assert got.cct == ref.cct, name


def test_single_phase_equals_static_loop(tree):
    s = PhaseSchedule("a2a1", (Phase("a2a", "all_to_all", 1.0,
                                     tree.n_hosts),))
    wl_ph = s.compile(tree, 4).workload
    wl_st = workloads.all_to_all(tree, 4)
    cfg = loopsim.LoopConfig(max_slots=3000)
    for name in ("host_pkt", "host_pkt_ar", "ofan"):
        scheme = lbs.by_name(name)
        got = loopsim.simulate(tree, wl_ph, scheme, cfg, seed=3)
        ref = loopsim.simulate(tree, wl_st, scheme, cfg, seed=3)
        np.testing.assert_array_equal(got.delivered_slot, ref.delivered_slot,
                                      err_msg=name)
        assert got.cct_slots == ref.cct_slots, name
        assert got.retransmissions == ref.retransmissions, name


def test_loop_phase_gate_respected(tree):
    """No packet of a later phase may deliver before that phase's start
    slot -- the ``f_start`` operand gates host injection."""
    cp = _model_sched().compile(tree, 8)
    wl = cp.workload
    res = loopsim.simulate(tree, wl, lbs.by_name("host_pkt"),
                           loopsim.LoopConfig(max_slots=4000), seed=0)
    assert res.finished
    ds = np.asarray(res.delivered_slot)
    start = np.asarray(wl.flow_start)[np.asarray(wl.flow)]
    assert (ds[ds >= 0] > start[ds >= 0]).all()


def test_fast_phase_release_offsets(tree):
    """Fast engine: per-phase completions are bounded below by the phase's
    release offset (phase offsets ride ``t_release``)."""
    cp = _model_sched().compile(tree, 8)
    res = fastsim.simulate(tree, cp.workload, lbs.by_name("host_pkt"),
                           seed=0)
    d = np.asarray(res.delivery)
    for i in range(cp.n_instances):
        lo, hi = int(cp.pkt_lo[i]), int(cp.pkt_hi[i])
        assert d[lo:hi].min() > cp.phase_start[i]


# ---- differential (b): fused phased campaign == serial --------------------

FLAP = FaultSchedule.flap(layer="ea", pod=0, i=0, j=1, t0=4, period=12,
                          cycles=1, host_react=0, switch_react=0)


def _phased_campaign(engine, sched, **kw):
    return Campaign(name=f"ph_{engine}", schemes=("host_pkt",),
                    loads=(WorkloadSpec("permutation", 4),),
                    trees=(4, 6), seeds=(0, 1), engine=engine,
                    phases=(None, sched), **kw)


def test_fused_phased_campaign_bitwise_fast(tree):
    """Mixed-k campaign with phased AND unphased rows -- plus a
    phases x faults point -- must reproduce serial fastsim bitwise.
    (``gpus_per_server=2`` divides both trees' host counts: 16 and 54.)"""
    sched = PhaseSchedule.from_model("deepseek-v3-671b", ep=8, dp=8,
                                     iterations=1, gpus_per_server=2)
    c = _phased_campaign("fast", sched, failures=(None, FLAP))
    plan = sweep.plan(c)
    assert plan.n_dispatches == plan.n_shapes
    _, full = run_campaign(c, keep_full=True)
    assert len(full) == c.n_points == 16
    for point, res in full.items():
        t = FatTree(point.k)
        wl = (point.phase.compile(t, point.load.msg_packets,
                                  rng_seed=point.load.rng_seed).workload
              if point.phase is not None
              else build_workload(t, point.load))
        ref = fastsim.simulate(t, wl, lbs.by_name(point.scheme),
                               seed=point.seed, fault=point.failure)
        np.testing.assert_array_equal(np.asarray(res.delivery),
                                      np.asarray(ref.delivery))
        assert res.cct == ref.cct


def test_fused_phased_campaign_bitwise_loop():
    sched = PhaseSchedule("mini", (
        Phase("a2a", "all_to_all", 1.0, 16),
        Phase("ring", "all_reduce", 1.0, 16, gap_slots=4),
    ), iterations=2, slack=1.0)
    c = _phased_campaign("loop", sched, max_slots=4000)
    plan = sweep.plan(c)
    assert plan.n_dispatches == plan.n_shapes
    _, full = run_campaign(c, keep_full=True)
    assert len(full) == c.n_points == 8
    for point, res in full.items():
        t = FatTree(point.k)
        wl = (point.phase.compile(t, point.load.msg_packets,
                                  rng_seed=point.load.rng_seed).workload
              if point.phase is not None
              else build_workload(t, point.load))
        ref = loopsim.simulate(t, wl, lbs.by_name(point.scheme),
                               c.loop_config(), seed=point.seed)
        np.testing.assert_array_equal(res.delivered_slot, ref.delivered_slot)
        assert res.cct_slots == ref.cct_slots


# ---- grid integration / records / report ----------------------------------

def test_train_iter_preset_plans_fused():
    c = PRESETS["train_iter"]()
    plan = sweep.plan(c)
    assert plan.n_dispatches == plan.n_shapes
    assert any(b.phase is not None for b in plan.batches)


def test_phase_records_and_summary(tmp_path):
    sched = _model_sched(iterations=2)
    c = Campaign(name="ph_rec", schemes=("host_pkt", "ofan"),
                 loads=(WorkloadSpec("permutation", 4),),
                 trees=(4,), seeds=(0,), phases=(sched,))
    store = ResultStore(tmp_path / "results.jsonl")
    run_campaign(c, store=store)
    store.close()
    assert len(store.records) == 2
    for r in store.records:
        assert r["phases"] == sched.label()
        assert r["n_phases"] == 4 and r["iterations"] == 2
        assert len(r["phase_completion"]) == 8
        assert len(r["iter_makespan"]) == 2
        assert r["iter_time_mean"] == pytest.approx(
            np.mean(r["iter_makespan"]))
        assert all(v >= 0 for v in r["phase_completion"])
    rows = summarize(store.records)
    assert all("iter_time_mean" in row for row in rows)
    rep = render_report([], store.records)
    assert "iteration time" in rep
    assert sched.label() in rep


def test_unphased_records_carry_no_phase_keys():
    c = Campaign(name="plain", schemes=("host_pkt",),
                 loads=(WorkloadSpec("permutation", 4),),
                 trees=(4,), seeds=(0,))
    recs, _ = run_campaign(c)
    for r in recs:
        assert "phases" not in r and "iter_makespan" not in r
        assert "n_phases" not in r and "iter_time_mean" not in r
    row = summarize(recs)[0]
    assert "iter_time_mean" not in row


def test_resume_byte_identical_with_phases(tmp_path):
    """Differential (c): a campaign mixing pre-phase (unphased) and phased
    rows, killed mid-run and resumed, reproduces the uninterrupted file
    byte-for-byte -- the phase fields are only-when-set, so the unphased
    prefix is exactly what a pre-phase producer wrote."""
    sched = PhaseSchedule("mini", (
        Phase("a2a", "all_to_all", 1.0, 16),
        Phase("ring", "all_reduce", 1.0, 16),
    ), slack=1.0)
    c = Campaign(name="ph_resume", schemes=("host_pkt", "ofan"),
                 loads=(WorkloadSpec("permutation", 4),),
                 trees=(4,), seeds=(0, 1), phases=(None, sched))
    a = tmp_path / "a"
    store = ResultStore(a / "results.jsonl")
    run_campaign(c, store=store, compile_cache_dir=False)
    store.close()
    golden = (a / "results.jsonl").read_bytes()
    # unphased rows carry no phase keys: byte-compatible with pre-phase files
    head = json.loads(golden.decode().splitlines()[0])
    assert "phases" not in head

    lines = golden.decode().splitlines(keepends=True)
    cut = len(lines) // 2
    b = tmp_path / "b"
    b.mkdir()
    (b / "results.jsonl").write_text(
        "".join(lines[:cut]) + lines[cut][: len(lines[cut]) // 2])
    store = ResultStore(b / "results.jsonl", overwrite=False)
    run_campaign(c, store=store, compile_cache_dir=False, resume=True)
    store.close()
    assert (b / "results.jsonl").read_bytes() == golden


def test_campaign_dict_roundtrip_with_phases():
    sched = _model_sched()
    c = Campaign(name="rt", schemes=("host_pkt",),
                 loads=(WorkloadSpec("permutation", 4),),
                 trees=(4,), seeds=(0,), phases=(None, sched))
    d = json.loads(json.dumps(c.to_dict()))
    back = Campaign.from_dict(d)
    assert back.phases == (None, sched)
    assert back.n_points == c.n_points == 2
    # all-None phase axis serializes away entirely (pre-phase compat)
    plain = Campaign(name="rt2", schemes=("host_pkt",),
                     loads=(WorkloadSpec("permutation", 4),),
                     trees=(4,), seeds=(0,))
    assert "phases" not in plain.to_dict()
    assert Campaign.from_dict(plain.to_dict()).phases == (None,)
