"""Cost-modeled adaptive planner: pow2_bucket edge cases, kmap cache
canonicalization, policy enumeration/selection invariants, trace
calibration, timing-axis bucketing, and cost-mode end-to-end equivalence."""
import dataclasses
import json

import pytest

from repro.net._batching import k_buckets, pow2_bucket
from repro.net import loopsim
from repro.net.topology import FatTree
from repro.core import lb_schemes as lbs
from repro import sweep
from repro.sweep.costmodel import (BucketPolicy, CostParams, PlanCost,
                                   candidate_policies, choose_policy,
                                   evaluate_policy)
from repro.sweep.planner import _kmap, _kmap_cached
from repro.sweep.runner import build_workload
from repro.obs import TraceWriter


def _campaign(**kw):
    base = dict(name="cm", schemes=("host_pkt", "simple_rr"),
                loads=(sweep.WorkloadSpec("permutation", 32,
                                          inter_pod_only=True),),
                trees=(4,), seeds=(0, 1))
    base.update(kw)
    return sweep.Campaign(**base)


def _mixed_a2a(**kw):
    """The acceptance campaign shape: mixed-k all_to_all, quadratic in
    hosts -- the case the greedy-2x heuristic pads pathologically."""
    base = dict(name="cm_a2a", schemes=("host_pkt", "simple_rr"),
                loads=(sweep.WorkloadSpec("all_to_all", 64),),
                trees=(4, 6, 8), seeds=(0, 1), planner="cost")
    base.update(kw)
    return sweep.Campaign(**base)


# ---------------------------------------------------------------------------
# pow2_bucket edge cases (satellite: n=0 returned 2)
# ---------------------------------------------------------------------------

def test_pow2_bucket_boundaries():
    assert pow2_bucket(0) == 1          # was 2: (-1).bit_length() == 1
    assert pow2_bucket(-3) == 1
    assert pow2_bucket(1) == 1
    assert pow2_bucket(2) == 2
    for m in range(1, 12):
        assert pow2_bucket(2 ** m) == 2 ** m
        assert pow2_bucket(2 ** m + 1) == 2 ** (m + 1)


def test_pow2_bucket_contract():
    for n in range(0, 300):
        b = pow2_bucket(n)
        assert b >= max(n, 1)
        assert b & (b - 1) == 0         # a power of two
        assert b == 1 or b // 2 < max(n, 1)   # the *next* power of two


# ---------------------------------------------------------------------------
# _kmap cache canonicalization (satellite: raw-tuple cache key)
# ---------------------------------------------------------------------------

def test_kmap_canonicalizes_permuted_and_duplicated_trees():
    _kmap_cached.cache_clear()
    a = _kmap((4, 8, 6))
    b = _kmap((8, 6, 4, 4, 8))
    c = _kmap((4, 6, 8))
    assert a == b == c == k_buckets((4, 6, 8))
    assert _kmap_cached.cache_info().currsize == 1


def test_permuted_trees_plan_identically():
    c1 = _campaign(trees=(4, 8))
    c2 = dataclasses.replace(c1, trees=(8, 4, 4))
    p1, p2 = sweep.plan(c1), sweep.plan(c2)
    # grid order follows the campaign's tree order, but the *grouping* --
    # each batch's compiled-pipeline identity -- must canonicalize
    key = lambda p, c: sorted((b.scheme, b.k, b.seeds, b.fused_key(c))
                              for b in p.batches)
    assert key(p1, c1) == key(p2, c2)
    assert p1.n_dispatches == p2.n_dispatches
    assert p1.n_shapes == p2.n_shapes


# ---------------------------------------------------------------------------
# Policy enumeration and selection
# ---------------------------------------------------------------------------

def test_heuristic_is_candidate_zero():
    c = _mixed_a2a()
    cands = candidate_policies(c)
    assert cands[0].label == "greedy2x/pow2"
    assert cands[0].kmap == tuple(sorted(k_buckets(c.trees).items()))
    sigs = {(p.kmap, p.pkt_exact) for p in cands}
    assert len(sigs) == len(cands)      # no duplicate candidates


def test_chosen_policy_never_costs_more_than_heuristic_or_pow2():
    params = CostParams()
    for c in (_campaign(), _mixed_a2a(), _campaign(trees=(4, 6, 8, 10)),
              _campaign(engine="loop", max_slots=3000,
                        loads=(sweep.WorkloadSpec("permutation", 16,
                                                  inter_pod_only=True),))):
        pol, cost, alts = choose_policy(c, params)
        heur = evaluate_policy(c, BucketPolicy.heuristic(c.trees), params)
        assert cost.total <= heur.total
        for _, alt_total, _ in alts:
            assert cost.total <= alt_total


def test_choose_policy_deterministic():
    c = _mixed_a2a()
    choose_policy.cache_clear()
    first = choose_policy(c, CostParams())
    choose_policy.cache_clear()
    second = choose_policy(c, CostParams())
    assert first == second
    # and a byte-equal campaign built separately hits the lru cache
    again = choose_policy(_mixed_a2a(), CostParams())
    assert again == second


def test_cost_plan_splits_mixed_k_all_to_all():
    """The model's reason to exist: mixed-k all_to_all pads quadratically
    under greedy-2x fusion, so the cost plan buys the split."""
    c = _mixed_a2a()
    p_cost = sweep.plan(c)
    p_heur = sweep.plan(dataclasses.replace(c, planner="heuristic"))

    def padded(p):
        return sum(m.n_points * m.npk_pad for m in p.megabatches)

    assert p_cost.policy is not None
    assert padded(p_cost) < padded(p_heur)
    # extra dispatches are bounded by what the compile charge lets it buy
    assert p_cost.cost.total <= evaluate_policy(
        dataclasses.replace(c, planner="heuristic"),
        BucketPolicy.heuristic(c.trees)).total
    assert p_cost.n_dispatches == p_cost.n_shapes


def test_cost_mode_plans_largest_first():
    megas = sweep.plan(_mixed_a2a()).megabatches
    sizes = [m.n_points * m.npk_pad for m in megas]
    assert sizes == sorted(sizes, reverse=True)


@pytest.mark.parametrize("name", sorted(sweep.PRESETS))
def test_presets_cost_mode_one_dispatch_per_shape(name):
    c = dataclasses.replace(sweep.preset(name), planner="cost")
    p = sweep.plan(c)
    assert p.n_dispatches == p.n_shapes
    assert sum(len(b.seeds) for b in p.batches) == c.n_points
    # deterministic given (campaign, calibration)
    q = sweep.plan(dataclasses.replace(sweep.preset(name), planner="cost"))
    assert [(b.scheme, b.k, b.seeds) for b in p.batches] == \
           [(b.scheme, b.k, b.seeds) for b in q.batches]


# ---------------------------------------------------------------------------
# Trace calibration
# ---------------------------------------------------------------------------

def _dispatch_span(i, compile_s, execute_s, padded):
    return {"kind": "dispatch", "dispatch": i, "compile_s": compile_s,
            "execute_s": execute_s, "pkt_rows_padded": padded,
            "pkt_rows_real": padded, "engine": "fast"}


def test_cost_params_from_trace(tmp_path):
    path = tmp_path / "trace.jsonl"
    spans = [{"kind": "plan", "schema": 1},
             _dispatch_span(0, 2.0, 1.0, 1000),
             _dispatch_span(1, 4.0, 3.0, 3000)]
    path.write_text("".join(json.dumps(s) + "\n" for s in spans))
    params = CostParams.from_trace(path)
    # rate = 4s / 4000 rows = 1e-3 s/row; median compile = 4.0s -> 4000 rows
    assert params.compile_rows == pytest.approx(4000.0)
    assert params.source == str(path)


def test_cost_params_from_trace_without_timing_split(tmp_path):
    path = tmp_path / "trace.jsonl"
    spans = [{"kind": "plan"}, {"kind": "dispatch", "dispatch": 0,
                                "wall_s": 1.0, "pkt_rows_padded": 100}]
    path.write_text("".join(json.dumps(s) + "\n" for s in spans))
    params = CostParams.from_trace(path)
    assert params.compile_rows == CostParams().compile_rows
    assert "defaults" in params.source


def test_compile_charge_steers_fusion():
    """A huge compile charge keeps even all_to_all fused; a tiny one splits
    everything it can."""
    c = dataclasses.replace(_mixed_a2a(), planner="heuristic")
    pol_hi, _, _ = choose_policy(c, CostParams(compile_rows=1e11))
    pol_lo, _, _ = choose_policy(c, CostParams(compile_rows=0.0))
    heur = BucketPolicy.heuristic(c.trees)
    assert pol_hi.kmap == heur.kmap
    assert len({pad for _, pad in pol_lo.kmap}) == len(set(c.trees))


# ---------------------------------------------------------------------------
# Timing-axis bucketing (tentpole B)
# ---------------------------------------------------------------------------

def test_timing_pairs_in_same_pow2_bucket_fuse():
    c = _campaign(engine="loop", max_slots=3000,
                  loads=(sweep.WorkloadSpec("permutation", 16,
                                            inter_pod_only=True),),
                  schemes=("jsq",),
                  timings=((9, 33), (12, 40), (3, 5)))
    p = sweep.plan(c)
    # (9,33) and (12,40) share pow2 buckets (16, 64); (3,5) gets (4, 8)
    fused = {tuple(sorted(b.timing for b in m.members))
             for m in p.megabatches}
    assert ((3, 5),) in fused
    assert ((9, 33), (12, 40)) in fused
    assert p.n_dispatches == 2


def test_static_config_buckets_timing_constants():
    cfg = dataclasses.replace(loopsim.LoopConfig(), prop_slots=9,
                              ack_delay=33)
    st = loopsim.static_config(cfg)
    assert st.prop_slots == 16 and st.ack_delay == 64
    other = loopsim.static_config(
        dataclasses.replace(cfg, prop_slots=12, ack_delay=40))
    assert st == other                  # same compiled pipeline identity


def test_timings_validation():
    with pytest.raises(ValueError):
        _campaign(timings=((1, 2),))               # fast engine: loop-only
    with pytest.raises(ValueError):
        _campaign(engine="loop", timings=((-1, 2),))
    with pytest.raises(ValueError):
        _campaign(planner="nope")


def test_campaign_timings_json_roundtrip():
    c = _campaign(engine="loop", max_slots=3000, schemes=("jsq",),
                  timings=((9, 33), None), planner="cost",
                  loads=(sweep.WorkloadSpec("permutation", 16,
                                            inter_pod_only=True),))
    d = json.loads(json.dumps(c.to_dict()))
    c2 = sweep.Campaign.from_dict(d)
    assert c2.timings == c.timings
    assert c2.planner == "cost"
    assert c2 == c


def test_timing_sweep_bitwise_vs_serial_loopsim():
    """Fused timing-sweep dispatches reproduce per-point serial
    loopsim.simulate exactly, including pairs sharing one compile."""
    c = _campaign(engine="loop", max_slots=3000, schemes=("jsq",),
                  seeds=(0, 1),
                  loads=(sweep.WorkloadSpec("permutation", 16,
                                            inter_pod_only=True),),
                  timings=((9, 33), (12, 40)))
    store = sweep.ResultStore(None)
    sweep.run_campaign(c, store=store)
    assert len(store.records) == c.n_points
    tree = FatTree(4)
    for rec in store.records:
        tm = (rec["prop_slots"], rec["ack_delay"])
        pt = next(p for p in c.points()
                  if p.seed == rec["seed"] and p.timing == tm)
        wl = build_workload(tree, pt.load)
        res = loopsim.simulate(tree, wl, lbs.by_name(pt.scheme),
                               c.loop_config(timing=tm), seed=pt.seed,
                               g_converge=pt.g_converge)
        assert rec["cct"] == float(res.cct_slots)
        assert rec["cct_acked"] == float(res.cct_acked_slots)
        assert rec["max_queue"] == float(res.max_queue)
        assert rec["drops"] == int(res.drops)
        assert rec["mean_cwnd"] == float(res.mean_cwnd)


def test_cost_mode_timing_sweep_loop_bitwise_vs_serial():
    """The acceptance shape end-to-end on the slotted engine: a cost-mode
    plan over a timing sweep still reproduces per-point serial simulate
    exactly."""
    c = _campaign(engine="loop", max_slots=3000, schemes=("jsq",),
                  seeds=(0,), planner="cost",
                  loads=(sweep.WorkloadSpec("permutation", 8,
                                            inter_pod_only=True),),
                  timings=((9, 33), (12, 40)))
    p = sweep.plan(c)
    assert p.policy is not None
    assert p.n_dispatches == p.n_shapes
    store = sweep.ResultStore(None)
    sweep.run_campaign(c, store=store)
    tree = FatTree(4)
    wl = build_workload(tree, c.loads[0])
    for rec in store.records:
        tm = (rec["prop_slots"], rec["ack_delay"])
        res = loopsim.simulate(tree, wl, lbs.by_name(rec["scheme"]),
                               c.loop_config(timing=tm), seed=rec["seed"])
        assert rec["cct"] == float(res.cct_slots)
        assert rec["max_queue"] == float(res.max_queue)


def test_timing_axis_off_records_have_no_timing_keys():
    c = _campaign(engine="loop", max_slots=3000, schemes=("jsq",),
                  loads=(sweep.WorkloadSpec("permutation", 16,
                                            inter_pod_only=True),))
    store = sweep.ResultStore(None)
    sweep.run_campaign(c, store=store)
    for rec in store.records:
        assert "prop_slots" not in rec and "ack_delay" not in rec


# ---------------------------------------------------------------------------
# Cost-mode end-to-end: equivalence, trace spans, report
# ---------------------------------------------------------------------------

def test_cost_mode_results_match_heuristic_mode():
    """Planner choice moves rows between dispatches; it must never change
    the physics.  Same campaign under both planners -> same record set."""
    base = _campaign(trees=(4, 6),
                     loads=(sweep.WorkloadSpec("all_to_all", 8),))
    s_h, s_c = sweep.ResultStore(None), sweep.ResultStore(None)
    sweep.run_campaign(base, store=s_h)
    sweep.run_campaign(dataclasses.replace(base, planner="cost"), store=s_c)
    key = lambda r: (r["scheme"], r["k"], r["workload"], r["seed"])
    a = {key(r): sweep.encode_record(r) for r in s_h.records}
    b = {key(r): sweep.encode_record(r) for r in s_c.records}
    assert a == b


def test_cost_mode_trace_spans_and_report(tmp_path):
    c = _mixed_a2a(loads=(sweep.WorkloadSpec("all_to_all", 8),),
                   trees=(4, 6))
    tw = TraceWriter(tmp_path / "trace.jsonl")
    store = sweep.ResultStore(None)
    sweep.run_campaign(c, store=store, trace=tw)
    tw.close()
    spans = sweep.load_trace(tmp_path / "trace.jsonl")
    plan_span = next(s for s in spans if s["kind"] == "plan")
    assert plan_span["planner"] == "cost"
    assert plan_span["policy"]
    assert plan_span["predicted"]["pkt_rows_padded"] > 0
    assert isinstance(plan_span["alternatives"], list)
    end = next(s for s in spans if s["kind"] == "campaign")
    assert end["pkt_rows_real"] <= end["pkt_rows_padded"]
    # predicted padded rows == realized padded rows (model mirrors planner)
    assert plan_span["predicted"]["pkt_rows_padded"] == \
        end["pkt_rows_padded"]
    text = sweep.render_report(spans, store.records)
    assert "cost-modeled policy" in text
    assert "predicted:" in text and "realized:" in text
