"""Dry-run machinery tests that work on 1 device: sharding rule resolution,
HLO collective parsing, roofline math, probe-variant construction.

(The actual 512-device lower+compile sweep runs via
``python -m repro.launch.dryrun --all``; results in experiments/dryrun/.)
"""
import json
import pathlib

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import get_config, SHAPES, applicable_shapes, \
    list_architectures
from repro.models.registry import Model
from repro.models import sharding as sh
from repro.launch import hlo_analysis, roofline


def test_applicable_shapes_per_family():
    assert "long_500k" in applicable_shapes(get_config("mamba2-130m"))
    assert "long_500k" in applicable_shapes(get_config("zamba2-2.7b"))
    assert "long_500k" not in applicable_shapes(get_config("phi4-mini-3.8b"))
    assert "long_500k" not in applicable_shapes(get_config("deepseek-v3-671b"))


def test_all_archs_have_all_cell_specs():
    """Every (arch x applicable shape) produces valid input specs and cache
    shapes with mesh-divisible dims where required."""
    for arch in list_architectures():
        cfg = get_config(arch)
        m = Model(cfg)
        for sname in applicable_shapes(cfg):
            shape = SHAPES[sname]
            specs = m.input_specs(shape)
            assert "tokens" in specs
            assert specs["tokens"].shape[0] == shape.global_batch
            if shape.kind != "train":
                cs = m.cache_shapes(shape.global_batch, shape.seq_len)
                assert jax.tree_util.tree_leaves(cs)


def test_spec_priority_dedup():
    """Two dims resolving to 'model' must not both shard (kv_heads wins)."""
    mesh = jax.make_mesh((1,), ("model",))
    spec = sh.spec_for(("batch", "seq_cache", "kv_heads", None),
                       (4, 32, 8, 16), mesh)
    axes = [a for a in spec if a is not None]
    flat = []
    for a in axes:
        flat.extend(a if isinstance(a, tuple) else (a,))
    assert len(flat) == len(set(flat))


def test_collective_bytes_parser():
    class FakeCompiled:
        def as_text(self):
            return (
                "%ag = bf16[16,128]{1,0} all-gather(%x), replica_groups={}\n"
                "%ar.1 = f32[64]{0} all-reduce-start(%y)\n"
                "%cp = bf16[8,8]{1,0} collective-permute(%z)\n"
                "%dot = f32[4,4]{1,0} dot(%a, %b)\n")
    out = hlo_analysis.collective_bytes(FakeCompiled())
    assert out["count"] == 3
    assert out["by_kind"]["all-gather"] == 16 * 128 * 2
    assert out["by_kind"]["all-reduce"] == 64 * 4
    assert out["by_kind"]["collective-permute"] == 64 * 2


def test_roofline_terms_math():
    cfg = get_config("phi4-mini-3.8b")
    shape = SHAPES["train_4k"]
    rec = {"status": "ok", "mesh": "pod2x16x16", "arch": cfg.name,
           "shape": "train_4k", "kind": "train",
           "flops": 6e13, "bytes_accessed": 3e12,
           "collectives": {"total_bytes": 2.7e9}}
    row = roofline.analyze_record(rec, cfg, shape)
    assert row.chips == 512
    assert row.dominant in ("compute", "memory", "collective")
    assert 0 < row.roofline_fraction <= 1.5
    # 6*N*D sanity: phi4 ~3.8B params -> 6*3.8e9*(256*4096) ~ 2.4e16
    assert 1.5e16 < row.model_flops < 3.5e16


def test_params_count_sane():
    approx = {
        "phi4-mini-3.8b": (3.0e9, 5.5e9),
        "phi3-mini-3.8b": (3.0e9, 4.7e9),
        "yi-6b": (5.5e9, 7.0e9),
        "qwen1.5-4b": (3.0e9, 5.0e9),
        "deepseek-v3-671b": (6.3e11, 7.2e11),
        "qwen3-moe-30b-a3b": (2.6e10, 3.4e10),
        "mamba2-130m": (1.0e8, 1.9e8),
        "whisper-small": (2.0e8, 3.3e8),
        "zamba2-2.7b": (2.2e9, 3.3e9),
        "llava-next-34b": (3.1e10, 3.9e10),
    }
    for arch, (lo, hi) in approx.items():
        n = roofline.params_count(get_config(arch))["total"]
        assert lo <= n <= hi, (arch, n)
    # MoE active << total
    ds = roofline.params_count(get_config("deepseek-v3-671b"))
    assert ds["active"] < 0.1 * ds["total"]


def test_dryrun_artifacts_if_present():
    """When the sweep has produced artifacts, sanity-check them."""
    d = pathlib.Path("experiments/dryrun")
    if not d.exists() or not list(d.glob("*.json")):
        pytest.skip("no dry-run artifacts in this checkout")
    n_ok = n_skip = 0
    for f in d.glob("*.json"):
        rec = json.loads(f.read_text())
        assert rec["status"] in ("ok", "skipped", "fail"), f
        if rec["status"] == "ok":
            n_ok += 1
            assert rec["flops"] > 0
            assert rec["memory"]["peak_estimate_bytes"] > 0
        elif rec["status"] == "skipped":
            n_skip += 1
            assert "long_500k" in rec["shape"]
    assert n_ok > 0
