"""Serving substrate: greedy decode consistency + continuous batching."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import get_config
from repro.models.registry import Model
from repro.serve import serve_step, batching


@pytest.fixture(scope="module")
def small_model():
    model = Model(get_config("phi4-mini-3.8b", smoke=True))
    params = model.init_params(jax.random.PRNGKey(0))
    return model, params


def test_greedy_decode_runs(small_model):
    model, params = small_model
    r = np.random.default_rng(0)
    prompt = jnp.asarray(r.integers(0, model.cfg.vocab, (2, 8)), jnp.int32)
    out = serve_step.greedy_decode(model, params, prompt, n_new=4)
    assert out.shape == (2, 4)
    assert bool((out >= 0).all())


def test_greedy_matches_dense_recompute(small_model):
    """Cached greedy decode must match argmax decoding with full forward
    recomputation each step (cache correctness, multi-step)."""
    model, params = small_model
    r = np.random.default_rng(1)
    prompt = jnp.asarray(r.integers(0, model.cfg.vocab, (1, 6)), jnp.int32)
    cached = np.asarray(serve_step.greedy_decode(model, params, prompt,
                                                 n_new=4))
    toks = prompt
    dense = []
    for _ in range(4):
        logits = model._fwd(params, {"tokens": toks}, mode="train")
        nxt = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        dense.append(int(nxt[0, 0]))
        toks = jnp.concatenate([toks, nxt], axis=1)
    assert cached[0].tolist() == dense


def test_continuous_batching_completes(small_model):
    model, params = small_model
    r = np.random.default_rng(2)
    cb = batching.ContinuousBatcher(model, params, n_slots=2, max_len=32)
    for rid in range(4):
        cb.submit(batching.Request(
            rid=rid,
            prompt=r.integers(0, model.cfg.vocab, (4 + rid,)).astype(np.int32),
            max_new_tokens=3))
    done = cb.run_to_completion(max_ticks=200)
    assert sorted(done) == [0, 1, 2, 3]
    for rq in done.values():
        assert len(rq.out) == 3


def test_batcher_matches_unbatched(small_model):
    """A request decoded through the continuous batcher must produce the
    same tokens as a standalone greedy decode."""
    model, params = small_model
    r = np.random.default_rng(3)
    prompt = r.integers(0, model.cfg.vocab, (5,)).astype(np.int32)
    solo = np.asarray(serve_step.greedy_decode(
        model, params, jnp.asarray(prompt[None]), n_new=3))[0].tolist()
    cb = batching.ContinuousBatcher(model, params, n_slots=2, max_len=32)
    cb.submit(batching.Request(rid=0, prompt=prompt, max_new_tokens=3))
    done = cb.run_to_completion(max_ticks=50)
    assert done[0].out == solo
