"""Counter-based randomness streams (``repro.core.entropy``).

Three layers of guarantees, in order of how the engines depend on them:

  1. **Construction**: the PRF is exactly Threefry-2x32 (known-answer tested
     against JAX's own implementation), and the numpy and jax.numpy
     evaluation paths agree bit-for-bit -- so host-side precompute (fast
     engine) and in-``while_loop`` draws (slotted engine) read one stream.
  2. **Padding invariance** (property-tested): a draw depends only on
     (seed, site, logical id, slot, lane).  Evaluating the stream over a
     padded id range, a padded lane grid, or at a different batch position
     changes NOTHING for the real ids -- this is the invariant that makes
     rand/JSQ schemes cross-tree-size fusable on the loop engine.
  3. **Statistics**: uniformity (chi-square) and cross-site/cross-lane
     independence (correlation), plus an end-to-end distribution-
     equivalence check that the carried-PRNGKey -> counter-stream swap did
     not shift the randomized schemes' paper-facing aggregates (goldens
     recorded from the old generator on a fixed smoke grid).

All draws are deterministic, so every statistical assertion here is
reproducible -- thresholds are standard chi-square critical values at
p = 0.001, checked once at the recorded constants.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                    # pragma: no cover
    from _hyp_fallback import given, settings, st

from repro.core import entropy as ent
from repro.core import lb_schemes as lbs
from repro.net.topology import FatTree
from repro.net import workloads, fastsim, loopsim


# ---------------------------------------------------------------------------
# 1. Construction: Threefry KAT + numpy/jnp agreement.
# ---------------------------------------------------------------------------

def test_threefry_matches_jax_reference():
    """Bit-for-bit agreement with JAX's threefry_2x32 on random keys and
    counters (the module reimplements the permutation against the operator
    set numpy and jnp share)."""
    jprng = pytest.importorskip("jax._src.prng")
    rng = np.random.default_rng(0)
    for _ in range(8):
        k = rng.integers(0, 2**32, 2, dtype=np.uint32)
        c = rng.integers(0, 2**32, 64, dtype=np.uint32)
        ref = jprng.threefry_2x32(k, c)
        x0, x1 = ent.threefry2x32(np.asarray(k[0]), np.asarray(k[1]),
                                  c[:32], c[32:])
        np.testing.assert_array_equal(ref, np.concatenate([x0, x1]))


def test_numpy_and_jnp_paths_agree():
    """The host-side (numpy) and traced (jnp, jitted) evaluations of one
    stream are identical: fast-engine precompute and slotted-engine in-loop
    draws can never diverge."""
    import jax
    import jax.numpy as jnp
    lo, hi = ent.key_words(1234567890123)
    ids = np.arange(257, dtype=np.uint32)
    host = ent.draw_u32(lo, hi, ent.SITE_EDGE_JSQ, ids, 41, lane=3)
    dev = jax.jit(lambda a, b: ent.draw_u32(
        a, b, ent.SITE_EDGE_JSQ, jnp.asarray(ids), 41, lane=3))(lo, hi)
    np.testing.assert_array_equal(host, np.asarray(dev))
    np.testing.assert_array_equal(
        np.asarray(ent.draw_uniform(lo, hi, 7, ids, 5)),
        np.asarray(jax.jit(lambda a, b: ent.draw_uniform(
            a, b, 7, jnp.asarray(ids), 5))(lo, hi)))


def test_key_words_round_trip_64_bit_seeds():
    lo, hi = ent.key_words((37 << 32) | 11)
    assert (int(lo), int(hi)) == (11, 37)
    lo0, hi0 = ent.key_words(11)
    assert (int(lo0), int(hi0)) == (11, 0)
    # Distinct high words must give distinct streams.
    a = ent.draw_u32(lo, hi, 1, np.arange(64, dtype=np.uint32), 0)
    b = ent.draw_u32(lo0, hi0, 1, np.arange(64, dtype=np.uint32), 0)
    assert (a != b).any()


# ---------------------------------------------------------------------------
# 2. Padding invariance (the k-fusion invariant), property-tested.
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=0, max_value=2**63 - 1),
       st.sampled_from((ent.SITE_EDGE_RAND, ent.SITE_AGG_RAND,
                        ent.SITE_EDGE_JSQ, ent.SITE_AGG_JSQ)),
       st.integers(min_value=1, max_value=200),
       st.integers(min_value=1, max_value=4),
       st.integers(min_value=0, max_value=100_000))
def test_draws_are_padding_invariant_by_construction(seed, site, n_ids,
                                                     pad_factor, slot):
    """Same logical ids => same draws at ANY pad width: evaluating the
    stream over a padded id range merely extends it, and the real-id prefix
    is untouched.  This is exactly what happens when a small tree's point
    rides a larger padded tree's compiled engine."""
    lo, hi = ent.key_words(seed)
    ids = np.arange(n_ids, dtype=np.uint32)
    ids_pad = np.arange(n_ids * pad_factor + 3, dtype=np.uint32)
    base = ent.draw_u32(lo, hi, site, ids, slot)
    padded = ent.draw_u32(lo, hi, site, ids_pad, slot)
    np.testing.assert_array_equal(base, padded[:n_ids])
    # Lane grids pad on the lane axis the same way (JSQ port columns).
    g = ent.draw_uniform(lo, hi, site, ids[:, None], slot,
                         lane=np.arange(2, dtype=np.uint32)[None, :])
    g_pad = ent.draw_uniform(lo, hi, site, ids_pad[:, None], slot,
                             lane=np.arange(5, dtype=np.uint32)[None, :])
    np.testing.assert_array_equal(g, g_pad[:n_ids, :2])


def test_draws_are_batch_position_invariant():
    """A row's draws do not depend on where it sits in a fused batch: the
    stream has no carried state, so vmapping it at any batch position gives
    the row's standalone values."""
    import jax
    import jax.numpy as jnp
    seeds = [3, 9, 3, 7]                 # duplicate seed at positions 0 and 2
    los, his = zip(*(ent.key_words(s) for s in seeds))
    ids = jnp.arange(50)
    batched = jax.vmap(lambda a, b: ent.draw_u32(a, b, 2, ids, 17))(
        jnp.asarray(los), jnp.asarray(his))
    for i, s in enumerate(seeds):
        lo, hi = ent.key_words(s)
        np.testing.assert_array_equal(
            np.asarray(batched[i]),
            ent.draw_u32(lo, hi, 2, np.arange(50, dtype=np.uint32), 17))
    np.testing.assert_array_equal(np.asarray(batched[0]),
                                  np.asarray(batched[2]))


def test_uniform_grid_growth_preserves_prefix():
    """Growing any axis of a fast-engine noise grid (JSQ pad-overflow
    retry, megabatch group-wide padding) extends it without perturbing
    existing entries -- unlike the old numpy-generator draw, which reshuffled
    everything on reshape."""
    g = ent.uniform_grid(5, ent.SITE_FAST_AGG_JSQ, 6, 10, 4)
    g_big = ent.uniform_grid(5, ent.SITE_FAST_AGG_JSQ, 9, 25, 8)
    np.testing.assert_array_equal(g, g_big[:6, :10, :4])


# ---------------------------------------------------------------------------
# 3. Statistics: uniformity, independence.
# ---------------------------------------------------------------------------

# Chi-square critical values at p = 0.001.
_CHI2_CRIT = {11: 31.26, 15: 37.70, 63: 103.44}


def _chi2(counts, expected):
    return float(((counts - expected) ** 2 / expected).sum())


def test_randint_uniform_chi_square():
    """draw_int over the engines' label spaces (h*h = 4..64) is uniform."""
    lo, hi = ent.key_words(0)
    for bound, df in ((12, 11), (64, 63)):
        r = np.asarray(ent.draw_int(lo, hi, ent.SITE_EDGE_RAND,
                                    np.arange(1 << 16, dtype=np.uint32), 3,
                                    bound))
        counts = np.bincount(r, minlength=bound)
        assert _chi2(counts, (1 << 16) / bound) < _CHI2_CRIT[df], bound


def test_uniform_chi_square_over_slots_and_ids():
    """Uniformity must hold along BOTH counter axes: fixed slot across ids
    (one engine step) and fixed id across slots (one host's draw history)."""
    lo, hi = ent.key_words(42)
    n = 1 << 15
    by_id = np.asarray(ent.draw_uniform(
        lo, hi, ent.SITE_EDGE_JSQ, np.arange(n, dtype=np.uint32), 9))
    by_slot = np.asarray(ent.draw_uniform(
        lo, hi, ent.SITE_EDGE_JSQ, 9, np.arange(n, dtype=np.uint32)))
    for u in (by_id, by_slot):
        assert 0.0 <= u.min() and u.max() < 1.0
        counts = np.bincount((u * 16).astype(int), minlength=16)
        assert _chi2(counts, n / 16) < _CHI2_CRIT[15]


def test_sites_and_lanes_are_independent():
    """Streams at different draw sites (and different lanes of one site)
    are uncorrelated: adding a consumer can never bias an existing one.
    |Pearson r| < 4/sqrt(n) for uncorrelated uniforms."""
    lo, hi = ent.key_words(7)
    n = 1 << 14
    ids = np.arange(n, dtype=np.uint32)
    streams = [np.asarray(ent.draw_uniform(lo, hi, site, ids, 0))
               for site in (ent.SITE_EDGE_RAND, ent.SITE_AGG_RAND,
                            ent.SITE_EDGE_JSQ, ent.SITE_AGG_JSQ)]
    streams.append(np.asarray(ent.draw_uniform(
        lo, hi, ent.SITE_EDGE_JSQ, ids, 0, lane=1)))
    bound = 4.0 / np.sqrt(n)
    for i in range(len(streams)):
        for j in range(i + 1, len(streams)):
            r = np.corrcoef(streams[i], streams[j])[0, 1]
            assert abs(r) < bound, (i, j, r)
    # ... and seeds decorrelate whole streams too.
    lo2, hi2 = ent.key_words(8)
    other = np.asarray(ent.draw_uniform(lo2, hi2, ent.SITE_EDGE_RAND, ids, 0))
    assert abs(np.corrcoef(streams[0], other)[0, 1]) < bound


# ---------------------------------------------------------------------------
# 4. Distribution equivalence: the generator swap must not shift the
#    randomized schemes' paper-facing aggregates.
# ---------------------------------------------------------------------------

# Goldens recorded from the OLD carried-PRNGKey generator (and, for the fast
# engine, the old per-point numpy noise draw) on the fixed smoke grid below:
# FatTree(4), inter-pod permutation of 8-packet messages (traffic rng_seed
# 1), LoopConfig(max_slots=4000), seeds 0..7.  Aggregates over seeds.
_SMOKE_SEEDS = list(range(8))
_GOLDEN_LOOP = {
    # scheme: (cct_mean, avg_queue_mean, fct_p50, fct_p90, fct_p99)
    "rsq":           (90.50, 4.1037, 88.0, 89.3, 91.0),
    "jsq":           (93.25, 6.4260, 89.0, 92.0, 94.0),
    "switch_pkt_ar": (91.25, 4.8823, 88.0, 91.0, 92.0),
}
_GOLDEN_FAST = {
    # scheme: (cct_mean, max_queue_mean)
    "jsq":           (93.068, 8.277),
    "switch_pkt_ar": (90.878, 3.875),
}


@pytest.fixture(scope="module")
def smoke_grid():
    tree = FatTree(4)
    wl = workloads.permutation(tree, 8, np.random.default_rng(1),
                               inter_pod_only=True)
    return tree, wl


@pytest.mark.parametrize("scheme", sorted(_GOLDEN_LOOP))
def test_loop_distribution_matches_old_generator(smoke_grid, scheme):
    """Counter-stream draws sample the same distribution the old generator
    did: seed-averaged CCT and FCT percentiles within 5%, queue occupancy
    within 15% of the recorded old-generator values (observed deltas are
    well inside: <= 1.1% on CCT/FCT, <= 7% on occupancy)."""
    tree, wl = smoke_grid
    cfg = loopsim.LoopConfig(max_slots=4000)
    res = loopsim.simulate_batch(tree, wl, lbs.by_name(scheme), _SMOKE_SEEDS,
                                 cfg)
    assert all(r.finished for r in res)
    cct = np.mean([r.cct_slots for r in res])
    avgq = np.mean([r.avg_queue for r in res])
    fct = np.concatenate([r.flow_data_done_slot for r in res])
    g_cct, g_avgq, g_p50, g_p90, g_p99 = _GOLDEN_LOOP[scheme]
    assert abs(cct - g_cct) <= 0.05 * g_cct
    assert abs(avgq - g_avgq) <= 0.15 * g_avgq
    for pct, golden in ((50, g_p50), (90, g_p90), (99, g_p99)):
        assert abs(np.percentile(fct, pct) - golden) <= 0.05 * golden, pct


@pytest.mark.parametrize("scheme", sorted(_GOLDEN_FAST))
def test_fast_distribution_matches_old_generator(smoke_grid, scheme):
    """Fast-engine JSQ tie-break noise moved to the same streams; the
    aggregate results must not shift either (CCT within 5%, max queue --
    a noisy order statistic -- within 50%)."""
    tree, wl = smoke_grid
    res = fastsim.simulate_batch(tree, wl, lbs.by_name(scheme), _SMOKE_SEEDS)
    cct = np.mean([r.cct for r in res])
    maxq = np.mean([r.max_queue for r in res])
    g_cct, g_maxq = _GOLDEN_FAST[scheme]
    assert abs(cct - g_cct) <= 0.05 * g_cct
    assert abs(maxq - g_maxq) <= 0.50 * g_maxq
