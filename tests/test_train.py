"""Training substrate: optimizers, data determinism, checkpoint/restart,
fault tolerance, gradient compression."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import get_config
from repro.models.registry import Model
from repro.train import optimizer as opt_mod
from repro.train import data as data_mod
from repro.train import checkpoint as ckpt
from repro.train import train_step as ts
from repro.train import fault_tolerance as ft_mod


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

def _quad_problem(opt, steps=200):
    params = {"w": jnp.asarray([2.0, -3.0, 1.5])}
    state = opt.init(params)
    for _ in range(steps):
        grads = {"w": 2 * params["w"]}       # d/dw ||w||^2
        params, state = opt.update(grads, state, params)
    return float(jnp.abs(params["w"]).max())


def test_adamw_converges_quadratic():
    assert _quad_problem(opt_mod.adamw(lr=0.1, weight_decay=0.0)) < 0.1


def test_adafactor_converges_quadratic():
    assert _quad_problem(opt_mod.adafactor(lr=0.3), steps=400) < 0.2


def test_adafactor_memory_is_factored():
    opt = opt_mod.adafactor()
    params = {"big": jnp.zeros((256, 512)), "small": jnp.zeros((16,))}
    st = opt.init(params)
    assert set(st["acc"]["big"]) == {"vr", "vc"}
    assert st["acc"]["big"]["vr"].shape == (256,)
    assert st["acc"]["big"]["vc"].shape == (512,)
    assert set(st["acc"]["small"]) == {"v"}


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_counter_determinism():
    cfg = data_mod.DataConfig(vocab=100, seq_len=16, global_batch=8, seed=3)
    a = data_mod.batch_for_step(cfg, 7)
    b = data_mod.batch_for_step(cfg, 7)
    np.testing.assert_array_equal(a, b)
    c = data_mod.batch_for_step(cfg, 8)
    assert not np.array_equal(a, c)


def test_data_shard_consistency():
    """Sharded loads must concatenate to the full batch (elastic resharding
    correctness)."""
    cfg = data_mod.DataConfig(vocab=100, seq_len=16, global_batch=8, seed=3)
    full = data_mod.batch_for_step(cfg, 5)
    lo = data_mod.batch_for_step(cfg, 5, 0, 4)
    hi = data_mod.batch_for_step(cfg, 5, 4, 8)
    np.testing.assert_array_equal(full, np.concatenate([lo, hi]))


def test_loader_prefetch(tmp_path):
    cfg = data_mod.DataConfig(vocab=50, seq_len=8, global_batch=4)
    loader = data_mod.Loader(cfg, start_step=3)
    it = iter(loader)
    s0, b0 = next(it)
    s1, b1 = next(it)
    loader.close()
    assert (s0, s1) == (3, 4)
    np.testing.assert_array_equal(b0, data_mod.batch_for_step(cfg, 3))


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def _tree():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.asarray([1, 2, 3], jnp.int32)}}


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(t, str(tmp_path), step=10, extra={"global_step": 10})
    target = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    out, extra = ckpt.restore(str(tmp_path), target)
    assert extra["global_step"] == 10
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)), t, out)


def test_checkpoint_atomic_commit(tmp_path):
    t = _tree()
    p = ckpt.save(t, str(tmp_path), step=1)
    # corrupt a not-committed directory: must be invisible
    bad = tmp_path / "step_00000002"
    bad.mkdir()
    assert ckpt.latest_step(str(tmp_path)) == 1


def test_checkpoint_integrity_check(tmp_path):
    t = _tree()
    p = ckpt.save(t, str(tmp_path), step=1)
    # corrupt a tensor
    import pathlib
    f = sorted(pathlib.Path(p).glob("arr_*.npy"))[0]
    arr = np.load(f)
    arr = arr + 1
    np.save(f, arr)
    target = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    with pytest.raises(IOError):
        ckpt.restore(str(tmp_path), target)


def test_checkpoint_retention(tmp_path):
    t = _tree()
    for s in range(6):
        ckpt.save(t, str(tmp_path), step=s, keep_last=2)
    steps = [d.name for d in tmp_path.iterdir() if d.name.startswith("step_")]
    assert len(steps) == 2


def test_async_checkpointer(tmp_path):
    t = _tree()
    ac = ckpt.AsyncCheckpointer(str(tmp_path))
    ac.save(t, 5)
    path = ac.wait()
    assert path and ckpt.latest_step(str(tmp_path)) == 5


# ---------------------------------------------------------------------------
# fault-tolerant loop: checkpoint/restart resume, retry, straggler
# ---------------------------------------------------------------------------

def _toy_step():
    def step(state, batch):
        new = {"w": state["w"] + batch["x"].sum(),
               "step": state["step"] + 1}
        return new, {"loss": jnp.float32(1.0) / (new["step"] + 1)}
    return step


def test_resilient_loop_restart_resumes(tmp_path):
    ftc = ft_mod.FTConfig(ckpt_dir=str(tmp_path), ckpt_every=5,
                          max_retries=0)
    state0 = {"w": jnp.float32(0.0), "step": jnp.int32(0)}
    batches = lambda s: {"x": jnp.asarray([float(s)])}

    loop = ft_mod.ResilientLoop(_toy_step(), state0, ftc)
    loop.run(batches, 7)
    # simulate crash + restart: new loop restores at step 5 then finishes
    loop2 = ft_mod.ResilientLoop(_toy_step(), state0, ftc)
    assert loop2.start_step in (5, 7)
    final = loop2.run(batches, 10)
    assert int(final["step"]) == 10
    # bit-exact: w == sum of 0..9
    assert float(final["w"]) == sum(range(10))


def test_resilient_loop_retries_transient_failure(tmp_path):
    calls = {"n": 0}

    def flaky(state, batch):
        calls["n"] += 1
        if calls["n"] == 2:
            raise RuntimeError("simulated fabric fault")
        return state, {"loss": jnp.float32(1.0)}

    ftc = ft_mod.FTConfig(ckpt_dir=str(tmp_path), ckpt_every=100,
                          max_retries=2, backoff_s=0.01)
    loop = ft_mod.ResilientLoop(
        flaky, {"w": jnp.float32(0)}, ftc)
    loop.run(lambda s: {"x": jnp.zeros(1)}, 3)
    assert calls["n"] >= 4      # 3 steps + 1 retry


def test_straggler_detection():
    ftc = ft_mod.FTConfig()
    sm = ft_mod.StragglerMitigator(ftc)
    for _ in range(10):
        assert not sm.record(0.1)
    assert sm.record(1.0)        # 10x p50 -> straggler


# ---------------------------------------------------------------------------
# microbatched train step == single-batch train step
# ---------------------------------------------------------------------------

def test_grad_accumulation_consistency():
    model = Model(get_config("phi4-mini-3.8b", smoke=True))
    params = model.init_params(jax.random.PRNGKey(0))
    r = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        r.integers(0, model.cfg.vocab, (4, 16)), jnp.int32)}
    outs = {}
    for mb in (1, 2):
        tcfg = ts.TrainConfig(learning_rate=1e-3, microbatch=mb)
        state = ts.make_train_state(model, params, tcfg)
        step = jax.jit(ts.build_train_step(model, tcfg))
        new_state, metrics = step(state, batch)
        outs[mb] = (float(metrics["loss"]),
                    np.asarray(jax.tree_util.tree_leaves(
                        new_state["params"])[0], np.float32))
    assert abs(outs[1][0] - outs[2][0]) < 2e-3
    np.testing.assert_allclose(outs[1][1], outs[2][1], atol=2e-3, rtol=2e-2)
