"""Fat-tree topology invariants (unit + hypothesis property tests)."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:            # fall back to a deterministic sample sweep
    from _hyp_fallback import given, settings, st

from repro.net.topology import (FatTree, LinkState, rho_max, BYPASS,
                                UP_E, UP_A, DN_C, DN_A, DN_E)
from repro.net import workloads


Ks = st.sampled_from([4, 6, 8])


@given(Ks)
@settings(max_examples=10, deadline=None)
def test_counts(k):
    t = FatTree(k)
    assert t.n_hosts == k ** 3 // 4
    assert t.n_cores == (k // 2) ** 2
    assert t.n_edge_switches == t.n_agg_switches == k * k // 2


@given(Ks, st.integers(0, 10_000))
@settings(max_examples=50, deadline=None)
def test_host_coords_roundtrip(k, seed):
    t = FatTree(k)
    h = seed % t.n_hosts
    assert t.host_id(t.host_pod(h), t.host_edge(h), t.host_slot(h)) == h


def test_stage_queues_interpod():
    t = FatTree(4)
    # host 0 (pod0,e0,s0) -> host 15 (pod3,e1,s1), choice a=1, c=0
    q = t.stage_queues(np.array([0]), np.array([15]),
                       np.array([1]), np.array([0]))[0]
    assert q[UP_E] == t.qid_up_e(0, 0, 1)
    assert q[UP_A] == t.qid_up_a(0, 1, 0)
    assert q[DN_C] == t.qid_dn_c(3, 1, 0)
    assert q[DN_A] == t.qid_dn_a(3, 1, 1)
    assert q[DN_E] == 15


def test_stage_queues_intrapod_and_same_edge():
    t = FatTree(4)
    # same pod (pod0: hosts 0..3), different edge: 0 -> 2
    q = t.stage_queues(np.array([0]), np.array([2]),
                       np.array([0]), np.array([1]))[0]
    assert q[UP_A] == BYPASS and q[DN_C] == BYPASS
    assert q[UP_E] >= 0 and q[DN_A] >= 0 and q[DN_E] == 2
    # same edge: 0 -> 1
    q = t.stage_queues(np.array([0]), np.array([1]),
                       np.array([0]), np.array([0]))[0]
    assert all(q[i] == BYPASS for i in (UP_E, UP_A, DN_C, DN_A))
    assert q[DN_E] == 1


def test_mandatory_waypoint_property():
    """Fat-tree: traffic entering core group a can only exit through agg a
    of the destination pod -- encoded by stage_queues using the same a."""
    t = FatTree(8)
    rngl = np.random.default_rng(3)
    src = rngl.integers(0, t.n_hosts, 100)
    dst = (src + t.hosts_per_pod) % t.n_hosts   # force inter-pod
    a = rngl.integers(0, t.half, 100)
    c = rngl.integers(0, t.half, 100)
    q = t.stage_queues(src, dst, a, c)
    # DN_C queue index encodes (dst_pod, a, c): the same a as UP_A
    dn = q[:, DN_C] - t.host_pod(dst) * t.half * t.half
    assert ((dn // t.half) == a).all()


def test_wecmp_weights_no_failures():
    t = FatTree(4)
    links = LinkState.all_up(t)
    w = links.wecmp_edge_weights(0, 0, 1, 1)
    assert (w == t.half).all()      # k/2 cores per agg pair
    wa = links.wecmp_agg_weights(0, 1, 2)
    assert (wa == 1).all()


def test_wecmp_weights_with_failure():
    t = FatTree(4)
    links = LinkState.all_up(t)
    links.ac[0, 0, 0] = False       # kill agg0-core(0,0) in pod 0
    w = links.wecmp_edge_weights(0, 0, 1, 0)
    assert w[0] == t.half - 1       # one fewer path via agg 0
    assert w[1] == t.half


def test_rho_max_no_failure_permutation():
    t = FatTree(4)
    links = LinkState.all_up(t)
    wl = workloads.permutation(t, 4, np.random.default_rng(0))
    assert rho_max(t, links, wl.flow_src, wl.flow_dst) == 1.0


def test_rho_max_with_failures_reduced():
    t = FatTree(4)
    rngl = np.random.default_rng(1)
    links = LinkState.random_failures(t, 0.3, rngl)
    wl = workloads.permutation(t, 4, np.random.default_rng(0),
                               inter_pod_only=True)
    r = rho_max(t, links, wl.flow_src, wl.flow_dst)
    assert 0.0 <= r <= 1.0


@given(st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_inter_pod_permutation_property(seed):
    t = FatTree(8)
    wl = workloads.permutation(t, 1, np.random.default_rng(seed),
                               inter_pod_only=True)
    pods_src = t.host_pod(wl.flow_src)
    pods_dst = t.host_pod(wl.flow_dst)
    assert (pods_src != pods_dst).all()
    # permutation: every host sends once and receives once
    assert len(np.unique(wl.flow_dst)) == t.n_hosts


def test_workload_release_pacing():
    """Hosts emit exactly one packet per slot (line rate)."""
    t = FatTree(4)
    wl = workloads.all_to_all(t, 4)
    for h in range(t.n_hosts):
        rel = np.sort(wl.t_release[wl.src == h])
        assert np.array_equal(rel, np.arange(len(rel)))


def test_fsdp_rings_structure():
    t = FatTree(8)
    wl = workloads.fsdp_rings(t, 8, 16, np.random.default_rng(0))
    assert wl.n_flows == t.n_hosts
    # every host sends exactly one flow and receives exactly one
    assert len(np.unique(wl.flow_src)) == t.n_hosts
    assert len(np.unique(wl.flow_dst)) == t.n_hosts
