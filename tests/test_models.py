"""Per-architecture smoke tests (reduced configs): forward/train step on CPU
with shape checks + no NaNs, prefill/decode consistency, and family-specific
invariants."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import list_architectures, get_config, SHAPES
from repro.models.registry import Model
from repro.train import train_step as ts
from repro.train import optimizer as opt_mod

ARCHS = list_architectures()


def _batch_for(cfg, B=2, S=16, seed=0):
    r = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(
        r.integers(0, cfg.vocab, (B, S)), jnp.int32)}
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            r.normal(size=(B, cfg.n_frontend_tokens, cfg.frontend_dim)),
            jnp.float32)
    if cfg.family == "vlm":
        batch["vision_embeds"] = jnp.asarray(
            r.normal(size=(B, 4, cfg.frontend_dim)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    """Assignment requirement: reduced config, one forward/train step on
    CPU, asserting output shapes and no NaNs."""
    model = Model(get_config(arch, smoke=True))
    cfg = model.cfg
    params = model.init_params(jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = _batch_for(cfg, B, S)
    loss = model.loss(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), arch

    tcfg = ts.TrainConfig(learning_rate=1e-3, microbatch=1)
    state = ts.make_train_state(model, params, tcfg)
    step = jax.jit(ts.build_train_step(model, tcfg))
    state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually changed
    moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        state["params"], params)
    assert max(jax.tree_util.tree_leaves(moved)) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_full_forward(arch):
    """Prefill + decode over a cache must reproduce the densely-computed
    next-token logits (KV-cache correctness)."""
    model = Model(get_config(arch, smoke=True))
    cfg = model.cfg
    params = model.init_params(jax.random.PRNGKey(1))
    B, S = 2, 12
    batch = _batch_for(cfg, B, S, seed=1)
    n_front = 0
    if cfg.family == "vlm":
        n_front = batch["vision_embeds"].shape[1]

    # dense forward logits at position S-1
    logits_full = model._fwd(params, batch, mode="train")
    last_full = logits_full[:, -1]

    cache = jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype),
        model.cache_shapes(B, S + n_front + 4))
    logits_pre, cache = model.prefill(params, batch, cache)
    last_pre = logits_pre[:, -1]
    np.testing.assert_allclose(np.asarray(last_full), np.asarray(last_pre),
                               atol=2e-2, rtol=2e-2)

    # decode one token; then compare against dense forward of S+1 tokens
    tok = jnp.argmax(last_pre, -1).astype(jnp.int32)[:, None]
    logits_dec, cache = model.decode_step(params, tok, cache, S + n_front)
    batch2 = dict(batch)
    batch2["tokens"] = jnp.concatenate([batch["tokens"], tok], axis=1)
    logits_full2 = model._fwd(params, batch2, mode="train")
    np.testing.assert_allclose(np.asarray(logits_dec[:, -1]),
                               np.asarray(logits_full2[:, -1]),
                               atol=2e-2, rtol=2e-2)


def test_moe_dense_vs_a2a_paths_smoke():
    """On a 1-device 'mesh' the a2a path degenerates; verify the dense
    oracle path is used and is deterministic."""
    model = Model(get_config("qwen3-moe-30b-a3b", smoke=True))
    params = model.init_params(jax.random.PRNGKey(0))
    batch = _batch_for(model.cfg, 2, 16)
    l1 = model.loss(params, batch)
    l2 = model.loss(params, batch)
    assert float(l1) == float(l2)


def test_mamba_decode_state_propagates():
    """SSM decode must depend on prefix state (not just the last token)."""
    model = Model(get_config("mamba2-130m", smoke=True))
    cfg = model.cfg
    params = model.init_params(jax.random.PRNGKey(2))
    B, S = 1, 12
    r = np.random.default_rng(0)
    t1 = jnp.asarray(r.integers(0, cfg.vocab, (B, S)), jnp.int32)
    t2 = t1.at[:, 0].set((t1[0, 0] + 1) % cfg.vocab)   # differ at position 0
    outs = []
    for toks in (t1, t2):
        cache = jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype), model.cache_shapes(B, S))
        _, cache = model.prefill(params, {"tokens": toks}, cache)
        logits, _ = model.decode_step(params, toks[:, -1:], cache, S)
        outs.append(np.asarray(logits))
    assert np.abs(outs[0] - outs[1]).max() > 1e-6


def test_training_reduces_loss_small_lm():
    """End-to-end sanity: a tiny dense LM learns the synthetic ngram data."""
    from repro.train import data as data_mod
    model = Model(get_config("phi4-mini-3.8b", smoke=True))
    cfg = model.cfg
    params = model.init_params(jax.random.PRNGKey(3))
    tcfg = ts.TrainConfig(learning_rate=3e-3, microbatch=1)
    state = ts.make_train_state(model, params, tcfg)
    step = jax.jit(ts.build_train_step(model, tcfg))
    dcfg = data_mod.DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8,
                               seed=0)
    losses = []
    for i in range(30):
        batch = {"tokens": jnp.asarray(data_mod.batch_for_step(dcfg, i))}
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.2, losses[:3] + losses[-3:]
