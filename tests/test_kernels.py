"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracles,
swept over shapes/dtypes, plus hypothesis property tests."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:            # fall back to a deterministic sample sweep
    from _hyp_fallback import given, settings, st

from repro.kernels.lindley import kernel as lk, ref as lr, ops as lo
from repro.kernels.flash_attn import kernel as fk, ref as fr, ops as fo
from repro.kernels.ssd_scan import kernel as sk, ref as sr, ops as so


# ---------------------------------------------------------------------------
# lindley segmented max-plus scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 7, 256, 1000, 4096])
@pytest.mark.parametrize("block", [128, 1024])
def test_lindley_kernel_matches_oracle(n, block, rng):
    v = rng.normal(size=n).astype(np.float32) * 100
    f = rng.random(n) < 0.15
    f[0] = True
    out_k = np.asarray(lk.segmented_cummax(jnp.asarray(v), jnp.asarray(f),
                                           block=block))
    out_r = np.asarray(lr.segmented_cummax(jnp.asarray(v), jnp.asarray(f)))
    np.testing.assert_allclose(out_k, out_r)


@given(st.integers(1, 300), st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_lindley_property_vs_serial(n, seed):
    r = np.random.default_rng(seed)
    v = r.normal(size=n).astype(np.float32)
    f = r.random(n) < 0.3
    f[0] = True
    out = np.asarray(lr.segmented_cummax(jnp.asarray(v), jnp.asarray(f)))
    ser = lr.segmented_cummax_serial(v, f)
    np.testing.assert_allclose(out, ser)


def test_lindley_departures_are_fifo_and_causal(rng):
    """Property: departures are strictly increasing within a queue and never
    precede arrival + service."""
    n = 500
    a = np.sort(rng.uniform(0, 100, n)).astype(np.float32)
    seg = np.zeros(n, bool)
    seg[0] = True
    seg[rng.choice(np.arange(1, n), 20, replace=False)] = True
    d = np.asarray(lo.lindley_departures(jnp.asarray(a), jnp.asarray(seg)))
    start = 0
    for i in range(1, n + 1):
        if i == n or seg[i]:
            dd = d[start:i]
            aa = a[start:i]
            assert (np.diff(dd) >= 1.0 - 1e-3).all()     # 1 pkt/slot service
            assert (dd >= aa + 1.0 - 1e-3).all()          # causality (f32)
            start = i


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

SHAPES = [
    (1, 4, 2, 128, 128, 64),
    (2, 8, 8, 256, 256, 64),
    (1, 8, 1, 128, 128, 128),
    (1, 4, 4, 1, 256, 64),      # decode
    (2, 6, 2, 64, 256, 32),     # Sq < Sk (query tail)
]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(shape, dtype, rng):
    B, Hq, Hkv, Sq, Sk, D = shape
    q = jnp.asarray(rng.normal(size=(B, Hq, Sq, D)), dtype)
    k = jnp.asarray(rng.normal(size=(B, Hkv, Sk, D)), dtype)
    v = jnp.asarray(rng.normal(size=(B, Hkv, Sk, D)), dtype)
    out_k = fk.flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    out_r = fr.mha(q, k, v, causal=True)
    tol = 2e-5 if dtype == np.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32),
                               atol=tol, rtol=tol)


def test_chunked_matches_full(rng):
    B, Hq, Hkv, S, D = 1, 4, 2, 512, 64
    q = jnp.asarray(rng.normal(size=(B, Hq, S, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Hkv, S, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Hkv, S, D)), jnp.float32)
    full = fr.mha(q, k, v, causal=True)
    chunk = fr.mha_chunked(q, k, v, causal=True, block_k=128)
    np.testing.assert_allclose(np.asarray(full), np.asarray(chunk),
                               atol=2e-5, rtol=2e-5)


def test_chunked_mixed_dims(rng):
    """MLA shape: d_k=48, d_v=32."""
    q = jnp.asarray(rng.normal(size=(1, 4, 64, 48)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 4, 64, 48)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 4, 64, 32)), jnp.float32)
    out = fr.mha_chunked(q, k, v, causal=True, block_k=32)
    # oracle: dense softmax
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) / np.sqrt(48)
    mask = jnp.tril(jnp.ones((64, 64), bool))
    logits = jnp.where(mask[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, -1)
    ref = jnp.einsum("bhqk,bhkd->bhqd", p, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


@given(st.integers(1, 4), st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_attention_rowsum_property(heads, seed):
    """Attention outputs are convex combinations of V rows: with identical V
    rows the output equals that row (softmax sums to 1)."""
    r = np.random.default_rng(seed)
    q = jnp.asarray(r.normal(size=(1, heads, 32, 16)), jnp.float32)
    k = jnp.asarray(r.normal(size=(1, heads, 32, 16)), jnp.float32)
    row = r.normal(size=(16,)).astype(np.float32)
    v = jnp.broadcast_to(jnp.asarray(row), (1, heads, 32, 16))
    out = fr.mha(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.broadcast_to(row, out.shape),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# Mamba2 SSD scan
# ---------------------------------------------------------------------------

SSD_SHAPES = [
    (1, 64, 2, 16, 1, 16),
    (2, 128, 4, 32, 2, 64),
    (1, 96, 8, 64, 4, 32),    # L not multiple of 64 (ops pads)
]


@pytest.mark.parametrize("shape", SSD_SHAPES)
def test_ssd_kernel_and_chunked_match_scan(shape, rng):
    B, L, H, P, G, N = shape
    x = jnp.asarray(rng.normal(size=(B, L, H, P)), jnp.float32)
    dt = jnp.asarray(0.01 + rng.random((B, L, H)) * 0.2, jnp.float32)
    A = jnp.asarray(-0.5 - rng.random(H), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B, L, G, N)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(B, L, G, N)), jnp.float32)
    oracle = np.asarray(sr.ssd_scan(x, dt, A, Bm, C))
    chunked = np.asarray(so.ssd(x, dt, A, Bm, C, chunk=32,
                                backend="chunked"))
    np.testing.assert_allclose(chunked, oracle, atol=5e-5, rtol=5e-4)
    if L % 32 == 0:
        pallas = np.asarray(sk.ssd_scan(x, dt, A, Bm, C, chunk=32))
        np.testing.assert_allclose(pallas, oracle, atol=5e-5, rtol=5e-4)


def test_ssd_final_state_matches_sequential(rng):
    B, L, H, P, G, N = 1, 48, 2, 8, 1, 8
    x = jnp.asarray(rng.normal(size=(B, L, H, P)), jnp.float32)
    dt = jnp.asarray(0.05 + rng.random((B, L, H)) * 0.1, jnp.float32)
    A = jnp.asarray(-1.0 - rng.random(H), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B, L, G, N)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(B, L, G, N)), jnp.float32)
    hf = np.asarray(sr.ssd_final_state(x, dt, A, Bm, C, chunk=16))
    # sequential oracle
    h = np.zeros((B, H, N, P), np.float32)
    xn, dtn, An = map(np.asarray, (x, dt, A))
    Bn = np.repeat(np.asarray(Bm), H // G, axis=2)
    for t in range(L):
        for b in range(B):
            for hh in range(H):
                h[b, hh] = (np.exp(An[hh] * dtn[b, t, hh]) * h[b, hh]
                            + dtn[b, t, hh]
                            * np.outer(Bn[b, t, hh], xn[b, t, hh]))
    np.testing.assert_allclose(hf, h, atol=1e-4, rtol=1e-3)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_ssd_decay_property(seed):
    """With A -> -inf (instant forgetting) the SSD reduces to the per-step
    readout C_t . (dt_t B_t x_t^T)."""
    r = np.random.default_rng(seed)
    B, L, H, P, G, N = 1, 16, 1, 4, 1, 4
    x = jnp.asarray(r.normal(size=(B, L, H, P)), jnp.float32)
    dt = jnp.asarray(np.full((B, L, H), 1.0), jnp.float32)
    A = jnp.asarray([-50.0], jnp.float32)
    Bm = jnp.asarray(r.normal(size=(B, L, G, N)), jnp.float32)
    C = jnp.asarray(r.normal(size=(B, L, G, N)), jnp.float32)
    y = np.asarray(sr.ssd_scan(x, dt, A, Bm, C))
    expect = np.einsum("blgn,blgn,blhp->blhp",
                       np.asarray(C), np.asarray(Bm), np.asarray(x))
    np.testing.assert_allclose(y, expect, atol=1e-4, rtol=1e-3)


# ---------------------------------------------------------------------------
# slot-step kernels (JSQ port-rank + enqueue, SACK scoreboard scans)
# ---------------------------------------------------------------------------

import functools  # noqa: E402

from repro.core import entropy as ent  # noqa: E402
from repro.kernels.slot_step import (  # noqa: E402
    kernel as qk, ref as qr, ops as qo)

_Q = dict(m=24, h=8, nq=48, cap=8, f=12, per_flow=16, off1=8, n_aggs=4)


def _slot_operands(seed, m=_Q["m"], h=_Q["h"], nq=_Q["nq"], cap=_Q["cap"],
                   f=_Q["f"], per_flow=_Q["per_flow"]):
    """Random engine-shaped operands for one slot step."""
    r = np.random.default_rng(seed)
    p = f * per_flow
    o = dict(
        qcnt=jnp.asarray(r.integers(0, cap, nq), jnp.int32),
        qbuf=jnp.asarray(r.integers(-1, p, (nq, cap)), jnp.int32),
        qhead=jnp.asarray(r.integers(0, cap, nq), jnp.int32),
        qbase=jnp.asarray(r.integers(0, nq - h, m), jnp.int32),
        ids=jnp.asarray(r.integers(0, p, m), jnp.int32),
        dead=jnp.asarray(r.random((m, h)) < 0.2),
        pad_pen=jnp.where(jnp.arange(h) < h - 2, 0.0,
                          1e9).astype(jnp.float32),
        alive=jnp.asarray(r.random(nq) < 0.9),
        apk=jnp.asarray(np.where(r.random(m) < 0.8,
                                 r.integers(0, p, m), -1), jnp.int32),
        aq=jnp.asarray(r.integers(0, nq, m), jnp.int32),
        asw=jnp.asarray(r.integers(0, _Q["n_aggs"], m), jnp.int32),
        p_recv=jnp.asarray(r.random(p) < 0.5),
        pk=jnp.asarray(r.integers(0, p, m), jnp.int32),
        deliv=jnp.asarray(r.random(m) < 0.5),
        f_cum=jnp.asarray(r.integers(0, per_flow, f), jnp.int32),
        fsize=jnp.full((f,), per_flow, jnp.int32),
        pbase=jnp.arange(f, dtype=jnp.int32) * per_flow,
        seed_lo=jnp.uint32(r.integers(0, 2**32)),
        seed_hi=jnp.uint32(r.integers(0, 2**32)),
        t=jnp.int32(r.integers(0, 4000)),
    )
    o["avalid"] = o["apk"] >= 0
    o["to_agg"] = o["avalid"] & (r.random(m) < 0.5)
    # aq of agg-bound lanes is rewritten by the pick; keep others in range
    return o


def _jsq_args(o):
    return (o["qcnt"], o["qbase"], o["ids"], o["dead"], o["pad_pen"],
            o["seed_lo"], o["seed_hi"], o["t"])


@pytest.mark.parametrize("quanta", [None, (0.05, 0.10, 0.20)])
@pytest.mark.parametrize("block", [None, 7, 16])
def test_slot_jsq_pick_matches_ref(quanta, block):
    """Interpret-mode JSQ pick is bitwise the oracle, including tile tails
    that don't divide the chooser count (block=7 over 24 lanes pads)."""
    o = _slot_operands(1)
    kw = dict(site=ent.SITE_EDGE_JSQ, quanta=quanta, cap=_Q["cap"])
    got = qk.jsq_pick(*_jsq_args(o), block=block, interpret=True, **kw)
    want = qr.jsq_pick(*_jsq_args(o), **kw)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_slot_jsq_padded_ports_never_picked():
    """port_pad_penalty masking: lanes past the real port count carry a 1e9
    penalty, so no pick may land there (unless every port is padded)."""
    o = _slot_operands(2)
    o["dead"] = jnp.zeros_like(o["dead"])     # only the pad penalty acts
    kw = dict(site=ent.SITE_EDGE_JSQ, quanta=None, cap=_Q["cap"])
    for backend in ("xla", "pallas"):
        pick = qo.jsq_pick(*_jsq_args(o), backend=backend, **kw)
        assert (np.asarray(pick) < _Q["h"] - 2).all(), backend


def test_slot_enqueue_matches_ref():
    o = _slot_operands(3)
    kw = dict(cap=_Q["cap"], ecn_thresh=5)
    got = qk.enqueue(o["qbuf"], o["qhead"], o["qcnt"], o["alive"],
                     o["apk"], o["aq"], o["avalid"], interpret=True, **kw)
    want = qr.enqueue(o["qbuf"], o["qhead"], o["qcnt"], o["alive"],
                      o["apk"], o["aq"], o["avalid"], **kw)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


@pytest.mark.parametrize("quanta", [None, (0.05, 0.10, 0.20)])
def test_slot_agg_jsq_enqueue_matches_ref(quanta):
    o = _slot_operands(4)
    kw = dict(site=ent.SITE_AGG_JSQ, quanta=quanta, cap=_Q["cap"],
              ecn_thresh=5, off1=_Q["off1"], h=_Q["h"])
    args = (o["qbuf"], o["qhead"], o["qcnt"], o["alive"], o["apk"],
            o["aq"], o["to_agg"], o["asw"], o["dead"], o["pad_pen"],
            o["seed_lo"], o["seed_hi"], o["t"])
    got = qk.agg_jsq_enqueue(*args, interpret=True, **kw)
    want = qr.agg_jsq_enqueue(*args, **kw)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_slot_sack_scans_match_ref():
    o = _slot_operands(5)
    got = qk.sack_update_scan(o["p_recv"], o["pk"], o["deliv"], o["f_cum"],
                              o["fsize"], o["pbase"], interpret=True)
    want = qr.sack_update_scan(o["p_recv"], o["pk"], o["deliv"], o["f_cum"],
                               o["fsize"], o["pbase"])
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    ga = qk.sack_advance(o["p_recv"], o["f_cum"], o["fsize"], o["pbase"],
                         interpret=True)
    wa = qr.sack_advance(o["p_recv"], o["f_cum"], o["fsize"], o["pbase"])
    np.testing.assert_array_equal(np.asarray(ga), np.asarray(wa))


def test_slot_kernels_campaign_batch_dim():
    """The fused campaign axis rides vmap's leading batch dim (>= 2 rows):
    batched kernel outputs equal the per-row oracle row-for-row."""
    rows = [_slot_operands(10 + i) for i in range(3)]
    stack = {k: jnp.stack([o[k] for o in rows]) for k in rows[0]}
    kw = dict(site=ent.SITE_EDGE_JSQ, quanta=None, cap=_Q["cap"])
    k_fn = jax.vmap(functools.partial(qk.jsq_pick, interpret=True, **kw))
    picks = k_fn(stack["qcnt"], stack["qbase"], stack["ids"], stack["dead"],
                 stack["pad_pen"], stack["seed_lo"], stack["seed_hi"],
                 stack["t"])
    for i, o in enumerate(rows):
        np.testing.assert_array_equal(np.asarray(picks[i]),
                                      np.asarray(qr.jsq_pick(*_jsq_args(o),
                                                             **kw)))
    e_fn = jax.vmap(functools.partial(qk.enqueue, cap=_Q["cap"],
                                      ecn_thresh=5, interpret=True))
    outs = e_fn(stack["qbuf"], stack["qhead"], stack["qcnt"], stack["alive"],
                stack["apk"], stack["aq"], stack["avalid"])
    for i, o in enumerate(rows):
        want = qr.enqueue(o["qbuf"], o["qhead"], o["qcnt"], o["alive"],
                          o["apk"], o["aq"], o["avalid"], cap=_Q["cap"],
                          ecn_thresh=5)
        for g, w in zip(outs, want):
            np.testing.assert_array_equal(np.asarray(g[i]), np.asarray(w))
    s_fn = jax.vmap(functools.partial(qk.sack_update_scan, interpret=True))
    prec, fm = s_fn(stack["p_recv"], stack["pk"], stack["deliv"],
                    stack["f_cum"], stack["fsize"], stack["pbase"])
    for i, o in enumerate(rows):
        wr, wf = qr.sack_update_scan(o["p_recv"], o["pk"], o["deliv"],
                                     o["f_cum"], o["fsize"], o["pbase"])
        np.testing.assert_array_equal(np.asarray(prec[i]), np.asarray(wr))
        np.testing.assert_array_equal(np.asarray(fm[i]), np.asarray(wf))


def test_slot_ops_backend_switch():
    """ops-layer contract: bad backends raise, resolve_impl honors the
    REPRO_PALLAS=interpret CI override, xla == pallas bitwise."""
    o = _slot_operands(6)
    kw = dict(site=ent.SITE_EDGE_JSQ, quanta=None, cap=_Q["cap"])
    with pytest.raises(ValueError):
        qo.jsq_pick(*_jsq_args(o), backend="nope", **kw)
    with pytest.raises(ValueError):
        qo.resolve_impl("nope")
    assert qo.resolve_impl("lax") == "lax"
    assert qo.resolve_impl("pallas") == "pallas"
    import os as _os
    forced = _os.environ.get("REPRO_PALLAS", "") == "interpret"
    on_tpu = jax.default_backend() == "tpu"
    assert qo.resolve_impl("auto") == (
        "pallas" if (on_tpu or forced) else "lax")
    np.testing.assert_array_equal(
        np.asarray(qo.jsq_pick(*_jsq_args(o), backend="xla", **kw)),
        np.asarray(qo.jsq_pick(*_jsq_args(o), backend="pallas", **kw)))
