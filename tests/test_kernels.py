"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracles,
swept over shapes/dtypes, plus hypothesis property tests."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:            # fall back to a deterministic sample sweep
    from _hyp_fallback import given, settings, st

from repro.kernels.lindley import kernel as lk, ref as lr, ops as lo
from repro.kernels.flash_attn import kernel as fk, ref as fr, ops as fo
from repro.kernels.ssd_scan import kernel as sk, ref as sr, ops as so


# ---------------------------------------------------------------------------
# lindley segmented max-plus scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 7, 256, 1000, 4096])
@pytest.mark.parametrize("block", [128, 1024])
def test_lindley_kernel_matches_oracle(n, block, rng):
    v = rng.normal(size=n).astype(np.float32) * 100
    f = rng.random(n) < 0.15
    f[0] = True
    out_k = np.asarray(lk.segmented_cummax(jnp.asarray(v), jnp.asarray(f),
                                           block=block))
    out_r = np.asarray(lr.segmented_cummax(jnp.asarray(v), jnp.asarray(f)))
    np.testing.assert_allclose(out_k, out_r)


@given(st.integers(1, 300), st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_lindley_property_vs_serial(n, seed):
    r = np.random.default_rng(seed)
    v = r.normal(size=n).astype(np.float32)
    f = r.random(n) < 0.3
    f[0] = True
    out = np.asarray(lr.segmented_cummax(jnp.asarray(v), jnp.asarray(f)))
    ser = lr.segmented_cummax_serial(v, f)
    np.testing.assert_allclose(out, ser)


def test_lindley_departures_are_fifo_and_causal(rng):
    """Property: departures are strictly increasing within a queue and never
    precede arrival + service."""
    n = 500
    a = np.sort(rng.uniform(0, 100, n)).astype(np.float32)
    seg = np.zeros(n, bool)
    seg[0] = True
    seg[rng.choice(np.arange(1, n), 20, replace=False)] = True
    d = np.asarray(lo.lindley_departures(jnp.asarray(a), jnp.asarray(seg)))
    start = 0
    for i in range(1, n + 1):
        if i == n or seg[i]:
            dd = d[start:i]
            aa = a[start:i]
            assert (np.diff(dd) >= 1.0 - 1e-3).all()     # 1 pkt/slot service
            assert (dd >= aa + 1.0 - 1e-3).all()          # causality (f32)
            start = i


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

SHAPES = [
    (1, 4, 2, 128, 128, 64),
    (2, 8, 8, 256, 256, 64),
    (1, 8, 1, 128, 128, 128),
    (1, 4, 4, 1, 256, 64),      # decode
    (2, 6, 2, 64, 256, 32),     # Sq < Sk (query tail)
]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(shape, dtype, rng):
    B, Hq, Hkv, Sq, Sk, D = shape
    q = jnp.asarray(rng.normal(size=(B, Hq, Sq, D)), dtype)
    k = jnp.asarray(rng.normal(size=(B, Hkv, Sk, D)), dtype)
    v = jnp.asarray(rng.normal(size=(B, Hkv, Sk, D)), dtype)
    out_k = fk.flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    out_r = fr.mha(q, k, v, causal=True)
    tol = 2e-5 if dtype == np.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32),
                               atol=tol, rtol=tol)


def test_chunked_matches_full(rng):
    B, Hq, Hkv, S, D = 1, 4, 2, 512, 64
    q = jnp.asarray(rng.normal(size=(B, Hq, S, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Hkv, S, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Hkv, S, D)), jnp.float32)
    full = fr.mha(q, k, v, causal=True)
    chunk = fr.mha_chunked(q, k, v, causal=True, block_k=128)
    np.testing.assert_allclose(np.asarray(full), np.asarray(chunk),
                               atol=2e-5, rtol=2e-5)


def test_chunked_mixed_dims(rng):
    """MLA shape: d_k=48, d_v=32."""
    q = jnp.asarray(rng.normal(size=(1, 4, 64, 48)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 4, 64, 48)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 4, 64, 32)), jnp.float32)
    out = fr.mha_chunked(q, k, v, causal=True, block_k=32)
    # oracle: dense softmax
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) / np.sqrt(48)
    mask = jnp.tril(jnp.ones((64, 64), bool))
    logits = jnp.where(mask[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, -1)
    ref = jnp.einsum("bhqk,bhkd->bhqd", p, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


@given(st.integers(1, 4), st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_attention_rowsum_property(heads, seed):
    """Attention outputs are convex combinations of V rows: with identical V
    rows the output equals that row (softmax sums to 1)."""
    r = np.random.default_rng(seed)
    q = jnp.asarray(r.normal(size=(1, heads, 32, 16)), jnp.float32)
    k = jnp.asarray(r.normal(size=(1, heads, 32, 16)), jnp.float32)
    row = r.normal(size=(16,)).astype(np.float32)
    v = jnp.broadcast_to(jnp.asarray(row), (1, heads, 32, 16))
    out = fr.mha(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.broadcast_to(row, out.shape),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# Mamba2 SSD scan
# ---------------------------------------------------------------------------

SSD_SHAPES = [
    (1, 64, 2, 16, 1, 16),
    (2, 128, 4, 32, 2, 64),
    (1, 96, 8, 64, 4, 32),    # L not multiple of 64 (ops pads)
]


@pytest.mark.parametrize("shape", SSD_SHAPES)
def test_ssd_kernel_and_chunked_match_scan(shape, rng):
    B, L, H, P, G, N = shape
    x = jnp.asarray(rng.normal(size=(B, L, H, P)), jnp.float32)
    dt = jnp.asarray(0.01 + rng.random((B, L, H)) * 0.2, jnp.float32)
    A = jnp.asarray(-0.5 - rng.random(H), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B, L, G, N)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(B, L, G, N)), jnp.float32)
    oracle = np.asarray(sr.ssd_scan(x, dt, A, Bm, C))
    chunked = np.asarray(so.ssd(x, dt, A, Bm, C, chunk=32,
                                backend="chunked"))
    np.testing.assert_allclose(chunked, oracle, atol=5e-5, rtol=5e-4)
    if L % 32 == 0:
        pallas = np.asarray(sk.ssd_scan(x, dt, A, Bm, C, chunk=32))
        np.testing.assert_allclose(pallas, oracle, atol=5e-5, rtol=5e-4)


def test_ssd_final_state_matches_sequential(rng):
    B, L, H, P, G, N = 1, 48, 2, 8, 1, 8
    x = jnp.asarray(rng.normal(size=(B, L, H, P)), jnp.float32)
    dt = jnp.asarray(0.05 + rng.random((B, L, H)) * 0.1, jnp.float32)
    A = jnp.asarray(-1.0 - rng.random(H), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B, L, G, N)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(B, L, G, N)), jnp.float32)
    hf = np.asarray(sr.ssd_final_state(x, dt, A, Bm, C, chunk=16))
    # sequential oracle
    h = np.zeros((B, H, N, P), np.float32)
    xn, dtn, An = map(np.asarray, (x, dt, A))
    Bn = np.repeat(np.asarray(Bm), H // G, axis=2)
    for t in range(L):
        for b in range(B):
            for hh in range(H):
                h[b, hh] = (np.exp(An[hh] * dtn[b, t, hh]) * h[b, hh]
                            + dtn[b, t, hh]
                            * np.outer(Bn[b, t, hh], xn[b, t, hh]))
    np.testing.assert_allclose(hf, h, atol=1e-4, rtol=1e-3)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_ssd_decay_property(seed):
    """With A -> -inf (instant forgetting) the SSD reduces to the per-step
    readout C_t . (dt_t B_t x_t^T)."""
    r = np.random.default_rng(seed)
    B, L, H, P, G, N = 1, 16, 1, 4, 1, 4
    x = jnp.asarray(r.normal(size=(B, L, H, P)), jnp.float32)
    dt = jnp.asarray(np.full((B, L, H), 1.0), jnp.float32)
    A = jnp.asarray([-50.0], jnp.float32)
    Bm = jnp.asarray(r.normal(size=(B, L, G, N)), jnp.float32)
    C = jnp.asarray(r.normal(size=(B, L, G, N)), jnp.float32)
    y = np.asarray(sr.ssd_scan(x, dt, A, Bm, C))
    expect = np.einsum("blgn,blgn,blhp->blhp",
                       np.asarray(C), np.asarray(Bm), np.asarray(x))
    np.testing.assert_allclose(y, expect, atol=1e-4, rtol=1e-3)
