import os

# Exercise the multi-device shard_map path on single-CPU hosts: split the
# host platform into two virtual devices.  Must run before jax initializes
# its backend, which conftest import order guarantees.
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=2").strip()

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
