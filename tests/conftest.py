import os

# Exercise the multi-device shard_map path on single-CPU hosts: split the
# host platform into two virtual devices.  Must run before jax initializes
# its backend, which conftest import order guarantees.
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=2").strip()

# Lock the backend NOW, before any test module imports.  Some in-repo modules
# (repro.launch.dryrun / .perf) append their own 512-device forcing to
# XLA_FLAGS at import time; if jax were still uninitialized when a test
# module pulled one of them in, the device count the suite runs under would
# depend on which subset of tests was collected and in what order.  Touching
# jax.devices() here pins it: every `pytest -x -q` invocation -- full run or
# single file -- sees the identical device topology.
import jax  # noqa: E402

_N_DEVICES = len(jax.devices())

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def two_devices():
    """Tests exercising the sharded (shard_map) megabatch paths require the
    two virtual CPU devices forced above.  If the user's environment pinned
    a different device count via XLA_FLAGS, skip with a clear message
    instead of failing deep inside a mesh construction."""
    if _N_DEVICES < 2:
        pytest.skip(f"sharded-path tests need >= 2 devices, have "
                    f"{_N_DEVICES} (XLA_FLAGS pinned elsewhere?)")
    return _N_DEVICES
