"""Continuous batching for serving (slot-based, MaxText/vLLM-style).

A fixed pool of ``n_slots`` decode slots shares one jitted decode step;
requests are admitted into free slots (their prompt prefilled into the
slot's cache region), decode advances all active slots together, and
finished slots (EOS or max-tokens) are retired and refilled.  Per-slot
position indices make the single decode program serve heterogeneous
request lengths -- no recompilation as the batch composition changes.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from ..models.registry import Model
from . import serve_step


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int
    eos_id: int = -1              # -1: never
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ContinuousBatcher:
    def __init__(self, model: Model, params, n_slots: int, max_len: int,
                 mesh=None):
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.cache = serve_step.zero_cache(model, n_slots, max_len)
        # per-slot single-sequence prefill shares the batched cache via
        # slot-indexed scatter; for simplicity we prefill with batch=1
        # caches and scatter in.
        self._prefill1, self._decode = serve_step.build_serve_fns(model, mesh)
        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self.slot_pos = np.zeros(n_slots, np.int32)
        self.slot_tok = np.zeros((n_slots, 1), np.int32)
        self.queue: List[Request] = []
        self.finished: Dict[int, Request] = {}

    # -- admission -----------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for s in range(self.n_slots):
            if self.slot_req[s] is None and self.queue:
                req = self.queue.pop(0)
                S = len(req.prompt)
                c1 = serve_step.zero_cache(self.model, 1, self.max_len)
                logits, c1 = self.model.prefill(
                    self.params, {"tokens": jnp.asarray(req.prompt[None])},
                    c1)
                tok = int(jnp.argmax(logits[:, -1]))
                req.out.append(tok)
                self.cache = jax.tree_util.tree_map(
                    lambda full, one: jax.lax.dynamic_update_slice_in_dim(
                        full, one.astype(full.dtype), s, axis=1),
                    self.cache, c1)
                self.slot_req[s] = req
                self.slot_pos[s] = S
                self.slot_tok[s, 0] = tok

    # -- decode tick -----------------------------------------------------------
    def step(self):
        self._admit()
        active = [s for s in range(self.n_slots)
                  if self.slot_req[s] is not None]
        if not active:
            return False
        # Decode per same-position group: gather the group's cache slice,
        # advance it, scatter back -- other slots' caches stay untouched.
        # (A production path would use per-slot scatter indices inside the
        # kernel; the gather/scatter keeps the same jitted program.)
        for pos in sorted({int(self.slot_pos[s]) for s in active}):
            group = [s for s in active if self.slot_pos[s] == pos]
            gidx = jnp.asarray(group)
            sub_cache = jax.tree_util.tree_map(
                lambda c: jnp.take(c, gidx, axis=1), self.cache)
            toks = jnp.asarray(self.slot_tok[group])
            logits, sub_cache = self.model.decode_step(
                self.params, toks, sub_cache, pos)
            self.cache = jax.tree_util.tree_map(
                lambda c, sc: c.at[:, gidx].set(sc), self.cache, sub_cache)
            nxt = np.asarray(jnp.argmax(logits[:, -1], -1)).astype(np.int32)
            for gi, s in enumerate(group):
                req = self.slot_req[s]
                tok = int(nxt[gi])
                req.out.append(tok)
                self.slot_pos[s] += 1
                self.slot_tok[s, 0] = tok
                if (tok == req.eos_id
                        or len(req.out) >= req.max_new_tokens
                        or self.slot_pos[s] >= self.max_len - 1):
                    req.done = True
                    self.finished[req.rid] = req
                    self.slot_req[s] = None
        return True

    def run_to_completion(self, max_ticks: int = 10_000):
        ticks = 0
        while (self.queue or any(r is not None for r in self.slot_req)) \
                and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.finished
