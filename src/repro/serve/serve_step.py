"""Serving steps: sharded prefill and decode over the model zoo.

``build_serve_fns`` returns jitted (prefill, decode) with cache shardings
resolved from the model's cache logical axes (batch over data, cache length
over model -- the layout that fits 32k-context batch-128 decode in HBM).
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from ..models import sharding as sh
from ..models.registry import Model


def zero_cache(model: Model, batch: int, max_len: int):
    return jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype),
        model.cache_shapes(batch, max_len))


def cache_shardings(model: Model, batch: int, max_len: int, mesh,
                    phase: str = "decode"):
    """Decode shards caches by kv-heads/sequence (capacity); prefill shards
    by batch only -- writing a sequence-sharded cache with a dynamic slice
    forces GSPMD to rematerialize the whole cache per layer (measured 5x
    collective blowup on the 32k prefill cells)."""
    shapes = model.cache_shapes(batch, max_len)
    axes = model.cache_logical_axes()
    if phase == "prefill":
        axes = jax.tree_util.tree_map(
            lambda ax: tuple(None if a in ("seq_cache", "kv_heads") else a
                             for a in ax),
            axes, is_leaf=lambda x: isinstance(x, tuple))
    return jax.tree_util.tree_map(
        lambda ax, s: sh.named_sharding(ax, s.shape, mesh),
        axes, shapes, is_leaf=lambda x: isinstance(x, tuple))


def build_serve_fns(model: Model, mesh=None):
    """(prefill_fn, decode_fn), both jitted.

    prefill_fn(params, batch, cache) -> (last_logits, cache)
    decode_fn(params, tokens, cache, index) -> (logits, cache)
    """

    def prefill(params, batch, cache):
        logits, cache = model.prefill(params, batch, cache)
        return logits[:, -1:], cache

    def decode(params, tokens, cache, index):
        logits, cache = model.decode_step(params, tokens, cache, index)
        return logits, cache

    if mesh is None:
        return jax.jit(prefill), jax.jit(decode)

    with sh.use_mesh(mesh):
        return jax.jit(prefill, donate_argnums=(2,)), \
            jax.jit(decode, donate_argnums=(2,))


def greedy_decode(model: Model, params, prompt_tokens, n_new: int,
                  mesh=None, extra_batch=None):
    """Reference end-to-end decode loop (used by examples + tests)."""
    B, S = prompt_tokens.shape
    n_front = 0
    if model.cfg.family == "vlm" and extra_batch:
        n_front = extra_batch["vision_embeds"].shape[1]
    cache = zero_cache(model, B, S + n_front + n_new)
    prefill_fn, decode_fn = build_serve_fns(model, mesh)
    batch = {"tokens": prompt_tokens}
    if extra_batch:
        batch.update(extra_batch)
    logits, cache = prefill_fn(params, batch, cache)
    out = [jnp.argmax(logits, -1).astype(jnp.int32)]
    idx = S + n_front
    for i in range(n_new - 1):
        logits, cache = decode_fn(params, out[-1], cache, idx + i)
        out.append(jnp.argmax(logits, -1).astype(jnp.int32))
    return jnp.concatenate(out, axis=1)
