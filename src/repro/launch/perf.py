import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

"""§Perf hillclimbing driver: re-lower a cell under named config variations
and report the three roofline terms before/after.

    python -m repro.launch.perf --arch deepseek-v3-671b --shape train_4k \
        --mesh multi --variant moe_rotation --variant remat_dots ...

Variants (composable):
  remat_dots     remat saves matmul outputs (recompute flops down, mem up)
  remat_nothing  full recompute (baseline policy)
  moe_rotation   MoE AllToAll as DR rotation rounds (paper's discipline)
  moe_a2a        XLA one-shot AllToAll (baseline)
  cap_1_0 / cap_2_0   MoE capacity factor
  mb_2 / mb_4 / mb_8 / mb_16   microbatch count
  compress_bf16  cross-pod gradient compression
  attn_block_256 chunked-attention block size
"""
import argparse
import dataclasses
import json
import time

import jax

from ..configs.base import SHAPES, get_config
from ..models.registry import Model
from ..models import sharding as sh
from . import mesh as mesh_mod
from . import dryrun as dr
from . import hlo_analysis
from .roofline import PEAK_FLOPS, HBM_BW, LINK_BW, model_flops_for


def apply_variants(cfg, names):
    tcfg_kw = {}
    for v in names:
        if v == "remat_dots":
            cfg = dataclasses.replace(cfg, remat_policy="dots")
        elif v == "remat_nothing":
            cfg = dataclasses.replace(cfg, remat_policy="nothing")
        elif v == "moe_rotation":
            cfg = dataclasses.replace(cfg, moe_impl="rotation")
        elif v == "moe_a2a":
            cfg = dataclasses.replace(cfg, moe_impl="a2a")
        elif v.startswith("cap_"):
            cfg = dataclasses.replace(
                cfg, capacity_factor=float(v[4:].replace("_", ".")))
        elif v.startswith("mb_"):
            cfg = dataclasses.replace(cfg, microbatch=int(v[3:]))
        elif v == "compress_bf16":
            tcfg_kw["compress_dcn"] = "bf16"
        elif v == "no_remat":
            cfg = dataclasses.replace(cfg, remat=False)
        elif v == "serve_tp":
            os.environ["REPRO_SERVE_LAYOUT"] = "tp" 
        else:
            raise ValueError(v)
    return cfg, tcfg_kw


def measure(arch, shape_name, multi_pod, variants=()):
    cfg = get_config(arch)
    cfg, tcfg_kw = apply_variants(cfg, variants)
    shape = SHAPES[shape_name]
    mesh = mesh_mod.make_production_mesh(multi_pod=multi_pod)
    chips = 512 if multi_pod else 256
    rules = sh.rules_for(cfg)
    t0 = time.time()
    with sh.use_mesh(mesh, rules):
        if shape.kind == "train":
            from ..train import train_step as ts
            tcfg = ts.TrainConfig(**tcfg_kw)
            lowered = dr._train_lowered(Model(cfg), shape, mesh, tcfg)
        else:
            lowered = dr._serve_lowered(Model(cfg), shape, mesh, shape.kind)
        compiled = lowered.compile()
        mem = hlo_analysis.memory_dict(compiled.memory_analysis())
        f, b, c = dr.calibrated_costs(cfg, shape, mesh, shape.kind,
                                      cfg.microbatch
                                      if shape.kind == "train" else 1)
    mf = model_flops_for(cfg, shape)
    row = {
        "variants": list(variants),
        "flops": f, "bytes": b, "coll_bytes": c,
        "t_compute": f / PEAK_FLOPS,
        "t_memory": b / HBM_BW,
        "t_collective": c / LINK_BW,
        "peak_gib": mem.get("peak_estimate_gib_per_device", -1),
        "model_flops": mf,
        "wall_s": round(time.time() - t0, 1),
    }
    t = max(row["t_compute"], row["t_memory"], row["t_collective"])
    row["dominant"] = ("compute" if t == row["t_compute"] else
                       "memory" if t == row["t_memory"] else "collective")
    row["roofline_fraction"] = (mf / (t * chips * PEAK_FLOPS)) if t > 0 else 0
    return row


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", choices=["single", "multi"], default="multi")
    ap.add_argument("--variant", action="append", default=[])
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args(argv)
    row = measure(args.arch, args.shape, args.mesh == "multi",
                  tuple(args.variant))
    if args.json:
        print(json.dumps(row))
    else:
        print(f"{args.arch} x {args.shape} x {args.mesh} "
              f"variants={row['variants']}")
        print(f"  t_compute={row['t_compute']*1e3:.2f}ms "
              f"t_memory={row['t_memory']*1e3:.2f}ms "
              f"t_collective={row['t_collective']*1e3:.2f}ms "
              f"dominant={row['dominant']}")
        print(f"  roofline_fraction={row['roofline_fraction']:.3f} "
              f"peak_gib={row['peak_gib']:.1f} wall={row['wall_s']}s")


if __name__ == "__main__":
    main()
