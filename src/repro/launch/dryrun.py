import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# The two lines above MUST run before any jax import: jax locks the device
# count at first initialization.  Everything else follows.

"""Multi-pod dry-run: lower + compile every (architecture x input-shape) cell
on the production meshes and record memory/cost/collective analyses.
(No __future__ import here: the XLA_FLAGS lines above must stay first.)

For each cell:
  * train_4k       lowers ``train_step`` (microbatched grad-accum + optimizer)
  * prefill_32k    lowers ``prefill`` (forward + cache write)
  * decode_32k     lowers ``decode_step`` (1 token against a 32k cache)
  * long_500k      decode at 524288 context (sub-quadratic archs only)

and each of the two meshes (16x16 single-pod; 2x16x16 multi-pod).  Success
== ``.lower().compile()`` returns and ``memory_analysis`` fits the 16 GB/chip
budget.  Results land in ``experiments/dryrun/<arch>__<shape>__<mesh>.json``
including the §Roofline inputs (HLO flops/bytes + per-collective bytes
parsed from the optimized HLO).

Usage:
  python -m repro.launch.dryrun --arch phi4-mini-3.8b --shape train_4k \
      --mesh multi [--out experiments/dryrun]
  python -m repro.launch.dryrun --all [--mesh both]
"""
import argparse
import json
import pathlib
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from ..configs.base import SHAPES, applicable_shapes, get_config
from ..models.registry import Model
from ..models import sharding as sh
from ..train import train_step as ts
from ..train import optimizer as opt_mod
from . import mesh as mesh_mod
from . import hlo_analysis


def _train_lowered(model: Model, shape, mesh, tcfg=None):
    tcfg = tcfg or ts.TrainConfig()
    step_fn = ts.build_train_step(model, tcfg)
    specs = model.input_specs(shape)

    param_shapes = model.param_shapes()
    opt = opt_mod.make(model.cfg.optimizer, lr=tcfg.learning_rate)
    state_shapes = {
        "params": param_shapes,
        "opt": jax.eval_shape(opt.init, param_shapes),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }
    state_sh = ts.shardings_for_state(model, mesh, tcfg)
    batch_sh = ts.batch_shardings(model, mesh, specs)
    jitted = jax.jit(step_fn, in_shardings=(state_sh, batch_sh),
                     out_shardings=(state_sh, None),
                     donate_argnums=(0,))
    return jitted.lower(state_shapes, specs)


def _serve_lowered(model: Model, shape, mesh, kind: str):
    from ..serve import serve_step as ss
    # Baseline keeps the training (FSDP) weight layout for comparability;
    # REPRO_SERVE_LAYOUT=tp switches to the serving layout (TP-only weights,
    # sharding.serve_rules) -- the beyond-paper optimization measured in
    # EXPERIMENTS.md §Perf.
    if os.environ.get("REPRO_SERVE_LAYOUT") == "tp":
        serve_rules = sh.serve_rules(model.cfg, mesh)
        with sh.use_mesh(mesh, serve_rules):
            return _serve_lowered_inner(model, shape, mesh, kind, ss)
    return _serve_lowered_inner(model, shape, mesh, kind, ss)


def _serve_lowered_inner(model: Model, shape, mesh, kind: str, ss):
    specs = model.input_specs(shape)
    B = shape.global_batch
    n_front = 0
    if model.cfg.family == "vlm":
        n_front = specs.get("vision_embeds").shape[1] \
            if "vision_embeds" in specs else 0
    # decode: the cache holds exactly seq_len positions (divisible by the
    # model axis for sequence sharding); the new token writes slot S-1.
    max_len = shape.seq_len + (n_front if kind == "prefill" else 0)
    cache_shapes_ = model.cache_shapes(B, max_len)
    cache_sh = ss.cache_shardings(model, B, max_len, mesh, phase=kind)
    p_sh = jax.tree_util.tree_map(
        lambda ax, s: sh.named_sharding(ax, s.shape, mesh),
        model.logical_axes(), model.param_shapes(),
        is_leaf=lambda x: isinstance(x, tuple))
    param_shapes = model.param_shapes()

    if kind == "prefill":
        def fn(params, batch, cache):
            logits, cache = model.prefill(params, batch, cache)
            return logits[:, -1:], cache
        batch_sh = ts.batch_shardings(model, mesh, specs)
        jitted = jax.jit(fn, in_shardings=(p_sh, batch_sh, cache_sh),
                         out_shardings=(None, cache_sh),
                         donate_argnums=(2,))
        return jitted.lower(param_shapes, specs, cache_shapes_)

    def fn(params, tokens, cache, index):
        return model.decode_step(params, tokens, cache, index)
    tok_sh = sh.named_sharding(("batch", None), (B, 1), mesh)
    jitted = jax.jit(fn, in_shardings=(p_sh, tok_sh, cache_sh, None),
                     out_shardings=(None, cache_sh), donate_argnums=(2,))
    return jitted.lower(param_shapes, specs["tokens"], cache_shapes_,
                        jnp.int32(max_len - 1))


# ---------------------------------------------------------------------------
# Calibrated cost composition.
#
# XLA's cost_analysis() counts while-loop (scan) bodies ONCE, ignoring trip
# counts, so a full-depth compile under-reports flops/bytes/collectives by
# ~n_layers x microbatches.  We therefore lower small *unrolled* depth
# variants (scan_unroll=True, microbatch=1, per-microbatch batch size) and
# solve the linear system  cost(depths) = base + sum_s depth_s * slope_s,
# then extrapolate to the full depths and multiply by the microbatch count.
# Memory analysis comes from the true full-depth compile (the compiler models
# loops correctly for buffers).
# ---------------------------------------------------------------------------
import dataclasses as _dc

import numpy as _np


def _probe_variants(cfg):
    """Returns (variants, full_depths): each variant is (cfg_i, depth_vec)."""
    if cfg.family == "encdec":
        mk = lambda e, d: _dc.replace(cfg, n_layers=d, n_encoder_layers=e,
                                      scan_unroll=True, microbatch=1)
        return ([(mk(1, 1), (1, 1)), (mk(2, 1), (2, 1)), (mk(1, 2), (1, 2))],
                (cfg.n_encoder_layers, cfg.n_layers))
    if cfg.family == "hybrid":
        k = cfg.shared_attn_every
        mk = lambda g: _dc.replace(cfg, n_layers=g * k, scan_unroll=True,
                                   microbatch=1)
        return ([(mk(1), (1,)), (mk(2), (2,))],
                (cfg.n_layers // k,))
    if cfg.n_experts and cfg.n_dense_layers:
        mk = lambda d, m: _dc.replace(cfg, n_layers=d + m, n_dense_layers=d,
                                      scan_unroll=True, microbatch=1)
        return ([(mk(1, 1), (1, 1)), (mk(2, 1), (2, 1)), (mk(1, 2), (1, 2))],
                (cfg.n_dense_layers, cfg.n_layers - cfg.n_dense_layers))
    mk = lambda d: _dc.replace(cfg, n_layers=d, scan_unroll=True,
                               microbatch=1)
    return ([(mk(1), (1,)), (mk(2), (2,))], (cfg.n_layers,))


def _cell_costs(model, shape, mesh, kind):
    if kind == "train":
        lowered = _train_lowered(model, shape, mesh)
    else:
        lowered = _serve_lowered(model, shape, mesh, kind)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    coll = hlo_analysis.collective_bytes(compiled)
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)),
            float(coll["total_bytes"]),
            coll)


def calibrated_costs(cfg, shape, mesh, kind, microbatch: int):
    """(flops, bytes, coll_bytes) per device per step, scan-corrected."""
    variants, full_depths = _probe_variants(cfg)
    # probe at the real per-microbatch batch size
    mb = max(1, microbatch) if kind == "train" else 1
    pshape = _dc.replace(shape, global_batch=max(shape.global_batch // mb, 1))
    rows, targets = [], []
    for vcfg, depths in variants:
        m = Model(vcfg)
        f, b, c, _ = _cell_costs(m, pshape, mesh, kind)
        rows.append((1,) + tuple(depths))
        targets.append((f, b, c))
    A = _np.array(rows, float)
    Y = _np.array(targets, float)
    sol, *_ = _np.linalg.lstsq(A, Y, rcond=None)
    full = _np.array((1,) + tuple(full_depths), float)
    est = full @ sol
    est = _np.maximum(est, 0.0)
    scale = mb if kind == "train" else 1
    return est[0] * scale, est[1] * scale, est[2] * scale


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str = "experiments/dryrun",
             smoke: bool = False) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cfg = get_config(arch, smoke=smoke)
    shape = SHAPES[shape_name]
    if shape_name not in applicable_shapes(cfg):
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": "skipped",
               "reason": "full-attention arch: long_500k needs "
                         "sub-quadratic attention (DESIGN.md)"}
        out = pathlib.Path(out_dir)
        out.mkdir(parents=True, exist_ok=True)
        (out / f"{arch}__{shape_name}__{mesh_name}.json").write_text(
            json.dumps(rec, indent=2))
        return rec
    model = Model(cfg)
    mesh_mod.require_devices(512 if multi_pod else 256)
    mesh = mesh_mod.make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "kind": shape.kind}
    rules = sh.rules_for(cfg)
    try:
        with sh.use_mesh(mesh, rules):
            if shape.kind == "train":
                lowered = _train_lowered(model, shape, mesh)
            else:
                lowered = _serve_lowered(model, shape, mesh, shape.kind)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        coll = hlo_analysis.collective_bytes(compiled)
        # scan-corrected costs via calibrated composition
        with sh.use_mesh(mesh, rules):
            cal_f, cal_b, cal_c = calibrated_costs(
                cfg, shape, mesh, shape.kind,
                cfg.microbatch if shape.kind == "train" else 1)
        rec.update({
            "status": "ok",
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "memory": hlo_analysis.memory_dict(mem),
            "flops_raw": float(cost.get("flops", -1.0)),
            "bytes_raw": float(cost.get("bytes accessed", -1.0)),
            "collectives_raw": coll,
            "flops": cal_f,
            "bytes_accessed": cal_b,
            "collectives": {"total_bytes": cal_c,
                            "by_kind": coll.get("by_kind", {})},
        })
        print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: OK "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
        print(f"  memory: {rec['memory']}")
        print(f"  flops={rec['flops']:.3e} bytes={rec['bytes_accessed']:.3e}"
              f" collective_bytes={coll['total_bytes']:.3e}")
    except Exception as e:  # noqa: BLE001
        rec.update({"status": "fail", "error": repr(e),
                    "traceback": traceback.format_exc()})
        print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: FAIL {e!r}")
    out = pathlib.Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    fn = out / f"{arch}__{shape_name}__{mesh_name}.json"
    fn.write_text(json.dumps(rec, indent=2, default=str))
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced configs (CI sanity of the dry-run path)")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args(argv)

    from ..configs.base import list_architectures
    archs = list_architectures() if (args.all or args.arch is None) \
        else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) \
        else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    n_fail = 0
    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape_name, mp, args.out,
                               smoke=args.smoke)
                n_fail += rec["status"] == "fail"
    sys.exit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
