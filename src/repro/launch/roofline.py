"""Roofline analysis over the dry-run artifacts.

Per (arch x shape x mesh) cell, from the compiled module:

  compute term     = HLO_FLOPs / (chips x 197e12 FLOP/s)        [bf16 MXU]
  memory term      = HLO_bytes / (chips x 819e9 B/s)            [HBM]
  collective term  = collective_bytes / (chips x 50e9 B/s)      [ICI link]

cost_analysis() reports whole-module (per-device-program x chips? -- on the
CPU backend it reports the per-program totals; we treat them as per-device
and DIVIDE the global-batch model flops consistently, see note below).

MODEL_FLOPS uses the 6*N*D rule (6 * params * tokens; N_active for MoE), so
``model_flops / hlo_flops`` exposes remat/redundancy waste.

Outputs ``experiments/roofline.csv`` + markdown for EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Dict, Optional

# TPU v5e-class hardware constants (per the assignment).
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
LINK_BW = 50e9               # B/s / ICI link


@dataclasses.dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    kind: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    model_flops: float
    t_compute: float
    t_memory: float
    t_collective: float
    dominant: str
    useful_ratio: float
    note: str = ""

    @property
    def roofline_fraction(self) -> float:
        """useful model flops / (time-if-run-at-dominant-term * peak)."""
        t = max(self.t_compute, self.t_memory, self.t_collective)
        if t <= 0:
            return 0.0
        return self.model_flops / (t * self.chips * PEAK_FLOPS)


def params_count(cfg) -> Dict[str, float]:
    """Total and active parameter counts from the config (analytic)."""
    D, V = cfg.d_model, cfg.vocab
    emb = V * D * (1 if cfg.tie_embeddings else 2)
    if cfg.family in ("dense", "vlm", "moe"):
        if cfg.mla:
            qr, kr = cfg.q_lora_rank, cfg.kv_lora_rank
            dn, dr, dv = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
            H = cfg.n_heads
            attn = (D * qr + qr * H * (dn + dr) + D * (kr + dr)
                    + kr * H * dn + kr * H * dv + H * dv * D)
        else:
            H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
            attn = D * H * hd + 2 * D * Hkv * hd + H * hd * D
        n_moe = (cfg.n_layers - cfg.n_dense_layers) if cfg.n_experts else 0
        n_dense = cfg.n_layers - n_moe
        dense_mlp = 3 * D * cfg.d_ff
        total = emb + cfg.n_layers * attn + n_dense * dense_mlp
        active = total
        if n_moe:
            expert = 3 * D * cfg.moe_d_ff
            shared = 3 * D * cfg.moe_d_ff * cfg.n_shared_experts
            router = D * cfg.n_experts
            total += n_moe * (cfg.n_experts * expert + shared + router)
            active += n_moe * (cfg.experts_per_tok * expert + shared + router)
        return {"total": float(total), "active": float(active)}
    if cfg.family == "ssm":
        din, H = cfg.ssm_d_inner, cfg.ssm_heads
        G, N = cfg.ssm_groups, cfg.ssm_state
        per = (D * (2 * din + 2 * G * N + H) + cfg.ssm_conv *
               (din + 2 * G * N) + din * D + din + 3 * H)
        total = emb + cfg.n_layers * per
        return {"total": float(total), "active": float(total)}
    if cfg.family == "hybrid":
        din, H = cfg.ssm_d_inner, cfg.ssm_heads
        G, N = cfg.ssm_groups, cfg.ssm_state
        per = (D * (2 * din + 2 * G * N + H) + cfg.ssm_conv *
               (din + 2 * G * N) + din * D + din + 3 * H)
        Hh, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        shared = (D * Hh * hd + 2 * D * Hkv * hd + Hh * hd * D
                  + 3 * D * cfg.d_ff)
        total = emb + cfg.n_layers * per + shared
        # the shared block runs n_layers/shared_attn_every times: active
        # compute counts it per application
        apps = cfg.n_layers // cfg.shared_attn_every
        return {"total": float(total), "active": float(total
                                                       + (apps - 1) * shared)}
    if cfg.family == "encdec":
        H, Hkv, hd, F = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_ff
        enc = cfg.n_encoder_layers * (D * H * hd + 2 * D * Hkv * hd
                                      + H * hd * D + 3 * D * F)
        dec = cfg.n_layers * (2 * (D * H * hd + H * hd * D)
                              + 2 * D * Hkv * hd + 3 * D * F)
        total = emb + enc + dec + cfg.n_frontend_tokens * D
        return {"total": float(total), "active": float(total)}
    raise ValueError(cfg.family)


def model_flops_for(cfg, shape) -> float:
    """6*N*D rule on *decoder tokens processed* (training: 3 passes =>
    6*N*T; prefill: 2*N*T; decode: 2*N per token * batch)."""
    pc = params_count(cfg)
    n_active = pc["active"]
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return 6.0 * n_active * B * S
    if shape.kind == "prefill":
        return 2.0 * n_active * B * S
    return 2.0 * n_active * B * 1


def analyze_record(rec: dict, cfg, shape) -> Optional[RooflineRow]:
    if rec.get("status") != "ok":
        return None
    chips = 512 if rec["mesh"] == "pod2x16x16" else 256
    hlo_flops = rec["flops"]
    hlo_bytes = rec["bytes_accessed"]
    coll = rec["collectives"]["total_bytes"]
    mf = model_flops_for(cfg, shape)
    # cost_analysis on SPMD modules reports per-device-program numbers; the
    # whole-job totals are x chips.
    t_compute = hlo_flops / PEAK_FLOPS
    t_memory = hlo_bytes / HBM_BW
    t_coll = coll / LINK_BW
    dom = max((t_compute, "compute"), (t_memory, "memory"),
              (t_coll, "collective"))[1]
    useful = mf / (hlo_flops * chips) if hlo_flops > 0 else 0.0
    return RooflineRow(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        kind=rec.get("kind", shape.kind), chips=chips,
        hlo_flops=hlo_flops, hlo_bytes=hlo_bytes, coll_bytes=coll,
        model_flops=mf, t_compute=t_compute, t_memory=t_memory,
        t_collective=t_coll, dominant=dom, useful_ratio=useful)


def load_all(dryrun_dir: str = "experiments/dryrun"):
    from ..configs.base import SHAPES, get_config
    rows = []
    skips = []
    for f in sorted(pathlib.Path(dryrun_dir).glob("*.json")):
        rec = json.loads(f.read_text())
        if rec.get("status") == "skipped":
            skips.append(rec)
            continue
        cfg = get_config(rec["arch"])
        shape = SHAPES[rec["shape"]]
        row = analyze_record(rec, cfg, shape)
        if row:
            rows.append(row)
        else:
            skips.append(rec)
    return rows, skips


def to_csv(rows, path: str):
    hdr = ("arch,shape,mesh,kind,chips,hlo_flops,hlo_bytes,coll_bytes,"
           "model_flops,t_compute_s,t_memory_s,t_collective_s,dominant,"
           "useful_ratio,roofline_fraction")
    lines = [hdr]
    for r in rows:
        lines.append(
            f"{r.arch},{r.shape},{r.mesh},{r.kind},{r.chips},"
            f"{r.hlo_flops:.4e},{r.hlo_bytes:.4e},{r.coll_bytes:.4e},"
            f"{r.model_flops:.4e},{r.t_compute:.4e},{r.t_memory:.4e},"
            f"{r.t_collective:.4e},{r.dominant},{r.useful_ratio:.4f},"
            f"{r.roofline_fraction:.4f}")
    pathlib.Path(path).write_text("\n".join(lines) + "\n")
    return path


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-dir", default="experiments/dryrun")
    ap.add_argument("--out", default="experiments/roofline.csv")
    args = ap.parse_args(argv)
    rows, skips = load_all(args.dryrun_dir)
    to_csv(rows, args.out)
    print(f"{len(rows)} cells analyzed, {len(skips)} skipped/failed "
          f"-> {args.out}")
    for r in sorted(rows, key=lambda r: r.roofline_fraction):
        print(f"  {r.arch:22s} {r.shape:12s} {r.mesh:10s} dom={r.dominant:10s}"
              f" frac={r.roofline_fraction:.3f} useful={r.useful_ratio:.3f}")


if __name__ == "__main__":
    main()
