"""Generate the dry-run summary table + roofline markdown for EXPERIMENTS.md.

    python -m repro.launch.summarize [--dryrun-dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import json
import pathlib

from .roofline import load_all, to_csv, PEAK_FLOPS


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-dir", default="experiments/dryrun")
    ap.add_argument("--out-md", default="experiments/dryrun_summary.md")
    ap.add_argument("--out-csv", default="experiments/roofline.csv")
    args = ap.parse_args(argv)

    rows, skips = load_all(args.dryrun_dir)
    to_csv(rows, args.out_csv)

    recs = {}
    for f in sorted(pathlib.Path(args.dryrun_dir).glob("*.json")):
        r = json.loads(f.read_text())
        recs[(r["arch"], r["shape"], r["mesh"])] = r

    lines = ["# Dry-run + roofline summary", "",
             "| arch | shape | mesh | status | peak GiB/chip | HLO flops/dev"
             " | coll bytes/dev | dominant | useful | roofline frac |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    by_key = {(r.arch, r.shape, r.mesh): r for r in rows}
    for key in sorted(recs):
        rec = recs[key]
        if rec["status"] == "skipped":
            lines.append(f"| {key[0]} | {key[1]} | {key[2]} | skip "
                         f"(full-attn long ctx) | — | — | — | — | — | — |")
            continue
        if rec["status"] != "ok":
            lines.append(f"| {key[0]} | {key[1]} | {key[2]} | FAIL | — | — "
                         f"| — | — | — | — |")
            continue
        r = by_key.get(key)
        gib = rec["memory"].get("peak_estimate_gib_per_device", -1)
        lines.append(
            f"| {key[0]} | {key[1]} | {key[2]} | ok | {gib:.1f} "
            f"| {rec['flops']:.2e} | "
            f"{rec['collectives']['total_bytes']:.2e} | "
            f"{r.dominant if r else '—'} | "
            f"{r.useful_ratio:.2f} | {r.roofline_fraction:.3f} |"
            if r else
            f"| {key[0]} | {key[1]} | {key[2]} | ok | {gib:.1f} | — | — | — "
            f"| — | — |")
    md = "\n".join(lines) + "\n"
    pathlib.Path(args.out_md).write_text(md)
    n_ok = sum(1 for r in recs.values() if r["status"] == "ok")
    n_skip = sum(1 for r in recs.values() if r["status"] == "skipped")
    n_fail = len(recs) - n_ok - n_skip
    print(f"{n_ok} ok / {n_skip} skipped / {n_fail} failed "
          f"-> {args.out_md}, {args.out_csv}")
    # worst cells (hillclimb candidates)
    for r in sorted(rows, key=lambda r: r.roofline_fraction)[:6]:
        print(f"  worst: {r.arch} {r.shape} {r.mesh} frac="
              f"{r.roofline_fraction:.3f} dom={r.dominant}")
    for r in sorted(rows, key=lambda r: -r.t_collective)[:3]:
        print(f"  most collective-bound: {r.arch} {r.shape} {r.mesh} "
              f"t_coll={r.t_collective*1e3:.1f}ms dom={r.dominant}")


if __name__ == "__main__":
    main()
