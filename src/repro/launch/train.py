"""Training launcher.

    python -m repro.launch.train --arch mamba2-130m --smoke --steps 50

Wires together: config -> model -> sharded train_step -> counter-based data
loader -> resilient loop (async checkpoints, retry, straggler log).  On this
CPU container use --smoke (reduced config, 1-device mesh); on a real cluster
the same driver runs under the production mesh.
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from ..configs.base import get_config
from ..models.registry import Model
from ..models import sharding as sh
from ..train import train_step as ts
from ..train import data as data_mod
from ..train import fault_tolerance as ft_mod
from . import mesh as mesh_mod


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--compress-dcn", default=None,
                    choices=[None, "bf16", "int8"])
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    model = Model(cfg)
    if args.smoke:
        mesh = None
    else:
        mesh = mesh_mod.make_production_mesh(multi_pod=args.multi_pod)

    tcfg = ts.TrainConfig(learning_rate=args.lr,
                          compress_dcn=args.compress_dcn)
    with sh.use_mesh(mesh):
        params = model.init_params(jax.random.PRNGKey(tcfg.seed))
        state = ts.make_train_state(model, params, tcfg)
        step_fn = jax.jit(ts.build_train_step(model, tcfg),
                          donate_argnums=(0,))

        dcfg = data_mod.DataConfig(vocab=cfg.vocab, seq_len=args.seq_len,
                                   global_batch=args.global_batch)

        def batches(step):
            toks = data_mod.batch_for_step(dcfg, step)
            batch = {"tokens": jnp.asarray(toks)}
            if cfg.family == "encdec":
                batch["frames"] = jnp.zeros(
                    (args.global_batch, cfg.n_frontend_tokens,
                     cfg.frontend_dim), jnp.float32)
            if cfg.family == "vlm":
                batch["vision_embeds"] = jnp.zeros(
                    (args.global_batch, 8, cfg.frontend_dim), jnp.float32)
            return batch

        ftc = ft_mod.FTConfig(ckpt_dir=args.ckpt_dir,
                              ckpt_every=args.ckpt_every)
        losses = []

        def metrics_cb(step, metrics, dt):
            losses.append(float(metrics["loss"]))
            if step % args.log_every == 0:
                print(f"step {step:5d} loss {losses[-1]:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"{dt*1e3:.0f} ms", flush=True)

        loop = ft_mod.ResilientLoop(step_fn, state, ftc,
                                    health_cb=lambda m: print(f"[ft] {m}"))
        loop.run(batches, args.steps, metrics_cb)
        print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")
        return losses


if __name__ == "__main__":
    main()
