"""Serving launcher: continuous-batching server over any zoo architecture.

    python -m repro.launch.serve --arch qwen1.5-4b --smoke --requests 8

On a real cluster the same driver runs under the production mesh with
cache shardings from ``serve_step.cache_shardings`` (batch over data,
KV heads/sequence over model).
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax

from ..configs.base import get_config
from ..models.registry import Model
from ..models import sharding as sh
from ..serve import batching
from . import mesh as mesh_mod


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    model = Model(cfg)
    mesh = None if args.smoke else mesh_mod.make_production_mesh(
        multi_pod=args.multi_pod)
    rng = np.random.default_rng(0)
    with sh.use_mesh(mesh, sh.rules_for(cfg)):
        params = model.init_params(jax.random.PRNGKey(0))
        cb = batching.ContinuousBatcher(model, params, n_slots=args.slots,
                                        max_len=args.max_len, mesh=mesh)
        t0 = time.time()
        for rid in range(args.requests):
            prompt = rng.integers(
                0, cfg.vocab, (int(rng.integers(4, 16)),)).astype(np.int32)
            cb.submit(batching.Request(rid=rid, prompt=prompt,
                                       max_new_tokens=args.max_new))
        done = cb.run_to_completion()
        dt = time.time() - t0
    total = sum(len(r.out) for r in done.values())
    print(f"served {len(done)}/{args.requests} requests, {total} tokens, "
          f"{total/dt:.1f} tok/s")


if __name__ == "__main__":
    main()
