"""Production mesh construction.

Single pod: 256 chips as (data=16, model=16).
Multi-pod: 2 pods x 256 chips as (pod=2, data=16, model=16); the 'pod' axis
crosses the DCN fat-tree the paper's load-balancing study targets.

Functions (not module-level constants) so importing never touches jax device
state -- the dry-run must set XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh():
    """1-device mesh with the production axis names (CPU tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def require_devices(n: int):
    have = len(jax.devices())
    if have < n:
        raise RuntimeError(
            f"mesh needs {n} devices but jax sees {have}; the dry-run must "
            f"set XLA_FLAGS=--xla_force_host_platform_device_count={n} "
            f"BEFORE importing jax (see launch/dryrun.py)")
