"""HLO post-compile analysis: collective byte accounting + memory digest.

``cost_analysis()`` reports flops and bytes but NOT collective traffic; we
parse the optimized HLO text and sum operand bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute,
attributing each to its mesh role where replica_groups allow.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Any, Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# shape like  bf16[16,1280,7168]{2,1,0}  or tuple (f32[...], f32[...])
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(compiled) -> Dict[str, Any]:
    """Sum output-shape bytes per collective op kind from optimized HLO.

    Uses the op's *result* shape (for a-g: gathered bytes; for a-r: reduced
    tensor; r-s: scattered shard) as the per-device traffic proxy --
    consistent across kinds and exactly what the roofline's
    ``collective_bytes / (chips x link_bw)`` term wants.
    """
    try:
        txt = compiled.as_text()
    except Exception:   # some backends: use memory analysis only
        return {"total_bytes": 0.0, "by_kind": {}, "count": 0}
    by_kind: Dict[str, float] = defaultdict(float)
    counts: Dict[str, int] = defaultdict(int)
    for line in txt.splitlines():
        s = line.strip()
        # ops look like:  %x = bf16[..]{..} all-gather(%y), replica_groups=...
        m = re.match(r"%?[\w.\-]+\s*=\s*(\([^)]*\)|[\w\[\],{}]+)\s+"
                     r"([\w\-]+)", s)
        if not m:
            continue
        shape_str, opname = m.group(1), m.group(2)
        base = opname.split(".")[0]
        if base.endswith("-start"):
            base = base[:-6]
        if base in _COLLECTIVES:
            by_kind[base] += _shape_bytes(shape_str)
            counts[base] += 1
    return {"total_bytes": float(sum(by_kind.values())),
            "by_kind": dict(by_kind),
            "count": int(sum(counts.values())),
            "count_by_kind": dict(counts)}


def memory_dict(mem) -> Dict[str, float]:
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes"):
        try:
            out[attr] = float(getattr(mem, attr))
        except Exception:
            pass
    if not out and isinstance(mem, dict):
        out = {k: float(v) for k, v in mem.items()}
    if not out:
        out = {"repr": 0.0}
    try:
        live = (out.get("argument_size_in_bytes", 0)
                + out.get("output_size_in_bytes", 0)
                + out.get("temp_size_in_bytes", 0)
                - out.get("alias_size_in_bytes", 0))
        out["peak_estimate_bytes"] = live
        out["peak_estimate_gib_per_device"] = live / (1 << 30)
    except Exception:
        pass
    return out
