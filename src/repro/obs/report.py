"""Render a campaign trace into a human-readable cost summary.

Backs ``python -m repro.sweep report``: given the dispatch spans of one
campaign run (``trace.jsonl``) and optionally its ``results.jsonl``, emit

* the dispatch timeline (engine, fused schemes, padding fill, wall split,
  and -- for loop dispatches -- the resolved slot-step ``impl``);
* per-shape padding-waste accounting -- the measured costs the ROADMAP's
  cost-modeled planner consumes;
* loop-engine slot-budget utilization;
* with ``--bench BENCH_sweep.json``: every ``speedup_vs_*`` sample labeled
  honestly -- ratios below 1.0 render as slowdowns, not small speedups;
* a robustness section (retries, terminal dispatch errors, degradation-
  ladder splits, resume checkpoints) whenever the trace carries any of the
  runner's retry/error/degrade/resume spans -- the view that makes a
  *partial* campaign legible: which points are missing from results.jsonl
  and why;
* the top queue trajectories (sparkline per point) when the results carry
  probe series (``Campaign.probes``).
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional

_BLOCKS = " ▁▂▃▄▅▆▇█"


def sparkline(vals, width: int = 60) -> str:
    """Downsample ``vals`` to <= ``width`` chars (max per chunk, so peaks
    survive) and render as unicode block heights."""
    vals = [float(v) for v in vals]
    if len(vals) > width:
        n = len(vals)
        vals = [max(vals[i * n // width:max((i + 1) * n // width,
                                            i * n // width + 1)])
                for i in range(width)]
    peak = max(vals) if vals else 0.0
    if peak <= 0:
        return _BLOCKS[0] * len(vals)
    return "".join(_BLOCKS[max(1, round(v / peak * 8))] if v > 0
                   else _BLOCKS[0] for v in vals)


def _fmt_s(x) -> str:
    return f"{x:8.2f}s" if isinstance(x, (int, float)) else " " * 9


def ratio_label(ratio: float) -> str:
    """Honest rendering of a wall-time ratio: values below 1.0 are
    *slowdowns*, not small speedups (a ``speedup_vs_warm`` of 0.49 means
    the fused path ran at half warm-serial throughput), and a non-finite
    or non-positive sample (a failed/aborted bench run writing 0.0, -1 or
    NaN) is labeled as bad data rather than rendered as an absurd
    "1000000000.0x slower"."""
    if not math.isfinite(ratio) or ratio <= 0.0:
        return "n/a (bad sample)"
    if ratio >= 1.0:
        return f"{ratio:.2f}x speedup"
    return (f"{ratio:.2f}x -- SLOWDOWN "
            f"({1.0 / ratio:.1f}x slower)")


def _bench_ratio_lines(bench: Dict) -> List[str]:
    """The speedup/slowdown summary of a ``BENCH_sweep.json`` dict: every
    ``speedup_vs_*`` sample in the top level and one section deep, labeled
    via :func:`ratio_label`."""
    lines: List[str] = []
    sections = [("", bench)] + [(f"{k}.", v) for k, v in bench.items()
                                if isinstance(v, dict)]
    for prefix, sec in sections:
        impl = sec.get("impl") if isinstance(sec, dict) else None
        for key, val in sec.items():
            if not key.startswith("speedup_vs_"):
                continue
            tag = f"  [impl={impl}]" if impl else ""
            lines.append(f"  {prefix + key:<32s} {ratio_label(float(val))}"
                         f"{tag}")
    return lines


def render_report(spans: List[Dict], records: Optional[List[Dict]] = None,
                  top: int = 3, bench: Optional[Dict] = None) -> str:
    """The ``python -m repro.sweep report`` text body."""
    plan = next((s for s in spans if s.get("kind") == "plan"), None)
    disp = [s for s in spans if s.get("kind") == "dispatch"]
    end = next((s for s in spans if s.get("kind") == "campaign"), None)
    lines: List[str] = []

    name = (plan or end or {"campaign": "?"}).get("campaign", "?")
    schema = (spans[0].get("schema", "?")) if spans else "?"
    lines.append(f"campaign {name!r} -- trace schema {schema}, "
                 f"{len(disp)} dispatches")
    if plan:
        lines.append(f"  {plan.get('n_points', '?')} grid points, "
                     f"{plan.get('n_shapes', '?')} compiled shapes, "
                     f"{plan.get('devices', '?')} device(s)")
    if end and "wall_s" in end:
        emit = end.get("emit_s", 0.0)
        lines.append(f"  total wall {end['wall_s']:.2f}s "
                     f"(trace overhead {emit:.4f}s)")

    # ---- cost-modeled planner: predicted vs realized fill -----------------
    if plan and plan.get("policy"):
        lines.append("")
        lines.append(f"planner: cost-modeled policy {plan['policy']!r}"
                     + (f" (calibration: {plan['calibration']})"
                        if plan.get("calibration") else ""))
        pred = plan.get("predicted") or {}
        if pred:
            lines.append(
                f"  predicted: pkt_fill {pred.get('pkt_fill', 0):.1%} "
                f"({pred.get('pkt_rows_real', '?')} real rows in "
                f"{pred.get('pkt_rows_padded', '?')} padded, "
                f"{pred.get('n_shapes', '?')} shapes, model total "
                f"{pred.get('total', 0):.0f} rows)")
        if end and end.get("pkt_rows_padded"):
            lines.append(
                f"  realized:  pkt_fill {end.get('pkt_fill', 0):.1%} "
                f"({end.get('pkt_rows_real', '?')} real rows in "
                f"{end.get('pkt_rows_padded', '?')} padded)")
        alts = plan.get("alternatives") or []
        for a in alts[:4]:
            lines.append(f"  rejected: {a.get('policy', '?'):<24s} "
                         f"cost {a.get('cost', 0):.0f} rows "
                         f"(fill {a.get('pkt_fill', 0):.1%})")
        if len(alts) > 4:
            lines.append(f"  ... and {len(alts) - 4} more alternatives")

    # ---- dispatch timeline -------------------------------------------------
    if disp:
        lines.append("")
        lines.append("dispatch timeline:")
        lines.append("   #  eng  rows  fill  pkt_fill      wall   "
                     "compile  schemes")
        for s in disp:
            wall = _fmt_s(s.get("wall_s"))
            comp = _fmt_s(s.get("compile_s"))
            cached = "  [cached]" if s.get("cache") == "hit" else ""
            impl = f" impl={s['impl']}" if "impl" in s else ""
            lines.append(
                f"  {s['dispatch']:>2d} {s['engine']:>4s} "
                f"{s['n_points']:>5d}  {s.get('row_fill', 1.0):.2f}  "
                f"{s.get('pkt_fill', 0.0):8.2f} {wall} {comp}  "
                f"{','.join(s.get('schemes', []))}"
                f" k_pad={s.get('k_pad', '?')}{impl}{cached}")

    # ---- padding waste per shape ------------------------------------------
    if disp:
        real = sum(s.get("pkt_rows_real", 0) for s in disp)
        padded = sum(s.get("pkt_rows_padded", 0) for s in disp)
        lines.append("")
        if padded:
            worst = min(disp, key=lambda s: s.get("pkt_fill", 1.0))
            lines.append(
                f"padding: {real} real packet-rows in {padded} padded "
                f"({real / padded:.1%} fill); worst dispatch "
                f"#{worst['dispatch']} at {worst.get('pkt_fill', 0):.1%} "
                f"({','.join(worst.get('schemes', []))})")
        loop_disp = [s for s in disp if "slots_run" in s]
        for s in loop_disp:
            lines.append(
                f"slot budget (dispatch #{s['dispatch']}): ran "
                f"{s['slots_run']}/{s['slot_budget']} slots, per-row fill "
                f"{s.get('slot_fill', 0):.1%}")

    # ---- benchmark ratios (BENCH_sweep.json, --bench) ---------------------
    if bench:
        ratio_lines = _bench_ratio_lines(bench)
        if ratio_lines:
            lines.append("")
            lines.append("benchmark wall-time ratios (fused vs serial "
                         "baselines; below 1.0 the fused path is SLOWER):")
            lines.extend(ratio_lines)

    # ---- dispatch errors / retries / degraded -----------------------------
    retries = [s for s in spans if s.get("kind") == "retry"]
    errors = [s for s in spans if s.get("kind") == "error"]
    degrades = [s for s in spans if s.get("kind") == "degrade"]
    resumes = [s for s in spans if s.get("kind") == "resume"]
    if retries or errors or degrades or resumes:
        lines.append("")
        lines.append("robustness (dispatch errors / retries / degraded):")
        for s in resumes:
            lines.append(f"  resume: kept {s.get('dispatches_kept', '?')} "
                         f"complete dispatches "
                         f"({s.get('records_kept', '?')} records)")
        if retries:
            lines.append(f"  {len(retries)} retried attempt(s) across "
                         f"dispatches "
                         f"{sorted({s.get('dispatch') for s in retries})}")
        for s in degrades:
            extra = (f", {s['failed']} point(s) lost"
                     if s.get("failed") else "")
            lines.append(f"  dispatch #{s.get('dispatch', '?')} degraded to "
                         f"{s.get('stage', '?')}"
                         f" ({s.get('scheme', '?')}){extra}")
        terminal = [s for s in errors if s.get("stage") == "point"]
        whole = [s for s in errors if s.get("stage") != "point"]
        if whole:
            lines.append(f"  {len(whole)} exhausted-budget error(s) at "
                         f"stage(s) "
                         f"{sorted({s.get('stage') for s in whole})}")
        for s in terminal:
            lines.append(f"  LOST point: dispatch "
                         f"#{s.get('dispatch', '?')} "
                         f"{s.get('scheme', '?')} seed "
                         f"{s.get('seed', '?')} -- "
                         f"{s.get('error', '?')}")
        if terminal:
            lines.append("  (lost points have no rows in results.jsonl; "
                         "re-run with --resume after fixing the cause)")

    # ---- iteration time (collective-phase records) ------------------------
    phased = [r for r in (records or []) if r.get("iter_makespan")]
    if phased:
        groups: Dict[tuple, List[Dict]] = {}
        order: List[tuple] = []
        for r in phased:
            key = (r.get("scheme"), r.get("phases"))
            if key not in groups:
                groups[key] = []
                order.append(key)
            groups[key].append(r)
        lines.append("")
        lines.append("iteration time (collective-phase campaigns; slots, "
                     "mean over seeds/loads):")
        for scheme, ph in order:
            rs = groups[(scheme, ph)]
            n_it = max(len(r["iter_makespan"]) for r in rs)
            per_it = []
            for i in range(n_it):
                vals = [r["iter_makespan"][i] for r in rs
                        if len(r["iter_makespan"]) > i]
                per_it.append(sum(vals) / len(vals))
            mean = (sum(r.get("iter_time_mean", 0.0) for r in rs)
                    / len(rs))
            per = ", ".join(f"{v:.0f}" for v in per_it)
            lines.append(f"  {str(scheme):<16s} {str(ph):<32s} "
                         f"iter {mean:8.1f}  per-iter [{per}]  "
                         f"({len(rs)} point(s))")

    # ---- top queue trajectories (needs probe-carrying results) -------------
    probed = [r for r in (records or []) if r.get("probe_queue")]
    if probed:
        probed.sort(key=lambda r: r.get("max_queue", 0), reverse=True)
        lines.append("")
        lines.append(f"top queue trajectories (of {len(probed)} probed "
                     f"points; stride {probed[0].get('probe_stride')} "
                     f"slots/char bucket):")
        for r in probed[:max(top, 0)]:
            series = r["probe_queue"]
            peaks = [max(row) if row else 0 for row in series]
            li = peaks.index(max(peaks))
            label = (f"{r.get('scheme', '?')} k={r.get('k', '?')} "
                     f"s{r.get('seed', '?')} layer{li}")
            lines.append(f"  {label:<28s} {sparkline(series[li])} "
                         f"(max {max(peaks):g})")
    elif records is not None:
        lines.append("")
        lines.append("no probe series in results (run with Campaign.probes "
                     "/ --probes to record queue trajectories)")

    return "\n".join(lines)
