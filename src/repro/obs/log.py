"""Structured sweep logging.

Replaces the runner's ad-hoc ``print`` progress with three levels:

* ``quiet``  -- nothing;
* ``info``   -- the default: the plan line, ONE line per fused dispatch
  (:func:`dispatch_line`, rendered from the dispatch's trace span), and a
  final campaign summary;
* ``debug``  -- additionally the per-member apportioned timings and cache
  diagnostics (the pre-structured-logger output, for scripts that watched
  individual grid cells).

A :class:`SweepLogger` writes to a ``sink`` callable (default ``print``),
so tests and embedding scripts can capture lines without touching stdout.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional

_LEVELS = {"quiet": 0, "info": 1, "debug": 2}


class SweepLogger:
    def __init__(self, level: str = "info",
                 sink: Optional[Callable[[str], None]] = None):
        if level not in _LEVELS:
            raise ValueError(f"unknown log level {level!r}; "
                             f"expected one of {sorted(_LEVELS)}")
        self.level = level
        self._sink = sink if sink is not None else print

    def _emit(self, lvl: str, msg: str) -> None:
        if _LEVELS[self.level] >= _LEVELS[lvl]:
            self._sink(msg)

    def info(self, msg: str) -> None:
        self._emit("info", msg)

    def debug(self, msg: str) -> None:
        self._emit("debug", msg)

    @property
    def verbose(self) -> bool:
        return _LEVELS[self.level] >= _LEVELS["debug"]


def dispatch_line(span: Dict, total: int) -> str:
    """The default one-line-per-dispatch progress format, rendered from the
    dispatch's trace span (so log output and trace never disagree)."""
    trees = span.get("trees", [])
    ks = (f"k={trees[0]}" if len(trees) == 1
          else "k={" + ",".join(str(k) for k in trees) + "}")
    bits = [f"[{span['dispatch'] + 1}/{total}]",
            f"{span['engine']:>4s}",
            ",".join(span.get("schemes", [])),
            ks,
            f"x{span['n_points']}",
            f"fill={span.get('pkt_fill', 0.0):.2f}"]
    if "impl" in span:
        bits.append(f"impl={span['impl']}")
    if "slots_run" in span:
        bits.append(f"slots={span['slots_run']}")
    if "wall_s" in span:
        bits.append(f"{span['wall_s']:.2f}s")
    if "compile_s" in span:
        bits.append(f"(compile {span['compile_s']:.2f}s)")
    if span.get("cache") == "hit":
        bits.append("[cached]")
    return "  " + " ".join(bits)
