"""Observability layer for the campaign engine.

Three pieces, all dependency-free (stdlib + numpy only, so both the sweep
stack and the engines can import from here without cycles):

* :mod:`~repro.obs.trace` -- versioned JSONL dispatch traces.  The runner
  emits one structured span per fused megabatch dispatch (plan key, bucket
  population, padding ratios, device fill, wall / compile-vs-execute split,
  compile-cache hits) plus campaign-level bookend spans; spans are
  deterministic modulo the :data:`~repro.obs.trace.TIMING_KEYS` fields.
* :mod:`~repro.obs.probes` -- the opt-in in-simulation probe spec
  (``Campaign.probes``): a fixed (stride, samples) downsampling grid both
  engines use to carry a per-layer queue-occupancy time series out of the
  jitted pipelines without splitting compiled shapes.
* :mod:`~repro.obs.log` -- the structured sweep logger (quiet / info /
  debug) and the one-line-per-dispatch progress format.
* :mod:`~repro.obs.report` -- renders a trace (+ optional results) into the
  ``python -m repro.sweep report`` cost summary.
"""
from .log import SweepLogger, dispatch_line
from .probes import ProbeSpec, QueueProbe, probe_shape
from .report import render_report
from .trace import (TIMING_KEYS, TRACE_SCHEMA, TraceWriter, load_trace,
                    strip_timing)

__all__ = [
    "SweepLogger", "dispatch_line",
    "ProbeSpec", "QueueProbe", "probe_shape",
    "render_report",
    "TIMING_KEYS", "TRACE_SCHEMA", "TraceWriter", "load_trace",
    "strip_timing",
]
