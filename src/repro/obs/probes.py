"""In-simulation queue-occupancy probes.

A :class:`ProbeSpec` asks the engines to carry a downsampled per-layer
queue-occupancy time series out of the jitted pipelines: ``samples`` time
windows of ``stride`` slots each, recording the *maximum* queue length
observed in every window.  Both dimensions are static (baked into the
compiled shape) so an entire campaign still fuses into one dispatch per
pipeline shape -- the series rides the fused batch axis like any other
output.  Time past ``stride * samples`` clamps into the last window, so a
slot budget larger than the probe horizon saturates the tail bucket rather
than recompiling.

Recording window *maxima* (not instantaneous samples) gives the invariant
the tests pin down: the max over a point's probe series equals the engine's
existing scalar ``max_queue`` exactly -- per layer on the fast engine, over
all layers on the loop engine -- because both reduce the identical values.

With ``probes=None`` (the default everywhere) no probe code is generated
and engine outputs are bitwise-identical to pre-probe behavior.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class ProbeSpec:
    """Opt-in queue-occupancy time series: ``samples`` windows of ``stride``
    slots, each recording the window's maximum occupancy."""
    stride: int
    samples: int = 256

    def __post_init__(self):
        if int(self.stride) < 1:
            raise ValueError(f"probe stride must be >= 1, got {self.stride}")
        if int(self.samples) < 1:
            raise ValueError(f"probe samples must be >= 1, "
                             f"got {self.samples}")

    @property
    def horizon_slots(self) -> int:
        """Slots covered before the series clamps into its last window."""
        return int(self.stride) * int(self.samples)


def probe_shape(probes) -> Tuple[int, int]:
    """Normalize a ProbeSpec / (stride, samples) tuple / None into the
    static ``(stride, samples)`` pair the compiled pipelines key on.
    ``(0, 0)`` means probes are off (no probe code is generated)."""
    if probes is None:
        return (0, 0)
    if isinstance(probes, tuple):
        stride, samples = probes
    else:
        stride, samples = probes.stride, probes.samples
    if int(samples) == 0:
        return (0, 0)
    return (int(stride), int(samples))


@dataclasses.dataclass
class QueueProbe:
    """One point's probe output: ``series[layer, window]`` is the maximum
    queue occupancy layer ``layer`` (``net.topology.LAYER_NAMES`` order) saw
    during window ``window`` (``stride`` slots wide; empty windows are 0)."""
    stride: int
    series: np.ndarray                   # (N_LAYERS, samples)

    def layer_max(self) -> np.ndarray:
        """Per-layer maximum over the series (equals the engine's per-layer
        ``max_queue`` scalars on the fast engine)."""
        return np.asarray(self.series).max(axis=1)

    def overall_max(self) -> float:
        """Max over layers and time (equals the engine's ``max_queue``)."""
        return float(np.asarray(self.series).max())
