"""Versioned JSONL dispatch traces.

The campaign runner emits one span per fused megabatch dispatch plus
campaign-level bookends into a :class:`TraceWriter`, which mirrors the
ResultStore's persistence contract: sorted keys, flush-per-line, and -- for
everything except the wall-clock / cache-state fields named in
:data:`TIMING_KEYS` -- byte-deterministic across re-runs of the same
campaign (tested in ``tests/test_obs.py`` via :func:`strip_timing`).

Span kinds (the ``kind`` field):

* ``"plan"``     -- one per campaign, before execution: grid size, dispatch
  and compiled-shape counts, device count, probe spec.  Cost-mode plans
  (``Campaign.planner="cost"``) additionally record the chosen bucket
  policy (``policy``, ``kmap``, ``pkt_exact``), its ``predicted`` cost
  breakdown (padded packet rows, fill, compile charge), the rejected
  ``alternatives``, and -- when calibrated via ``--plan-from-trace`` --
  the ``calibration`` source.
* ``"dispatch"`` -- one per fused megabatch: member population, padding
  ratios (packet rows, batch-row fill, loop slot budget), shard/device
  fill, wall seconds, optional compile-vs-execute split, compile-cache
  hit/miss.  Loop-engine dispatches additionally carry ``"impl"`` -- the
  *resolved* slot-step implementation (``"lax"`` or ``"pallas"``; an
  ``impl="auto"`` campaign records whichever the host selected), so perf
  trajectories can tell kernel runs from inline-lax runs.
* ``"campaign"`` -- one per campaign, after execution: totals, including
  the trace's own cumulative emit overhead (``emit_s``), which is how the
  benchmark measures telemetry cost, and the *realized* packet-row
  padding counters (``pkt_rows_real`` / ``pkt_rows_padded`` /
  ``pkt_fill``) the report sets against a cost-mode plan's prediction.

Robustness spans (the runner's retry / degradation ladder / resume,
``sweep.runner``):

* ``"retry"``    -- a dispatch attempt failed with retry budget left:
  attempt index, error repr, backoff seconds.
* ``"error"``    -- a failure that exhausted its budget, at ``stage``
  ``"megabatch"`` (whole fused dispatch), ``"member"`` (one seed batch
  during degradation) or ``"point"`` (one seed during serial fallback);
  points under a terminal error span produce no result records.
* ``"degrade"``  -- a dispatch that completed only after splitting, at
  ``stage`` ``"member"`` or ``"serial"``.
* ``"resume"``   -- a ``--resume`` run skipping already-complete
  dispatches: how many were kept, how many records were trusted.

Every span carries ``"schema": TRACE_SCHEMA``; readers should skip spans
with a schema they don't know.
"""
from __future__ import annotations

import json
import pathlib
import time
from typing import Dict, List, Optional

import numpy as np

TRACE_SCHEMA = 1

# Fields that legitimately differ between two runs of the same campaign:
# wall-clock measurements and process/compile-cache state.  Golden
# comparisons strip these (strip_timing); everything else in a span is a
# pure function of the campaign spec and the simulation results.
TIMING_KEYS = frozenset({
    "wall_s", "compile_s", "execute_s", "emit_s",
    "cache", "cache_dir", "cache_entries_added",
    # Robustness fields: which attempt failed, with what error, after what
    # backoff is environment-dependent (a transient OOM needn't recur).
    "error", "backoff_s",
})


def strip_timing(span: Dict) -> Dict:
    """A span minus its :data:`TIMING_KEYS` fields (golden comparisons)."""
    return {k: v for k, v in span.items() if k not in TIMING_KEYS}


def _canon(x):
    if isinstance(x, np.floating):
        return float(x)
    if isinstance(x, np.integer):
        return int(x)
    if isinstance(x, np.ndarray):
        return [_canon(v) for v in x.tolist()]
    if isinstance(x, (list, tuple)):
        return [_canon(v) for v in x]
    if isinstance(x, dict):
        return {k: _canon(v) for k, v in x.items()}
    return x


def encode_span(span: Dict) -> str:
    return json.dumps({k: _canon(v) for k, v in span.items()},
                      sort_keys=True)


class TraceWriter:
    """Append-only JSONL span sink (``path=None`` keeps spans in memory).

    ``emit_s`` accumulates the wall time spent inside :meth:`emit` --
    the telemetry layer's own overhead, reported in the final campaign
    span and in ``BENCH_sweep.json``'s telemetry section.

    ``overwrite=False`` appends to an existing file instead of replacing
    it -- the ``--resume`` mode: a resumed campaign's trace keeps the
    crashed run's spans followed by a ``"resume"`` span and the replayed
    tail (traces are an execution log, so unlike ``results.jsonl`` they
    are *not* expected to be byte-identical to an uninterrupted run's).
    """

    def __init__(self, path: Optional[str] = None, overwrite: bool = True):
        self.path = pathlib.Path(path) if path else None
        self.spans: List[Dict] = []
        self.emit_s = 0.0
        self._fh = None
        if self.path:
            self.path.parent.mkdir(parents=True, exist_ok=True)
            if overwrite and self.path.exists():
                self.path.unlink()

    def emit(self, span: Dict) -> Dict:
        t0 = time.perf_counter()
        span = {"schema": TRACE_SCHEMA, **span}
        self.spans.append(span)
        if self.path:
            if self._fh is None:
                self._fh = self.path.open("a")
            self._fh.write(encode_span(span) + "\n")
            self._fh.flush()    # every emitted span is durable on return
        self.emit_s += time.perf_counter() - t0
        return span

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None


def load_trace(path: str) -> List[Dict]:
    """Read a trace JSONL back into its list of spans."""
    with pathlib.Path(path).open() as f:
        return [json.loads(line) for line in f if line.strip()]
