"""Slotted feedback engine (the "loop" simulator).

Complements ``fastsim``: a time-stepped ``lax.while_loop`` simulation carrying
the *feedback* the layered max-plus engine cannot: ECN-marked ACKs (REPS,
PLB), windowed congestion control (MSwift), SACK loss recovery, link failures
with routing-convergence time ``G``, and finite buffers with drops.

Model (one step = one data-packet slot):

  * every queue (5 fat-tree layers, finite capacity) serves one packet/slot;
  * served packets travel ``prop_slots`` and are enqueued at the next stage;
    edge/aggregation port choices follow the scheme (host labels / RR or OFAN
    pointers / (quantized) JSQ on live queue lengths);
  * queues mark ECN on enqueue above the marking threshold and drop when full;
  * deliveries generate ACKs returning after a constant ``ack_delay``.  ACKs
    are assumed never to queue (they are ~1.5% of a slot) but they consume the
    host NIC byte budget: hosts accumulate 'ack debt' and skip a data slot
    when it reaches one packet -- the App.-B interleaving to first order;
  * hosts pace with the ideal fixed-rate CCA at ``rho`` (§4 decoupling;
    ``rho = rho_max`` under failures) or with MSwift;
  * loss recovery: ideal rateless erasure coding (§4) or SACK with reordering
    threshold ``x`` (§8.2).

Failures: dead links black-hole packets silently before the convergence slot
``G``; from ``G`` on, switches use post-failure state (OFAN IWRR over W-ECMP
weights, RR/JSQ over locally-alive ports) and hosts re-draw labels among
valid paths.  Host-adaptive REPS additionally avoids dead paths *before*
convergence because labels that black-hole never return ACKs and hence are
never recycled into the pool -- the paper's key failure-resilience mechanism.
Dynamic fault schedules (``repro.faults.FaultSchedule``) generalize this to
E link-state *epochs*: every link-derived operand carries a leading epoch
axis the loop gathers by current slot, the physical state switching exactly
at each epoch start and the routing state a per-scheme reaction delay later
(host-visible schemes react with ``host_react``, switch-local ones with
``switch_react``); the static (links, g_converge) pair is the one-epoch
special case and stays bitwise-identical.

Dispatch granularities (mirroring ``fastsim``):

  * :func:`simulate` -- one (tree, workload, scheme, cfg, links, G) point,
    one seed;
  * :func:`simulate_batch` -- one point, many seeds, vmapped into a single
    jitted dispatch;
  * :func:`simulate_megabatch` -- many points sharing a pipeline identity
    fused onto one batch axis (scheme tables, DR/OFAN state, SACK
    scoreboards, MSwift cwnd state and buffer occupancy are all vmappable
    operands), optionally ``shard_map``-sharded across devices.

All three are bitwise-identical per point.  Batched variants run ONE
``lax.while_loop`` whose termination is ``jnp.all`` over per-row done flags
(the vmap batching rule for ``while_loop``): rows that finish early get
their slot updates masked out, so padding and co-batched slower rows never
perturb a finished row's state.  Shape padding (packet/flow axes to the
planner's power-of-two buckets, ``host_flows`` columns, OFAN order widths)
is bitwise-safe: pad flows have ``fsize = 0`` and therefore never become
sendable, pad packets are never referenced by any live flow, and padded
``host_flows`` slots rank below every real flow in the host round-robin.

In-loop randomness (rand spraying, JSQ tie-break noise) comes from the
stateless counter streams of :mod:`repro.core.entropy`: every draw is a
pure function of (seed, draw site, *logical* host/packet id, slot, port),
never of array shapes or batch position.  Hosts and packets are dense
prefixes of any padded id space, so a point padded onto a larger tree's
compiled engine -- or onto a fused megabatch axis -- draws bitwise-identical
values, which is what lets rand/JSQ switch schemes cross-tree-size fuse
like every other scheme (padded port columns are masked out of JSQ argmins
via :func:`~._batching.port_pad_penalty`).

Documented approximations (vs. an event-driven byte-level simulator):
  * ACK return time is constant (no ACK queueing);
  * the SACK sender picks retransmit sequence numbers from the receiver
    bitmap directly (its *trigger* is still ACK-driven);
  * same-slot arrivals at a switch are ranked by a consistent arbitration
    order for pointer schemes; JSQ choices within a slot see start-of-slot
    queue lengths.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from .topology import FatTree, LinkState
from .workloads import Workload
from ._batching import (TreePad, pad_tail, pad_to_group_max,
                        port_pad_penalty, pow2_bucket, rank_by, shard_pad)
from ..core.lb_schemes import LBScheme, precompute_host_choices
from ..core import entropy as ent
from ..core import ofan as ofan_mod
from ..obs.probes import QueueProbe, probe_shape

INT = jnp.int32


@dataclasses.dataclass
class LoopSimResult:
    delivered_slot: np.ndarray      # per-packet first-delivery slot (-1 never)
    flow_complete_slot: np.ndarray  # per-flow full-message-ACKed slot
    flow_data_done_slot: np.ndarray  # per-flow all-data-delivered slot
    cct_slots: float                # data CCT (max flow_data_done)
    cct_acked_slots: float          # ACK-complete CCT
    drops: int
    retransmissions: int
    max_queue: int
    avg_queue: float
    finished: bool
    mean_cwnd: float
    # Queue-occupancy time series (5 layers x samples windows), present only
    # when the point ran with a probe spec (repro.obs.probes); its max over
    # layers and time equals ``max_queue`` exactly.
    probe: Optional[QueueProbe] = None


@dataclasses.dataclass(frozen=True)
class LoopConfig:
    cca: str = "ideal"             # 'ideal' | 'mswift'
    loss: str = "erasure"          # 'erasure' | 'sack'
    rho: float = 1.0               # ideal CCA rate (rho_max under failures)
    prop_slots: int = 12
    ack_delay: int = 74            # return path: ~6*prop + serialization
    buffer_pkts: int = 195
    ecn_frac: float = 0.5          # marking threshold (fraction of buffer)
    sack_thresh: int = 32          # reordering threshold x (§8.2)
    rto_slots: int = 400
    ack_cost: float = 0.0206       # ack bytes / slot bytes (86/4178)
    bdp_pkts: int = 150
    max_slots: int = 200_000
    plb_alpha: int = 64            # PLB: min packets between label changes
    plb_beta: float = 0.4          # PLB: EWMA mark fraction trigger
    # MSwift (App. H): target delay = BDP + queueing component.
    sw_target_slots: float = 180.0
    sw_ai: float = 1.0
    sw_beta: float = 0.8
    sw_max_cwnd: float = 384.0
    # Engine body implementation: 'lax' (inline while_loop body), 'pallas'
    # (fused slot-step kernels, repro.kernels.slot_step; interpret-mode
    # off-TPU) or 'auto' (pallas where it wins: on TPU, or under
    # REPRO_PALLAS=interpret).  Bitwise-identical on integer outputs.
    impl: str = "lax"


def static_config(cfg: LoopConfig) -> LoopConfig:
    """The compile-relevant normalization of a LoopConfig.

    ``rho`` and ``max_slots`` ride as per-row *operands* in the jitted
    engine (so an rho_max axis or differing slot budgets share one
    executable), and the timing constants ``prop_slots``/``ack_delay``
    bucket to the next power of two: they only set the ``DELAY``/``ADELAY``
    ring-buffer *shapes*, while every ring index is taken modulo the
    point's real constants (per-row operands), so a timing sweep shares
    one compiled pipeline per bucket instead of compiling per point --
    rows past a point's real modulus stay at their init value and are
    never read, keeping results bitwise-identical to serial.  Every other
    field is baked into the compiled pipeline -- either through shapes
    (``buffer_pkts``) or through Python branches (``cca``, ``loss``,
    ``impl``).  Two points whose ``static_config`` are equal can fuse into
    one megabatch dispatch (mixed-``impl`` grids therefore plan one
    dispatch per impl).
    """
    return dataclasses.replace(
        cfg, rho=0.0, max_slots=0,
        prop_slots=pow2_bucket(max(int(cfg.prop_slots), 1)),
        ack_delay=pow2_bucket(max(int(cfg.ack_delay), 1)))


@dataclasses.dataclass(frozen=True)
class _Static:
    n: int; h: int; mid: int; F: int; P: int; Fh: int
    n_edges: int; n_aggs: int; n_pods: int
    edge_mode: str; agg_mode: str
    quanta: Optional[Tuple[float, ...]]
    adaptive_host: bool
    plb: bool
    cfg: LoopConfig                 # normalized via static_config()
    # Probe grid (stride, samples); (0, 0) = probes off.  Static: the series
    # buffer shape is baked into the compiled engine, so probed campaigns
    # still fuse into one dispatch per pipeline shape.
    probe: Tuple[int, int] = (0, 0)


@dataclasses.dataclass
class LoopPlan:
    """Seed-independent preparation of one (tree, workload, scheme, cfg,
    links, g_converge | fault) simulation point.

    Splitting this out of :func:`simulate` is what makes seed replication
    and point fusion batchable: everything here is identical across seeds,
    while :func:`_draw_seed_inputs` produces the per-seed operands that
    become the leading ``vmap`` axis in :func:`simulate_batch` /
    :func:`simulate_megabatch`.

    ``ep_links`` is the fault-epoch timeline (one entry, the static link
    state, when no schedule was given); every link-derived table carries a
    leading epoch axis the engine gathers by current slot.  ``pv`` mirrors
    it: one per-flow path-validity stack per epoch (or None).
    """
    tree: FatTree
    wl: Workload
    scheme: LBScheme
    cfg: LoopConfig
    links: LinkState                 # epoch-0 link state
    ep_links: list
    any_fail: bool
    pv: Optional[list]
    fsrc: np.ndarray
    fdst: np.ndarray
    static: _Static
    tables: dict

    @property
    def n_epochs(self) -> int:
        return len(self.ep_links)


def _prepare(tree: FatTree, wl: Workload, scheme: LBScheme,
             cfg: LoopConfig = LoopConfig(),
             links: Optional[LinkState] = None,
             g_converge: Optional[int] = None, probes=None,
             fault=None) -> LoopPlan:
    """Host-side precomputation shared by every seed of a simulation point.

    ``fault`` (a ``repro.faults.FaultSchedule``) is the dynamic alternative
    to the static ``links``/``g_converge`` pair: it compiles to an epoch
    timeline whose link states become stacked, slot-gathered operands, with
    per-scheme reaction delays replacing the single convergence slot.  The
    static pair lowers to the identical machinery with one epoch starting
    at slot 0 and reacting at ``g_converge``.
    """
    if cfg.impl not in ("lax", "pallas", "auto"):
        raise ValueError(f"LoopConfig.impl {cfg.impl!r}: expected "
                         f"'lax', 'pallas' or 'auto'")
    h = tree.half
    n = tree.n_hosts
    P = wl.n_packets
    F = wl.n_flows
    mid = tree.queues_per_mid_layer

    fsrc = wl.flow_src.astype(np.int32)
    fdst = wl.flow_dst.astype(np.int32)
    fsize = wl.flow_size.astype(np.int32)
    pkt_base = np.zeros(F + 1, dtype=np.int64)
    np.cumsum(fsize, out=pkt_base[1:])
    if not (wl.flow == np.repeat(np.arange(F), fsize)).all():
        raise ValueError("loopsim expects flow-contiguous packet layout")
    # Per-flow start gate (collective-phase schedules): a flow may not send
    # before its phase's start slot.  All-zero (every static workload) is
    # bitwise-inert in the engine's send mask.
    f_start = (np.zeros(F, dtype=np.int32) if wl.flow_start is None
               else np.asarray(wl.flow_start, dtype=np.int32))

    fp1 = tree.host_pod(fsrc).astype(np.int32)
    fe1 = tree.host_edge(fsrc).astype(np.int32)
    fp2 = tree.host_pod(fdst).astype(np.int32)
    fe2 = tree.host_edge(fdst).astype(np.int32)
    f_inter = fp1 != fp2
    f_leaves = f_inter | (fe1 != fe2)

    Fh = int(np.bincount(fsrc, minlength=n).max()) if F else 1
    host_flows = np.full((n, Fh), -1, dtype=np.int32)
    cnt = np.zeros(n, dtype=np.int64)
    for f, sh in enumerate(fsrc.tolist()):
        host_flows[sh, cnt[sh]] = f
        cnt[sh] += 1

    # ---- fault-epoch timeline ---------------------------------------------
    # Static (links, g_converge) lowers to a single epoch starting at slot 0
    # whose routing reacts at g_converge; a FaultSchedule compiles to E
    # epochs with per-scheme reaction delays.  Every link-derived table
    # below carries a leading epoch axis the engine gathers by slot.
    if fault is not None:
        if links is not None or g_converge is not None:
            raise ValueError("pass either fault= or links=/g_converge=, "
                             "not both")
        comp = fault.compile(tree)
        ep_links = list(comp.links)
        ep_start = np.asarray(comp.ep_start, np.int32)
        r_start = comp.react_starts(scheme.reaction_class())
    else:
        ep_links = [links if links is not None else LinkState.all_up(tree)]
        ep_start = np.zeros(1, np.int32)
        r_start = np.asarray(
            [g_converge if g_converge is not None else 2**30], np.int32)
    E = len(ep_links)
    links = ep_links[0]
    any_fail = any(l.any_failure() for l in ep_links)

    alive = np.stack([np.concatenate([
        l.ea.reshape(-1),                           # UP_E (pod,edge,agg)
        l.ac.reshape(-1),                           # UP_A (pod,agg,sub)
        l.ac.reshape(-1),                           # DN_C (pod,agg,sub)
        np.transpose(l.ea, (0, 2, 1)).reshape(-1),  # DN_A (pod,agg,edge)
        np.ones(n, bool)]) for l in ep_links])

    # Per-(switch, destination-group) valid port sets (W-ECMP reachability):
    # used by switch schemes after routing convergence.  Edge switches group
    # destinations by destination edge switch, aggregation switches by
    # destination pod (the same consolidation OFAN exploits).
    n_edges = tree.n_edge_switches
    n_aggs = tree.n_agg_switches

    def _port_lists(valid3d):  # (S, Gd, h) bool -> padded lists + counts
        S, Gd, _ = valid3d.shape
        ports = np.zeros((S * Gd, h), np.int32)
        cnts = np.zeros(S * Gd, np.int32)
        flat = valid3d.reshape(S * Gd, h)
        for i in range(S * Gd):
            alive_p = np.flatnonzero(flat[i])
            if len(alive_p) == 0:
                alive_p = np.arange(h)
            reps = int(np.ceil(h / len(alive_p)))
            ports[i] = np.tile(alive_p, reps)[:h]
            cnts[i] = len(alive_p)
        return ports, cnts

    def _wecmp_valid(l):
        # edge: valid uplink a for (src edge (p1,e1), dst edge (p2,e2))
        valid_e = np.zeros((n_edges, n_edges, h), bool)
        for se in range(n_edges):
            sp, sei = divmod(se, h)
            for de in range(n_edges):
                dp, dei = divmod(de, h)
                if se == de:
                    valid_e[se, de] = l.ea[sp, sei, :]
                    continue
                valid_e[se, de] = l.wecmp_edge_weights(sp, sei, dp, dei) > 0
        # agg: valid core sub-link c for (agg (p,a), dst pod)
        valid_a = np.zeros((n_aggs, tree.n_pods, h), bool)
        for ga in range(n_aggs):
            sp, ai = divmod(ga, h)
            for dp in range(tree.n_pods):
                if dp == sp:
                    valid_a[ga, dp] = l.ac[sp, ai, :]  # unused southbound
                else:
                    valid_a[ga, dp] = l.ac[sp, ai, :] & l.ac[dp, ai, :]
        return valid_e, valid_a

    e_ports = np.zeros((E, n_edges * n_edges, h), np.int32)
    e_pcnt = np.zeros((E, n_edges * n_edges), np.int32)
    a_ports = np.zeros((E, n_aggs * tree.n_pods, h), np.int32)
    a_pcnt = np.zeros((E, n_aggs * tree.n_pods), np.int32)
    e_dead = np.zeros((E, n_edges, n_edges, h), bool)
    a_dead = np.zeros((E, n_aggs, tree.n_pods, h), bool)
    for e_i, l in enumerate(ep_links):
        valid_e, valid_a = _wecmp_valid(l)
        e_ports[e_i], e_pcnt[e_i] = _port_lists(valid_e)
        a_ports[e_i], a_pcnt[e_i] = _port_lists(valid_a)
        e_dead[e_i] = ~valid_e
        a_dead[e_i] = ~valid_a

    # Path-validity matrices (seed-independent, rng-free): consumed by the
    # per-seed host-choice precompute and the REPS/PLB valid-label lists.
    # One (F, h, h) stack per epoch.
    pv = None
    if any_fail and (scheme.edge_mode == "pre" or scheme.adaptive_host):
        pv = [np.stack([l.path_matrix(int(s_), int(d_))
                        for s_, d_ in zip(fsrc, fdst)]) for l in ep_links]

    # Valid-path list per flow and epoch: post-convergence the W-ECMP rehash
    # maps any flow label onto an alive path (paper §5.2).  REPS/PLB labels.
    f_vpaths = np.tile(np.arange(h * h, dtype=np.int32), (E, F, 1))
    f_vcnt = np.full((E, F), h * h, dtype=np.int32)
    if any_fail and scheme.adaptive_host:
        for e_i in range(E):
            for fi in range(F):
                cand = np.flatnonzero(pv[e_i][fi].reshape(-1))
                if len(cand) == 0:
                    cand = np.arange(h * h)
                reps = int(np.ceil(h * h / len(cand)))
                f_vpaths[e_i, fi] = np.tile(cand, reps)[:h * h]
                f_vcnt[e_i, fi] = len(cand)

    static = _Static(
        n=n, h=h, mid=mid, F=F, P=P, Fh=Fh,
        n_edges=n_edges, n_aggs=n_aggs, n_pods=tree.n_pods,
        edge_mode=scheme.edge_mode, agg_mode=scheme.agg_mode,
        quanta=(tuple(scheme.quanta) if scheme.edge_mode == "jsq_quant"
                else None),
        adaptive_host=scheme.adaptive_host,
        plb=scheme.name == "host_flowlet_ar",
        cfg=static_config(cfg),
        probe=probe_shape(probes))

    tables = dict(
        fsrc=fsrc, fdst=fdst, fsize=fsize, pkt_base=pkt_base,
        fp1=fp1, fe1=fe1, fp2=fp2, fe2=fe2, f_start=f_start,
        f_inter=f_inter, f_leaves=f_leaves, host_flows=host_flows,
        alive=alive, ep_start=ep_start, r_start=r_start,
        e_ports=e_ports, e_pcnt=e_pcnt, a_ports=a_ports, a_pcnt=a_pcnt,
        e_dead=e_dead, a_dead=a_dead,
        f_vpaths=f_vpaths, f_vcnt=f_vcnt,
        rho=np.float32(cfg.rho), max_slots=np.int32(cfg.max_slots),
        # Logical port count: an operand, so a point padded onto a larger
        # tree's compiled engine still decodes labels / rotates pointers
        # over its own k/2 ports.
        h_log=np.int32(h),
        # Real timing constants: the compiled engine sizes its delay rings
        # from the pow2-bucketed static_config but indexes them modulo
        # these per-row values, so a timing sweep rides one compile.
        prop_slots=np.int32(cfg.prop_slots),
        ack_delay=np.int32(cfg.ack_delay),
    )
    return LoopPlan(tree=tree, wl=wl, scheme=scheme, cfg=cfg, links=links,
                    ep_links=ep_links, any_fail=any_fail, pv=pv,
                    fsrc=fsrc, fdst=fdst, static=static, tables=tables)


def _draw_seed_inputs(plan: LoopPlan, seed: int) -> dict:
    """Per-seed randomness, drawn in the exact order the pre-batching engine
    used so results stay bit-identical run-to-run and serial-to-batched.

    Fault epochs extend the sequential ``np.random`` stream *in epoch
    order* at the exact positions the static path draws its converged
    state: stale host choices first, then one converged draw per epoch,
    then the label pool / RR starts, then the stale OFAN tables, then one
    converged OFAN build per epoch.  A one-epoch plan therefore consumes
    the identical stream as the pre-schedule engine (bitwise goldens), and
    a failure-free plan aliases its converged state to the stale draw
    without consuming anything, as before.
    """
    tree, wl, scheme = plan.tree, plan.wl, plan.scheme
    h = tree.half
    P = wl.n_packets
    E = plan.n_epochs
    rng = np.random.default_rng(seed)
    key_lo, key_hi = ent.key_words(seed)

    a_stale = c_stale = a_conv = c_conv = None
    if scheme.edge_mode == "pre":
        pre_kw = dict(tree=tree, flow=wl.flow, seq=wl.seq, flow_src=plan.fsrc,
                      flow_dst=plan.fdst, rng=rng)
        a_stale, c_stale = precompute_host_choices(scheme, path_valid=None,
                                                   **pre_kw)
        if plan.any_fail:
            per_ep = [precompute_host_choices(scheme, path_valid=pv_e,
                                              **pre_kw) for pv_e in plan.pv]
            a_conv = np.stack([a for a, _ in per_ep])
            c_conv = np.stack([c for _, c in per_ep])
        else:
            a_conv = np.stack([a_stale] * E)
            c_conv = np.stack([c_stale] * E)

    rand_pool = rng.integers(0, h * h, size=65536).astype(np.int32)

    ofan_stale = None
    ofan_eps: list = []
    rr_starts_e = rng.integers(0, h, tree.n_edge_switches).astype(np.int32)
    rr_starts_a = rng.integers(0, h, tree.n_agg_switches).astype(np.int32)
    if scheme.edge_mode == "ofan":
        ofan_stale = ofan_mod.build_tables(tree, rng, links=None)
        ofan_eps = ([ofan_mod.build_tables(tree, rng, links=l)
                     for l in plan.ep_links]
                    if plan.any_fail else [ofan_stale] * E)

    return dict(
        a_stale=_z(a_stale, P), c_stale=_z(c_stale, P),
        a_conv=_ze(a_conv, E, P), c_conv=_ze(c_conv, E, P),
        rand_pool=rand_pool,
        rr_starts_e=rr_starts_e, rr_starts_a=rr_starts_a,
        ofan_e_orders=_tbl(ofan_stale, ofan_eps, "edge_orders", E),
        ofan_e_starts=_tbl(ofan_stale, ofan_eps, "edge_starts", E),
        ofan_e_len=_tbl(ofan_stale, ofan_eps, "edge_len", E),
        ofan_a_orders=_tbl(ofan_stale, ofan_eps, "agg_orders", E),
        ofan_a_starts=_tbl(ofan_stale, ofan_eps, "agg_starts", E),
        ofan_a_len=_tbl(ofan_stale, ofan_eps, "agg_len", E),
        # Counter-stream key words: the in-loop randomness operands.  Draws
        # are pure functions of (seed, site, logical id, slot), so they ride
        # any padding/batching unchanged (core.entropy).
        seed_lo=key_lo, seed_hi=key_hi,
    )


def _postprocess(out: dict, cfg: LoopConfig, n_packets: int,
                 n_flows: int, probes=None) -> LoopSimResult:
    """Assemble a LoopSimResult from one (unbatched) engine output tree,
    slicing off any shape-bucketing padding."""
    comp = out["flow_complete"][:n_flows]
    data_done = out["f_data_done"][:n_flows]
    f_cwnd = np.asarray(out["f_cwnd"][:n_flows], np.float32)
    finished = bool((comp >= 0).all())
    # Zero-flow workloads (msg_packets=0, empty phases): vacuously finished
    # at slot 0 -- the empty maxima below would raise.
    return LoopSimResult(
        delivered_slot=out["delivered_slot"][:n_packets],
        flow_complete_slot=comp,
        flow_data_done_slot=data_done,
        cct_slots=0.0 if n_flows == 0
        else float(data_done.max()) if (data_done >= 0).all()
        else float(cfg.max_slots),
        cct_acked_slots=0.0 if n_flows == 0
        else float(comp.max()) if finished else float(cfg.max_slots),
        drops=int(out["drops"]),
        retransmissions=int(out["rtx"]),
        max_queue=int(out["max_q"]),
        avg_queue=float(out["sum_q"]) / max(float(out["enq_events"]), 1.0),
        finished=finished,
        mean_cwnd=float(f_cwnd.mean()) if n_flows else 0.0,
        probe=(QueueProbe(probe_shape(probes)[0], np.asarray(out["q_probe"]))
               if "q_probe" in out else None),
    )


def simulate(tree: FatTree, wl: Workload, scheme: LBScheme,
             cfg: LoopConfig = LoopConfig(), seed: int = 0,
             links: Optional[LinkState] = None,
             g_converge: Optional[int] = None,
             probes=None, fault=None) -> LoopSimResult:
    """Run one collective on the slotted engine.

    ``links``: failed-link state (None = all up).  ``g_converge``: slot at
    which routing state converges; None => G = infinity (never converges).
    ``fault``: a ``repro.faults.FaultSchedule`` -- the dynamic alternative
    to the (links, g_converge) pair (mutually exclusive with it).
    """
    if wl.n_packets == 0:
        # The slotted engine gathers per-packet state each step, which
        # needs a packet axis of at least 1.  An all-degenerate workload
        # (msg_packets=0, or a phase schedule whose collectives are all
        # n<=1/zero-byte) runs as a one-point megabatch padded to one
        # inert packet row -- bitwise what the fused runner path does.
        return simulate_megabatch(
            [(tree, wl, scheme, cfg, [seed], links, g_converge, fault)],
            npk_pad=1, probes=probes)[0][0]
    plan = _prepare(tree, wl, scheme, cfg, links, g_converge, probes=probes,
                    fault=fault)
    tables = {**plan.tables, **_draw_seed_inputs(plan, seed)}
    out = jax.tree_util.tree_map(np.asarray, _run(plan.static, tables))
    return _postprocess(out, cfg, wl.n_packets, wl.n_flows, probes)


def simulate_batch(tree: FatTree, wl: Workload, scheme: LBScheme,
                   seeds, cfg: LoopConfig = LoopConfig(),
                   links: Optional[LinkState] = None,
                   g_converge: Optional[int] = None, probes=None,
                   fault=None) -> list:
    """Run one simulation point for many seeds as a single vmapped dispatch.

    Per-seed randomness (host labels, spray entropy, RR starts, OFAN
    rotation orders) is drawn host-side exactly as :func:`simulate` draws it
    and stacked onto a leading batch axis; seed-independent operands are
    broadcast.  The fused ``while_loop`` steps until every row's flows have
    completed (or hit ``max_slots``); finished rows freeze.  Results are
    bitwise-identical, per seed, to serial :func:`simulate` calls.
    """
    seeds = list(seeds)
    if not seeds:
        return []
    plan = _prepare(tree, wl, scheme, cfg, links, g_converge, probes=probes,
                    fault=fault)
    per_seed = [_draw_seed_inputs(plan, s) for s in seeds]
    stacked = jax.tree_util.tree_map(lambda *xs: np.stack(xs), *per_seed)
    out = jax.tree_util.tree_map(
        np.asarray, _run(plan.static, {**plan.tables, **stacked},
                         batch="seed"))
    return [_postprocess(jax.tree_util.tree_map(lambda x: x[i], out),
                         cfg, wl.n_packets, wl.n_flows, probes)
            for i in range(len(seeds))]


def _pipeline_identity(plan: LoopPlan) -> _Static:
    """Everything two plans must agree on to share one megabatched dispatch:
    scheme modes and the static LoopConfig fields.  Packet/flow/host-flow
    axes are padded, and tree dims pad to the group's largest k for EVERY
    scheme -- in-loop randomness comes from counter streams keyed on logical
    ids (``core.entropy``), so the draws survive padding."""
    return dataclasses.replace(plan.static, P=0, F=0, Fh=0, n=0, h=0, mid=0,
                               n_edges=0, n_aggs=0, n_pods=0)


def _repad_tables(st: dict, plan: LoopPlan, tp: TreePad) -> dict:
    """Re-lay one point's switch-/queue-id-indexed operands into the padded
    tree's id space (:class:`~._batching.TreePad`).  Host ids and per-flow
    coordinates are unchanged: real hosts are a dense prefix of the padded
    host space, and real (pod, edge/agg, port) coordinates are sparse in
    the padded switch/queue id spaces.  Padded queues stay empty (no real
    packet ever routes to one) and padded table rows are never indexed by a
    live flow, so dynamics match the standalone run exactly."""
    if tp.noop:
        return st
    pt = tp.padded
    st = dict(st)
    n_sw = pt.n_edge_switches            # == n_agg_switches
    mid_r = plan.tree.queues_per_mid_layer
    mid_p = pt.queues_per_mid_layer
    E = st["alive"].shape[0]

    # Per-queue aliveness (epoch-stacked): 4 mid layers scatter through the
    # queue-id map; padded queues read True, which is inert (nothing is
    # enqueued there).
    alive = np.ones((E, 4 * mid_p + pt.n_hosts), dtype=bool)
    for L in range(4):
        alive[:, L * mid_p + tp.mid] = st["alive"][:, L * mid_r:
                                                   (L + 1) * mid_r]
    st["alive"] = alive

    st["host_flows"] = pad_tail(st["host_flows"], 0, pt.n_hosts, fill=-1)
    # Valid-label lists keep their raw h_log-encoded entries; only the pool
    # axis widens (entries past a flow's own f_vcnt are never indexed).
    st["f_vpaths"] = pad_tail(st["f_vpaths"], 2, pt.half * pt.half)
    # W-ECMP valid-port lists: (switch, dst-group) rows scatter; the port
    # axis pads with zeros that sit beyond every row's count operand.
    # All carry a leading epoch axis, so table axes shift by one.
    st["e_ports"] = pad_tail(
        tp.scatter(st["e_ports"], tp.edge_pair, n_sw * n_sw, axis=1),
        2, pt.half)
    st["e_pcnt"] = tp.scatter(st["e_pcnt"], tp.edge_pair, n_sw * n_sw,
                              axis=1, fill=1)
    st["a_ports"] = pad_tail(
        tp.scatter(st["a_ports"], tp.agg_pod, n_sw * pt.n_pods, axis=1),
        2, pt.half)
    st["a_pcnt"] = tp.scatter(st["a_pcnt"], tp.agg_pod, n_sw * pt.n_pods,
                              axis=1, fill=1)
    st["e_dead"] = pad_tail(tp.scatter(
        tp.scatter(st["e_dead"], tp.switch, n_sw, axis=1, fill=True),
        tp.switch, n_sw, axis=2, fill=True), 3, pt.half, fill=True)
    st["a_dead"] = pad_tail(pad_tail(
        tp.scatter(st["a_dead"], tp.switch, n_sw, axis=1, fill=True),
        2, pt.n_pods, fill=True), 3, pt.half, fill=True)
    return st


def _repad_seed(d: dict, plan: LoopPlan, tp: TreePad) -> dict:
    """Scatter the per-seed switch tables (RR starts, OFAN pointer tables)
    into the padded tree's id space."""
    if tp.noop:
        return d
    pt = tp.padded
    d = dict(d)
    n_sw = pt.n_edge_switches
    d["rr_starts_e"] = tp.scatter(d["rr_starts_e"], tp.switch, n_sw)
    d["rr_starts_a"] = tp.scatter(d["rr_starts_a"], tp.switch, n_sw)
    if plan.scheme.edge_mode == "ofan":
        for pre, idx, n_ptr in (("ofan_e", tp.edge_pair, n_sw * n_sw),
                                ("ofan_a", tp.agg_pod, n_sw * pt.n_pods)):
            for suf in ("orders", "starts", "len"):
                d[f"{pre}_{suf}"] = tp.scatter(d[f"{pre}_{suf}"], idx,
                                               n_ptr, axis=1)
    return d


# Seed-independent per-point operands that carry a padded flow/packet axis.
# (f_start pads with 0; pad flows have fsize 0 and complete at slot 0, so
# their gate value never matters.)
_F_PAD0 = ("fsrc", "fdst", "fsize", "fp1", "fe1", "fp2", "fe2", "f_start")


def simulate_megabatch(items, *, npk_pad: Optional[int] = None,
                       n_shards=1, k_pad: Optional[int] = None,
                       probes=None) -> list:
    """Run many loop-engine simulation points as ONE fused, jitted dispatch.

    ``items`` is a sequence of ``(tree, wl, scheme, cfg, seeds, links,
    g_converge)`` tuples whose points lower to the same compiled engine
    (equal :func:`_pipeline_identity`: scheme modes and static LoopConfig
    fields -- ``rho``, ``max_slots`` and ``g_converge`` ride as per-row
    operands).  Per-seed inputs are drawn host-side exactly as
    :func:`simulate` draws them, padded to shared shapes (packet arrays up
    to ``npk_pad``, flow arrays and ``host_flows`` columns to group-wide
    maxima, OFAN order widths to the group maximum, switch/queue tables
    scattered into the padded ``k_pad`` tree's id space; pad flows have
    size 0 and are inert, padded switches and queues never see traffic),
    stacked onto one fused (scheme x load x failure x seed) batch axis, and
    executed by a single vmapped -- and, with ``n_shards > 1`` (or
    ``"auto"``), ``shard_map``-sharded -- dispatch whose ``while_loop``
    terminates once every row is done.

    ``k_pad`` (default: the largest tree among the items) is the fat-tree
    size every member's topology operands pad to; the planner passes the
    k-bucket head so campaigns sweeping tree size share one compile.
    Tree-size padding holds for EVERY scheme, including rand/JSQ switch
    modes: their in-loop draws come from the counter streams of
    ``core.entropy`` (keyed on seed, draw site, logical host/packet id and
    slot), so padding extends the id range the stream is evaluated over
    without perturbing any real entity's draws, and padded JSQ port columns
    are masked out of the argmin (``_batching.port_pad_penalty``).

    Items may also carry a trailing ``fault`` entry (a
    ``repro.faults.FaultSchedule``; 8-tuples) mixed freely with 7-tuple
    static items: fault-epoch axes pad to the group maximum (pad epochs
    repeat the last real epoch and start at an unreachable sentinel slot,
    so they are bitwise-inert), which is how static and flapping campaign
    rows fuse into one dispatch.

    Returns one list of :class:`LoopSimResult` per item (aligned with its
    ``seeds``); every result is bitwise-identical to the standalone
    :func:`simulate` call with the same arguments (tested in
    ``tests/test_loopsim.py`` and ``tests/test_differential.py``).
    """
    items = [(it[0], it[1], it[2], it[3], list(it[4]), it[5], it[6],
              it[7] if len(it) > 7 else None) for it in items]
    if not items or all(not it[4] for it in items):
        return [[] for _ in items]

    plans = [_prepare(t, w, s, c, l, g, probes=probes, fault=fz)
             for (t, w, s, c, _, l, g, fz) in items]
    idents = {_pipeline_identity(p) for p in plans}
    if len(idents) > 1:
        raise ValueError(f"megabatch items span {len(idents)} pipeline "
                         f"identities; group by tree size, scheme loop "
                         f"shape and static LoopConfig first")

    k_max = max(p.tree.k for p in plans)
    k_pad = k_max if k_pad is None else max(int(k_pad), k_max)
    tree_pad = next((p.tree for p in plans if p.tree.k == k_pad),
                    FatTree(k_pad))
    pads = [TreePad(p.tree, tree_pad) for p in plans]

    P_max = max(p.wl.n_packets for p in plans)
    # The engine's per-step packet gathers need a non-empty packet axis
    # even when every member is degenerate (all-empty phase schedules).
    npk_pad = max(P_max if npk_pad is None else max(int(npk_pad), P_max), 1)
    F_pad = max(p.wl.n_flows for p in plans)
    Fh_pad = max(p.static.Fh for p in plans)
    E_pad = max(p.n_epochs for p in plans)

    elems: list = []          # merged (static + per-seed) dicts, padded
    spans: list = []          # (item index, seed) per fused-axis element
    for i, ((tree, wl, scheme, cfg, seeds, links, g, fz), plan) in enumerate(
            zip(items, plans)):
        st = _repad_tables(plan.tables, plan, pads[i])
        # Fault-epoch padding: tables repeat their last real epoch; the
        # start operands pad with an unreachable sentinel slot, so the
        # epoch/reaction counters never index a pad epoch -- padded rows
        # are bitwise-inert, letting static and flapping points fuse.
        for k in ("alive", "e_ports", "e_pcnt", "a_ports", "a_pcnt",
                  "e_dead", "a_dead", "f_vpaths", "f_vcnt"):
            st[k] = _pad_epochs(st[k], E_pad)
        for k in ("ep_start", "r_start"):
            st[k] = pad_tail(st[k], 0, E_pad, fill=2**30)
        # Flow-axis padding: pad flows have fsize 0, so they complete at the
        # first slot, never send, and never reference a packet; pkt_base is
        # edge-padded so searchsorted still lands real packets on real flows.
        st["pkt_base"] = pad_tail(st["pkt_base"], 0, F_pad + 1,
                                  fill=int(st["pkt_base"][-1]))
        for k in _F_PAD0:
            st[k] = pad_tail(st[k], 0, F_pad)
        st["f_inter"] = pad_tail(st["f_inter"], 0, F_pad, fill=False)
        st["f_leaves"] = pad_tail(st["f_leaves"], 0, F_pad, fill=False)
        st["f_vpaths"] = pad_tail(st["f_vpaths"], 1, F_pad)
        st["f_vcnt"] = pad_tail(st["f_vcnt"], 1, F_pad, fill=1)
        # Padded host_flows columns hold -1 and rank below every real flow
        # in the host round-robin, so picks (and hence all sends) match the
        # unpadded point exactly.
        st["host_flows"] = pad_tail(st["host_flows"], 1, Fh_pad, fill=-1)
        for s in seeds:
            d = {**st, **_repad_seed(_draw_seed_inputs(plan, s), plan,
                                     pads[i])}
            for k in ("a_stale", "c_stale"):
                d[k] = pad_tail(d[k], 0, npk_pad)
            for k in ("a_conv", "c_conv"):
                d[k] = pad_tail(_pad_epochs(d[k], E_pad), 1, npk_pad)
            # OFAN stacks lead with the [stale, epoch...] axis: 1 + E.
            for k in ("ofan_e_orders", "ofan_e_starts", "ofan_e_len",
                      "ofan_a_orders", "ofan_a_starts", "ofan_a_len"):
                d[k] = _pad_epochs(d[k], 1 + E_pad)
            elems.append(d)
            spans.append((i, s))

    # OFAN rotation orders are padded to the group-wide width; entries past
    # a row's own table length are never indexed (pointers wrap modulo the
    # per-group length operand).
    for key in ("ofan_e_orders", "ofan_a_orders"):
        for d, arr in zip(elems, pad_to_group_max([d[key] for d in elems])):
            d[key] = arr

    stacked = jax.tree_util.tree_map(lambda *xs: np.stack(xs), *elems)

    n_batch = len(elems)
    if n_shards == "auto":
        n_shards = max(1, min(len(jax.devices()), n_batch))
    n_shards = int(n_shards)
    stacked = shard_pad(stacked, n_batch, n_shards)

    static = dataclasses.replace(
        plans[0].static, P=npk_pad, F=F_pad, Fh=Fh_pad,
        n=tree_pad.n_hosts, h=tree_pad.half,
        mid=tree_pad.queues_per_mid_layer,
        n_edges=tree_pad.n_edge_switches, n_aggs=tree_pad.n_agg_switches,
        n_pods=tree_pad.n_pods)
    out = jax.tree_util.tree_map(
        np.asarray, _run(static, stacked, batch="mega", n_shards=n_shards))

    results = [dict() for _ in items]
    for b, (i, s) in enumerate(spans):
        out_b = jax.tree_util.tree_map(lambda x: x[b], out)
        results[i][s] = _postprocess(out_b, items[i][3],
                                     plans[i].wl.n_packets,
                                     plans[i].wl.n_flows, probes)
    return [[results[i][s] for s in seeds]
            for i, (_, _, _, _, seeds, _, _, _) in enumerate(items)]


def _pad_epochs(x, e_pad, axis=0):
    """Pad an epoch-stacked table to ``e_pad`` epochs by repeating its last
    real epoch (inert: the sentinel-padded start operands guarantee the
    epoch counters never index past the real epochs)."""
    E = x.shape[axis]
    if E >= e_pad:
        return x
    last = np.take(x, [E - 1], axis=axis)
    return np.concatenate([x, np.repeat(last, e_pad - E, axis=axis)],
                          axis=axis)


def _z(x, P):
    return np.zeros(P, np.int32) if x is None else x.astype(np.int32)


def _ze(x, E, P):
    return np.zeros((E, P), np.int32) if x is None else x.astype(np.int32)


def _tbl(stale, eps, attr, n_ep):
    """Stack OFAN tables as [stale, epoch_0, ..., epoch_{E-1}] (the engine
    indexes this axis with the reaction-epoch counter directly: 0 = stale,
    1+e = converged on epoch e's links), width-padding ragged IWRR orders
    by tiling (entries past a group's ``len`` are never indexed)."""
    if stale is None:
        return np.zeros((1 + n_ep, 1, 1) if attr.endswith("orders")
                        else (1 + n_ep, 1), np.int32)
    arrs = [getattr(stale, attr)] + [getattr(e, attr) for e in eps]
    if arrs[0].ndim == 2 and len({a.shape[1] for a in arrs}) > 1:
        w = max(a.shape[1] for a in arrs)
        def padw(x):
            reps = int(np.ceil(w / x.shape[1]))
            return np.tile(x, (1, reps))[:, :w]
        arrs = [padw(a) for a in arrs]
    return np.stack(arrs)


# Positional order of the engine arguments; the first block is
# seed-independent (vmap in_axes=None in the seed-batched variant), the
# rest carry the seed batch axis.  In the megabatched variant *every*
# argument carries the fused (scheme x load x failure x seed) axis.
_STATIC_KEYS = ("fsrc", "fdst", "fsize", "pkt_base", "fp1", "fe1", "fp2",
                "fe2", "f_start", "f_inter", "f_leaves", "host_flows",
                "alive", "ep_start", "r_start",
                "e_ports", "e_pcnt", "a_ports", "a_pcnt", "e_dead", "a_dead",
                "f_vpaths", "f_vcnt", "rho", "max_slots", "h_log",
                "prop_slots", "ack_delay")
_SEED_KEYS = ("a_stale", "c_stale", "a_conv", "c_conv", "rand_pool",
              "rr_starts_e", "rr_starts_a",
              "ofan_e_orders", "ofan_e_starts", "ofan_e_len",
              "ofan_a_orders", "ofan_a_starts", "ofan_a_len",
              "seed_lo", "seed_hi")
_ARG_ORDER = _STATIC_KEYS + _SEED_KEYS


@functools.lru_cache(maxsize=32)
def _compiled(static: _Static, shapes: tuple, batch, n_shards: int):
    def fn(*args):
        return _engine(static, **dict(zip(_ARG_ORDER, args)))
    if batch == "mega":
        f = jax.vmap(fn, in_axes=(0,) * len(_ARG_ORDER))
        if n_shards > 1:
            from jax.experimental.shard_map import shard_map
            from jax.sharding import Mesh, PartitionSpec
            mesh = Mesh(np.asarray(jax.devices()[:n_shards]), ("b",))
            # check_rep=False: shard_map has no replication rule for the
            # while_loop primitive; every operand/output is sharded anyway.
            f = shard_map(f, mesh=mesh, in_specs=PartitionSpec("b"),
                          out_specs=PartitionSpec("b"), check_rep=False)
        return jax.jit(f)
    if batch == "seed":
        in_axes = tuple(0 if k in _SEED_KEYS else None for k in _ARG_ORDER)
        return jax.jit(jax.vmap(fn, in_axes=in_axes))
    return jax.jit(fn)


def _run(static: _Static, tables: dict, batch=False, n_shards: int = 1):
    shapes = tuple(sorted((k, np.asarray(v).shape) for k, v in tables.items()))
    fn = _compiled(static, shapes, batch, int(n_shards))
    return fn(*(jnp.asarray(tables[k]) for k in _ARG_ORDER))


def _engine(s: _Static, *, fsrc, fdst, fsize, pkt_base, fp1, fe1, fp2, fe2,
            f_start, f_inter, f_leaves, host_flows, alive, ep_start, r_start,
            e_ports, e_pcnt, a_ports, a_pcnt, e_dead, a_dead,
            f_vpaths, f_vcnt, rho, max_slots, h_log, prop_slots, ack_delay,
            a_stale, c_stale, a_conv, c_conv, rand_pool,
            rr_starts_e, rr_starts_a,
            ofan_e_orders, ofan_e_starts, ofan_e_len,
            ofan_a_orders, ofan_a_starts, ofan_a_len, seed_lo, seed_hi):
    cfg = s.cfg
    n, h, mid, F, P, Fh = s.n, s.h, s.mid, s.F, s.P, s.Fh
    CAP = cfg.buffer_pkts
    NQ = 4 * mid + n
    # Delay rings: *shapes* come from the pow2-bucketed static config
    # (DELAY_PAD/ADELAY_PAD rows), but every index is taken modulo the
    # point's real timing constants (per-row operands), so the real
    # modulus is always <= the ring size, rows past it keep their init
    # value and are never read, and a prop_slots/ack_delay sweep shares
    # one compiled pipeline per bucket -- bitwise-identical to serial.
    DELAY_PAD = max(cfg.prop_slots, 1) + 1
    DELAY = jnp.maximum(prop_slots, 1) + 1
    MOVE = 4 * mid + n
    ADELAY_PAD = cfg.ack_delay + 1
    ADELAY = ack_delay + 1
    ecn_t = max(1, int(cfg.ecn_frac * CAP))
    ecn_thresh = jnp.int32(ecn_t)
    # LoopConfig.impl: trace the inline lax body or the fused Pallas
    # slot-step kernels (repro.kernels.slot_step; 'auto' resolves to pallas
    # on TPU or under REPRO_PALLAS=interpret, lax elsewhere).  The kernels
    # are bitwise-identical to the inline code on integer outputs.
    use_pallas = False
    if cfg.impl != "lax":
        from ..kernels.slot_step import ops as _slot
        use_pallas = _slot.resolve_impl(cfg.impl) == "pallas"
    OFF = (0, mid, 2 * mid, 3 * mid, 4 * mid)
    PBASE = pkt_base[:F]
    # JSQ guard for tree-size padding: +1e9 on port columns >= h_log (the
    # all-zero no-op when this point runs unpadded).
    pad_pen = port_pad_penalty(h, h_log)

    st0 = dict(
        t=jnp.int32(0),
        qbuf=jnp.full((NQ, CAP), -1, INT),
        qhead=jnp.zeros((NQ,), INT),
        qcnt=jnp.zeros((NQ,), INT),
        dl_pkt=jnp.full((DELAY_PAD, MOVE), -1, INT),
        dl_q=jnp.zeros((DELAY_PAD, MOVE), INT),
        al_pkt=jnp.full((ADELAY_PAD, n), -1, INT),
        p_sent_t=jnp.full((P,), -1, INT),
        p_ecn=jnp.zeros((P,), bool),
        p_recv=jnp.zeros((P,), bool),
        p_deliv=jnp.full((P,), -1, INT),
        p_a=jnp.zeros((P,), INT),
        p_c=jnp.zeros((P,), INT),
        f_next=jnp.zeros((F,), INT),
        f_sent=jnp.zeros((F,), INT),
        f_acked=jnp.zeros((F,), INT),
        f_delivered=jnp.zeros((F,), INT),
        f_cum=jnp.zeros((F,), INT),
        f_hi=jnp.full((F,), -1, INT),
        f_complete=jnp.full((F,), -1, INT),
        # Zero-size flows (phase padding, msg_packets=0) are data-done at
        # slot 0, not at the first slot the delivery check can fire
        # (t + prop_slots).
        f_data_done=jnp.where(fsize > 0, INT(-1), INT(0)),
        f_last_ack_t=jnp.full((F,), -1, INT),
        f_lost=jnp.zeros((F,), INT),
        f_cwnd=jnp.full((F,), jnp.float32(min(cfg.bdp_pkts * 2.0,
                                              cfg.sw_max_cwnd))),
        f_last_dec=jnp.full((F,), -10**6, INT),
        f_label=(rand_pool[jnp.arange(F) % rand_pool.shape[0]]).astype(INT),
        f_label_cnt=jnp.zeros((F,), INT),
        f_mark_ewma=jnp.zeros((F,), jnp.float32),
        f_draw=jnp.arange(F, dtype=INT) * 31 + 1,
        pool_lab=jnp.zeros((F, 64), INT),
        pool_cnt=jnp.zeros((F,), INT),
        h_rr=jnp.zeros((n,), INT),
        h_credit=jnp.zeros((n,), jnp.float32),
        h_ackdebt=jnp.zeros((n,), jnp.float32),
        ptr_e=jnp.zeros((s.n_edges * s.n_edges,) if s.edge_mode == "ofan"
                        else (s.n_edges,), INT),
        ptr_a=jnp.zeros((s.n_aggs * s.n_pods,) if s.agg_mode == "ofan"
                        else (s.n_aggs,), INT),
        drops=jnp.int32(0),
        rtx=jnp.int32(0),
        max_q=jnp.int32(0),
        sum_q=jnp.float32(0.0),
        enq_events=jnp.int32(0),
    )
    if s.probe[1]:
        # Per-layer windowed queue maxima (repro.obs.probes); padded queues
        # are never enqueued to and read 0, so the series is
        # padding-invariant like every other output.
        st0["q_probe"] = jnp.zeros((5, s.probe[1]), INT)

    def step(st_in):
        st = dict(st_in)
        t = st["t"]
        # Fault-epoch counters.  ``pe``: the *physical* epoch (whose links
        # black-hole packets) -- the number of epoch starts reached, minus
        # one.  ``cvg_i``: how many epochs the *routing* has reacted to
        # (r_start[e] = ep_start[e] + reaction delay, saturated host-side);
        # 0 means stale/failure-unaware, 1+e means converged on epoch e.
        # Pad epochs start at a 2**30 sentinel and never count.  The static
        # single-epoch path reduces to the old ``t >= G`` gate bitwise.
        pe = jnp.maximum(jnp.sum((t >= ep_start).astype(INT)) - 1, 0)
        cvg_i = jnp.sum((t >= r_start).astype(INT))
        converged = cvg_i > 0
        ci = cvg_i                       # OFAN [stale, epoch...] table index
        ric = jnp.maximum(cvg_i - 1, 0)  # index into converged epoch stacks

        # ---- 1. serve all queues -------------------------------------------
        qcnt = st["qcnt"]
        has = qcnt > 0
        headpos = st["qhead"]
        popped = jnp.where(has, st["qbuf"][jnp.arange(NQ), headpos], -1)
        st["qhead"] = jnp.where(has, (headpos + 1) % CAP, headpos)
        st["qcnt"] = jnp.where(has, qcnt - 1, qcnt)

        # ---- 2. route popped packets ---------------------------------------
        qids = jnp.arange(NQ)
        stg = jnp.clip(qids // mid, 0, 4)
        pk = popped
        valid = pk >= 0
        pkc = jnp.maximum(pk, 0)
        pf = jnp.where(valid,
                       jnp.searchsorted(pkt_base, pk, side="right") - 1,
                       0).astype(INT)
        a_ch = st["p_a"][pkc]
        c_ch = st["p_c"][pkc]
        p2 = fp2[pf]
        e2 = fe2[pf]
        nq_from_0 = jnp.where(f_inter[pf],
                              OFF[1] + (fp1[pf] * h + a_ch) * h + c_ch,
                              OFF[3] + (p2 * h + a_ch) * h + e2)
        nq_from_1 = OFF[2] + (p2 * h + a_ch) * h + c_ch
        nq_from_2 = OFF[3] + (p2 * h + a_ch) * h + e2
        nq_from_3 = OFF[4] + fdst[pf]
        nxt = jnp.select([stg == 0, stg == 1, stg == 2, stg == 3],
                         [nq_from_0, nq_from_1, nq_from_2, nq_from_3], -2)
        nxt = jnp.where(valid, nxt, -1)

        # ---- 3. deliveries (stage-4 pops) ----------------------------------
        deliv = valid & (nxt == -2)
        dt = t + prop_slots
        first_del = deliv & ~st["p_recv"][pkc]
        st["p_deliv"] = st["p_deliv"].at[jnp.where(first_del, pk, P)].set(
            dt, mode="drop")
        if use_pallas and cfg.loss == "sack":
            # Fused SACK scoreboard kernel: bitmap scatter + per-flow
            # first-missing window scan in one launch.  Legal here because
            # step 5's retransmit candidate reads the post-update bitmap
            # and nothing between writes ``p_recv`` or ``f_cum``; the
            # per-flow scan gathered at ``[sfv]`` below is bitwise-equal
            # to the inline per-lane scan.
            st["p_recv"], fm_flow = _slot.sack_update_scan(
                st["p_recv"], pk, deliv, st["f_cum"], fsize, PBASE,
                backend="pallas")
        else:
            st["p_recv"] = st["p_recv"].at[jnp.where(deliv, pk, P)].set(
                True, mode="drop")
        # Erasure coding is rateless: every delivered symbol counts toward
        # decoding; SACK needs unique packets.
        counts_delivery = deliv if cfg.loss == "erasure" else first_del
        st["f_delivered"] = st["f_delivered"].at[
            jnp.where(counts_delivery, pf, F)].add(1, mode="drop")
        data_done_now = (st["f_data_done"] < 0) & (st["f_delivered"] >= fsize)
        st["f_data_done"] = jnp.where(data_done_now, dt, st["f_data_done"])
        # ACKs: deliveries only come from DN_E pops (<= n)
        dn_pk = popped[OFF[4]:]
        dn_ok = deliv[OFF[4]:]
        st["al_pkt"] = st["al_pkt"].at[t % ADELAY, :].set(
            jnp.where(dn_ok, dn_pk, -1))

        # ---- 4. fabric moves ------------------------------------------------
        mover = valid & (nxt >= 0)
        dslot = (t + prop_slots) % DELAY
        st["dl_pkt"] = st["dl_pkt"].at[dslot, :4 * mid].set(
            jnp.where(mover, pk, -1)[:4 * mid])
        st["dl_q"] = st["dl_q"].at[dslot, :4 * mid].set(
            jnp.where(mover, nxt, 0)[:4 * mid])

        # ---- 5. host injection ----------------------------------------------
        inflight = st["f_sent"] - st["f_acked"] - st["f_lost"]
        if cfg.cca == "ideal":
            window_ok = jnp.ones((F,), bool)
        else:
            window_ok = inflight.astype(jnp.float32) < st["f_cwnd"]
        if cfg.loss == "erasure":
            remaining = ((st["f_acked"] < fsize)
                         & (inflight < (fsize - st["f_acked"]) + cfg.bdp_pkts))
            need_rtx = jnp.zeros((F,), bool)
        else:
            gap = st["f_hi"] + 1 - st["f_cum"]
            need_rtx = (st["f_hi"] >= 0) & (gap > cfg.sack_thresh) & (
                st["f_cum"] < fsize)
            remaining = (st["f_next"] < fsize) | need_rtx
        # Phase gate (collective-phase schedules): a flow may not send
        # before its phase's start slot.  f_start == 0 everywhere (every
        # static workload) keeps the mask all-true -- bitwise-inert.
        sendable = (window_ok & remaining & (st["f_complete"] < 0)
                    & (t >= f_start))

        hf = host_flows
        hf_ok = jnp.where(hf >= 0, sendable[jnp.maximum(hf, 0)], False)
        rrp = st["h_rr"][:, None]
        prio = (jnp.arange(Fh)[None, :] - rrp) % Fh
        prio = jnp.where(hf_ok, prio, Fh + 1)
        pick = jnp.argmin(prio, axis=1)
        can_send = jnp.take_along_axis(hf_ok, pick[:, None], axis=1)[:, 0]
        st["h_credit"] = jnp.minimum(st["h_credit"] + rho, 4.0)
        debt_ok = st["h_ackdebt"] < 1.0
        st["h_ackdebt"] = jnp.where(~debt_ok, st["h_ackdebt"] - 1.0,
                                    st["h_ackdebt"])
        do_send = can_send & (st["h_credit"] >= 1.0) & debt_ok
        st["h_credit"] = jnp.where(do_send, st["h_credit"] - 1.0,
                                   st["h_credit"])
        st["h_rr"] = jnp.where(do_send, (pick + 1) % Fh,
                               st["h_rr"]).astype(INT)

        sf = jnp.where(do_send, hf[jnp.arange(n), pick], -1)
        sfv = jnp.maximum(sf, 0)
        seq_fresh = st["f_next"][sfv]
        if cfg.loss == "sack":
            if use_pallas:
                first_missing = fm_flow[sfv]
            else:
                base = st["f_cum"][sfv]
                offs = jnp.arange(64)[None, :]
                cand = jnp.minimum(base[:, None] + offs,
                                   fsize[sfv][:, None] - 1)
                got = st["p_recv"][PBASE[sfv][:, None] + cand]
                first_missing = cand[jnp.arange(n), jnp.argmin(got, axis=1)]
            is_rtx = need_rtx[sfv] & do_send
            seq = jnp.where(is_rtx, first_missing,
                            jnp.minimum(seq_fresh, fsize[sfv] - 1))
            # if no fresh left and not rtx-triggered, resend first missing too
            exhausted = (seq_fresh >= fsize[sfv]) & ~is_rtx & do_send
            seq = jnp.where(exhausted, first_missing, seq)
            is_rtx = is_rtx | exhausted
            st["rtx"] = st["rtx"] + is_rtx.sum()
        else:
            is_rtx = jnp.zeros((n,), bool)
            seq = jnp.where(seq_fresh < fsize[sfv], seq_fresh,
                            st["f_sent"][sfv] % jnp.maximum(fsize[sfv], 1))
        pid = (PBASE[sfv] + jnp.clip(seq, 0, fsize[sfv] - 1)).astype(INT)

        fresh_ok = do_send & ~is_rtx & (seq_fresh < fsize[sfv])
        st["f_next"] = st["f_next"].at[jnp.where(fresh_ok, sf, F)].add(
            1, mode="drop")
        first_send = do_send & (st["f_sent"][sfv] == 0)
        st["f_last_ack_t"] = st["f_last_ack_t"].at[
            jnp.where(first_send, sf, F)].set(t, mode="drop")
        st["f_sent"] = st["f_sent"].at[jnp.where(do_send, sf, F)].add(
            1, mode="drop")
        st["p_sent_t"] = st["p_sent_t"].at[jnp.where(do_send, pid, P)].set(
            t, mode="drop")

        # ---- 6. edge port choice for injected packets -----------------------
        # REPS / PLB label machinery
        draw_idx = (st["f_draw"][sfv] * 48271 + 12345) % rand_pool.shape[0]
        fresh_lab = rand_pool[draw_idx]
        has_pool = st["pool_cnt"][sfv] > 0
        pooled = st["pool_lab"][sfv, jnp.maximum(st["pool_cnt"][sfv] - 1, 0)]
        if s.adaptive_host and not s.plb:      # REPS
            lab = jnp.where(has_pool, pooled, fresh_lab)
            st["pool_cnt"] = st["pool_cnt"].at[
                jnp.where(do_send & has_pool, sf, F)].add(-1, mode="drop")
        elif s.plb:
            lab = st["f_label"][sfv]
        else:
            lab = fresh_lab
        st["f_draw"] = st["f_draw"] + jnp.zeros_like(st["f_draw"]).at[
            jnp.where(do_send, sf, F)].add(7, mode="drop")

        if s.edge_mode == "pre":
            if s.adaptive_host:
                # post-convergence W-ECMP rehash: labels land on valid paths.
                # Labels stay encoded in the point's own h_log port space so
                # the draw/recycle stream matches the standalone run even
                # when the point rides a larger padded tree's engine.
                eff = jnp.where(converged,
                                f_vpaths[ric, sfv, lab % f_vcnt[ric, sfv]],
                                lab)
                a_new = ((eff // h_log) % h_log).astype(INT)
                c_new = (eff % h_log).astype(INT)
            else:
                a_new = jnp.where(converged, a_conv[ric, pid], a_stale[pid])
                c_new = jnp.where(converged, c_conv[ric, pid], c_stale[pid])
        elif s.edge_mode == "rand":
            sw = (fp1[sfv] * h + fe1[sfv]).astype(INT)
            de = (fp2[sfv] * h + fe2[sfv]).astype(INT)
            gp = sw * s.n_edges + de
            # Per-host spray draw over the LOGICAL (a, c) label space, from
            # the counter stream keyed on (seed, host id, slot): identical
            # for every real host at any padding (hosts are a dense prefix;
            # padded hosts never send, so their draws are inert).
            r = ent.draw_int(seed_lo, seed_hi, ent.SITE_EDGE_RAND,
                             jnp.arange(n), t, h_log * h_log)
            a_naive = (r // h_log).astype(INT)
            a_live = e_ports[ric, gp,
                             r % jnp.maximum(e_pcnt[ric, gp], 1)].astype(INT)
            a_new = jnp.where(converged, a_live, a_naive)
            c_new = (r % h_log).astype(INT)
        elif s.edge_mode in ("rr", "rr_reset", "ofan"):
            sw = (fp1[sfv] * h + fe1[sfv]).astype(INT)
            north = do_send & f_leaves[sfv]
            de = (fp2[sfv] * h + fe2[sfv]).astype(INT)
            gp = sw * s.n_edges + de
            if s.edge_mode == "ofan":
                gid = gp
                rk = rank_by(gid, north)
                ctr = st["ptr_e"][gid] + rk
                L = jnp.maximum(ofan_e_len[ci, gid], 1)
                a_new = ofan_e_orders[
                    ci, gid, (ofan_e_starts[ci, gid] + ctr) % L].astype(INT)
                st["ptr_e"] = st["ptr_e"].at[
                    jnp.where(north, gid, st["ptr_e"].shape[0])].add(
                    1, mode="drop")
            else:
                rk = rank_by(sw, north)
                ctr = st["ptr_e"][sw] + rk
                # pre-convergence: all ports; post: W-ECMP-valid for dest
                naive = ((rr_starts_e[sw] + ctr) % h_log).astype(INT)
                pcn = jnp.maximum(e_pcnt[ric, gp], 1)
                live = e_ports[ric, gp,
                               (rr_starts_e[sw] + ctr) % pcn].astype(INT)
                a_new = jnp.where(converged, live, naive)
                st["ptr_e"] = st["ptr_e"].at[
                    jnp.where(north, sw, s.n_edges)].add(1, mode="drop")
            c_new = jnp.zeros((n,), INT)
        else:  # jsq / jsq_quant at edge
            sw = (fp1[sfv] * h + fe1[sfv]).astype(INT)
            de = (fp2[sfv] * h + fe2[sfv]).astype(INT)
            if use_pallas:
                # Fused occupancy-gather + in-kernel tie-break noise +
                # masked-argmin kernel (one VMEM-resident pass).
                a_new = _slot.jsq_pick(
                    st["qcnt"], OFF[0] + sw * h, jnp.arange(n, dtype=INT),
                    converged & e_dead[ric, sw, de], pad_pen,
                    seed_lo, seed_hi, t, site=ent.SITE_EDGE_JSQ,
                    quanta=s.quanta, cap=CAP, backend="pallas")
            else:
                qbase = OFF[0] + sw * h
                lens = st["qcnt"][qbase[:, None] + jnp.arange(h)[None, :]]
                # Tie-break noise from the counter stream keyed on (seed,
                # host id, slot, port lane): shape-independent, so the same
                # host sees the same noise at any padding/batch position.
                nz = ent.draw_uniform(seed_lo, seed_hi, ent.SITE_EDGE_JSQ,
                                      jnp.arange(n)[:, None], t,
                                      lane=jnp.arange(h)[None, :])
                if s.quanta is None:
                    score = lens.astype(jnp.float32) + nz * 1e-3
                else:
                    thr = jnp.asarray(s.quanta, jnp.float32) * CAP
                    bins = jnp.sum(lens[:, :, None] > thr[None, None, :],
                                   axis=2)
                    score = bins.astype(jnp.float32) + nz * 0.5
                score = score + pad_pen[None, :]
                score = score + jnp.where(converged & e_dead[ric, sw, de],
                                          1e9, 0.0)
                a_new = jnp.argmin(score, axis=1).astype(INT)
            c_new = jnp.zeros((n,), INT)

        st["p_a"] = st["p_a"].at[jnp.where(do_send, pid, P)].set(
            a_new, mode="drop")
        st["p_c"] = st["p_c"].at[jnp.where(do_send, pid, P)].set(
            c_new, mode="drop")
        st["f_label_cnt"] = st["f_label_cnt"].at[
            jnp.where(do_send, sf, F)].add(1, mode="drop")

        inj_q = jnp.where(f_leaves[sfv],
                          OFF[0] + (fp1[sfv] * h + fe1[sfv]) * h + a_new,
                          OFF[4] + fdst[sfv])
        st["dl_pkt"] = st["dl_pkt"].at[dslot, 4 * mid:].set(
            jnp.where(do_send, pid, -1))
        st["dl_q"] = st["dl_q"].at[dslot, 4 * mid:].set(
            jnp.where(do_send, inj_q, 0))

        # ---- 7. arrivals: agg uplink choice then enqueue ---------------------
        arr_slot = t % DELAY
        apk = st["dl_pkt"][arr_slot]
        aq = st["dl_q"][arr_slot]
        avalid = apk >= 0
        apkc = jnp.maximum(apk, 0)
        af = jnp.where(avalid,
                       jnp.searchsorted(pkt_base, apk, "right") - 1,
                       0).astype(INT)
        to_agg = avalid & (aq >= OFF[1]) & (aq < OFF[2])
        asw = jnp.clip((aq - OFF[1]) // h, 0, s.n_aggs - 1).astype(INT)
        gpa = asw * s.n_pods + fp2[af]
        if s.agg_mode in ("pre", "rand"):
            c_fin = st["p_c"][apkc]
            if s.agg_mode == "rand":
                # Per-packet draw over the LOGICAL core sub-links, keyed on
                # (seed, packet id, slot): the packet's identity -- not its
                # position in the (padding-sized) move list -- selects the
                # stream value, so draws survive any tree/batch padding.
                r = ent.draw_int(seed_lo, seed_hi, ent.SITE_AGG_RAND,
                                 apkc, t, h_log)
                c_live = a_ports[ric, gpa,
                                 r % jnp.maximum(a_pcnt[ric, gpa], 1)]
                c_fin = jnp.where(converged, c_live, r).astype(INT)
        elif s.agg_mode in ("rr", "rr_reset", "ofan"):
            if s.agg_mode == "ofan":
                gid = gpa
                rk = rank_by(gid, to_agg)
                ctr = st["ptr_a"][gid] + rk
                L = jnp.maximum(ofan_a_len[ci, gid], 1)
                c_fin = ofan_a_orders[
                    ci, gid, (ofan_a_starts[ci, gid] + ctr) % L].astype(INT)
                st["ptr_a"] = st["ptr_a"].at[
                    jnp.where(to_agg, gid, st["ptr_a"].shape[0])].add(
                    1, mode="drop")
            else:
                rk = rank_by(asw, to_agg)
                ctr = st["ptr_a"][asw] + rk
                naive = ((rr_starts_a[asw] + ctr) % h_log).astype(INT)
                pcn = jnp.maximum(a_pcnt[ric, gpa], 1)
                live = a_ports[ric, gpa,
                               (rr_starts_a[asw] + ctr) % pcn].astype(INT)
                c_fin = jnp.where(converged, live, naive)
                st["ptr_a"] = st["ptr_a"].at[
                    jnp.where(to_agg, asw, s.n_aggs)].add(1, mode="drop")
        elif not use_pallas:  # jsq at agg (inline; pallas fuses it below)
            qbase = OFF[1] + asw * h
            lens = st["qcnt"][qbase[:, None] + jnp.arange(h)[None, :]]
            # Noise keyed on (seed, arriving packet id, slot, port lane).
            nz = ent.draw_uniform(seed_lo, seed_hi, ent.SITE_AGG_JSQ,
                                  apkc[:, None], t,
                                  lane=jnp.arange(h)[None, :])
            if s.quanta is None:
                score = lens.astype(jnp.float32) + nz * 1e-3
            else:
                thr = jnp.asarray(s.quanta, jnp.float32) * CAP
                bins = jnp.sum(lens[:, :, None] > thr[None, None, :], axis=2)
                score = bins.astype(jnp.float32) + nz * 0.5
            score = score + pad_pen[None, :]
            score = score + jnp.where(converged & a_dead[ric, asw, fp2[af]],
                                      1e9, 0.0)
            c_fin = jnp.argmin(score, axis=1).astype(INT)
        fuse_agg = use_pallas and s.agg_mode not in ("pre", "rand", "rr",
                                                     "rr_reset", "ofan")
        if fuse_agg:
            # ---- 7+8 fused: agg JSQ pick + enqueue in one kernel pass ----
            (st["qbuf"], qcnt2, c_fin, enq_try, do_enq, occ_after,
             marked) = _slot.agg_jsq_enqueue(
                st["qbuf"], st["qhead"], st["qcnt"], alive[pe], apk, aq,
                to_agg, asw, converged & a_dead[ric, asw, fp2[af]], pad_pen,
                seed_lo, seed_hi, t, site=ent.SITE_AGG_JSQ, quanta=s.quanta,
                cap=CAP, ecn_thresh=ecn_t, off1=OFF[1], h=h,
                backend="pallas")
            st["p_c"] = st["p_c"].at[jnp.where(to_agg, apk, P)].set(
                c_fin, mode="drop")
        else:
            st["p_c"] = st["p_c"].at[jnp.where(to_agg, apk, P)].set(
                c_fin, mode="drop")
            aq = jnp.where(to_agg, OFF[1] + asw * h + c_fin, aq)

        # ---- 8. enqueue (drops, ECN, failure black-holing) -------------------
        if use_pallas:
            if not fuse_agg:
                (st["qbuf"], qcnt2, enq_try, do_enq, occ_after,
                 marked) = _slot.enqueue(
                    st["qbuf"], st["qhead"], st["qcnt"], alive[pe], apk, aq,
                    avalid, cap=CAP, ecn_thresh=ecn_t, backend="pallas")
            st["drops"] = st["drops"] + (avalid & ~enq_try).sum()
            st["drops"] = st["drops"] + (enq_try & ~do_enq).sum()
            st["p_ecn"] = st["p_ecn"].at[jnp.where(marked, apk, P)].set(
                True, mode="drop")
            st["qcnt"] = qcnt2
        else:
            aqc = jnp.clip(aq, 0, NQ - 1)
            dead = ~alive[pe, aqc]
            enq_try = avalid & ~dead
            st["drops"] = st["drops"] + (avalid & dead).sum()
            rkq = rank_by(aq, enq_try)
            room = st["qcnt"][aqc] + rkq < CAP
            do_enq = enq_try & room
            st["drops"] = st["drops"] + (enq_try & ~room).sum()
            pos = (st["qhead"][aqc] + st["qcnt"][aqc] + rkq) % CAP
            st["qbuf"] = st["qbuf"].at[jnp.where(do_enq, aq, NQ),
                                       jnp.where(do_enq, pos, 0)].set(
                jnp.where(do_enq, apk, -1), mode="drop")
            occ_after = st["qcnt"][aqc] + rkq + 1
            marked = do_enq & (occ_after > ecn_thresh)
            st["p_ecn"] = st["p_ecn"].at[jnp.where(marked, apk, P)].set(
                True, mode="drop")
            st["qcnt"] = st["qcnt"].at[jnp.where(do_enq, aq, NQ)].add(
                1, mode="drop")
        st["max_q"] = jnp.maximum(st["max_q"], st["qcnt"].max())
        if s.probe[1]:
            # Same reduction point as max_q, split per fat-tree layer and
            # scattered into the slot's stride window (slots past the probe
            # horizon clamp into the last window), so the series max over
            # layers and time equals max_q exactly.
            p_stride, p_samples = s.probe
            si = jnp.minimum(t // p_stride, p_samples - 1)
            qc = st["qcnt"]
            lay = jnp.stack([qc[OFF[0]:OFF[1]].max(), qc[OFF[1]:OFF[2]].max(),
                             qc[OFF[2]:OFF[3]].max(), qc[OFF[3]:OFF[4]].max(),
                             qc[OFF[4]:].max()])
            st["q_probe"] = st["q_probe"].at[:, si].max(lay)
        st["sum_q"] = st["sum_q"] + jnp.where(do_enq, occ_after, 0).sum()
        st["enq_events"] = st["enq_events"] + do_enq.sum()
        st["dl_pkt"] = st["dl_pkt"].at[arr_slot].set(-1)

        # ---- 9. ACK processing -----------------------------------------------
        ak = st["al_pkt"][(t + 1) % ADELAY]   # written ack_delay slots ago
        aok = ak >= 0
        akc = jnp.maximum(ak, 0)
        akf = jnp.where(aok, jnp.searchsorted(pkt_base, ak, "right") - 1,
                        0).astype(INT)
        st["al_pkt"] = st["al_pkt"].at[(t + 1) % ADELAY].set(-1)
        st["h_ackdebt"] = st["h_ackdebt"].at[
            jnp.where(aok, fsrc[akf], n)].add(cfg.ack_cost, mode="drop")
        st["f_acked"] = st["f_acked"].at[jnp.where(aok, akf, F)].add(
            1, mode="drop")
        st["f_last_ack_t"] = st["f_last_ack_t"].at[
            jnp.where(aok, akf, F)].set(t, mode="drop")
        aseq = (ak - PBASE[akf]).astype(INT)
        st["f_hi"] = st["f_hi"].at[jnp.where(aok, akf, F)].max(
            jnp.where(aok, aseq, -1), mode="drop")
        if cfg.loss == "sack":
            if use_pallas:
                st["f_cum"] = _slot.sack_advance(
                    st["p_recv"], st["f_cum"], fsize, PBASE,
                    backend="pallas")
            else:
                for _ in range(2):
                    cum = st["f_cum"]
                    offs = jnp.arange(4)[None, :]
                    cand = jnp.minimum(cum[:, None] + offs,
                                       fsize[:, None] - 1)
                    got = st["p_recv"][PBASE[:, None] + cand] & (
                        cum[:, None] + offs < fsize[:, None])
                    adv = jnp.sum(jnp.cumprod(got, axis=1),
                                  axis=1).astype(INT)
                    st["f_cum"] = jnp.minimum(cum + adv, fsize)
        mk = st["p_ecn"][akc]
        if s.adaptive_host and not s.plb:      # REPS recycle
            lab_back = st["p_a"][akc] * h_log + st["p_c"][akc]
            good = aok & ~mk
            pc0 = st["pool_cnt"][jnp.maximum(akf, 0)]
            st["pool_lab"] = st["pool_lab"].at[
                jnp.where(good, akf, F), jnp.minimum(pc0, 63)].set(
                lab_back, mode="drop")
            st["pool_cnt"] = jnp.minimum(
                st["pool_cnt"].at[jnp.where(good, akf, F)].add(
                    1, mode="drop"), 64)
        if s.plb:
            w = jnp.float32(0.125)
            dec = jnp.zeros((F,), jnp.float32).at[
                jnp.where(aok, akf, F)].add(1.0, mode="drop")
            inc = jnp.zeros((F,), jnp.float32).at[
                jnp.where(aok & mk, akf, F)].add(1.0, mode="drop")
            st["f_mark_ewma"] = (st["f_mark_ewma"] * (1 - w * dec)
                                 + w * inc)
            change = ((st["f_mark_ewma"] > cfg.plb_beta)
                      & (st["f_label_cnt"] > cfg.plb_alpha))
            newlab = rand_pool[(st["f_draw"] * 104729 + 13)
                               % rand_pool.shape[0]]
            st["f_label"] = jnp.where(change, newlab,
                                      st["f_label"]).astype(INT)
            st["f_label_cnt"] = jnp.where(change, 0,
                                          st["f_label_cnt"]).astype(INT)
            st["f_draw"] = st["f_draw"] + change.astype(INT)
        if cfg.cca == "mswift":
            delay = (t - st["p_sent_t"][akc]).astype(jnp.float32)
            over = delay > cfg.sw_target_slots
            cw = st["f_cwnd"]
            inc = jnp.where(aok & ~over,
                            cfg.sw_ai / jnp.maximum(cw[akf], 1.0), 0.0)
            cw = cw.at[jnp.where(aok, akf, F)].add(inc, mode="drop")
            can_dec = (t - st["f_last_dec"][akf]) > (ack_delay + prop_slots)
            factor = jnp.clip(1.0 - cfg.sw_beta
                              * (delay - cfg.sw_target_slots)
                              / jnp.maximum(delay, 1.0), 0.5, 1.0)
            dec_sel = aok & over & can_dec
            cw = cw.at[jnp.where(dec_sel, akf, F)].multiply(
                jnp.where(dec_sel, factor, 1.0), mode="drop")
            st["f_cwnd"] = jnp.clip(cw, 1.0, cfg.sw_max_cwnd)
            st["f_last_dec"] = st["f_last_dec"].at[
                jnp.where(dec_sel, akf, F)].set(t, mode="drop")

        # ---- 10. timeouts -----------------------------------------------------
        inflight2 = st["f_sent"] - st["f_acked"] - st["f_lost"]
        rto_fire = ((st["f_sent"] > 0) & (st["f_complete"] < 0)
                    & (inflight2 > 0)
                    & (t - st["f_last_ack_t"] > cfg.rto_slots))
        st["f_lost"] = st["f_lost"] + jnp.where(rto_fire, inflight2, 0)
        st["f_last_ack_t"] = jnp.where(rto_fire, t, st["f_last_ack_t"])
        if cfg.loss == "sack":
            st["f_next"] = jnp.where(rto_fire,
                                     jnp.minimum(st["f_next"], st["f_cum"]),
                                     st["f_next"])
        if cfg.cca == "mswift":
            st["f_cwnd"] = jnp.where(rto_fire, 1.0, st["f_cwnd"])  # freeze

        # ---- 11. flow completion ----------------------------------------------
        if cfg.loss == "sack":
            done_now = (st["f_complete"] < 0) & (st["f_cum"] >= fsize)
        else:
            done_now = (st["f_complete"] < 0) & (st["f_acked"] >= fsize)
        st["f_complete"] = jnp.where(done_now, t, st["f_complete"])

        st["t"] = t + 1
        return st

    def cond(st):
        return (st["f_complete"] < 0).any() & (st["t"] < max_slots)

    final = jax.lax.while_loop(cond, step, st0)
    out = {
        "delivered_slot": final["p_deliv"],
        "flow_complete": final["f_complete"],
        "f_data_done": final["f_data_done"],
        "drops": final["drops"],
        "rtx": final["rtx"],
        "max_q": final["max_q"],
        "sum_q": final["sum_q"],
        "enq_events": final["enq_events"],
        "f_cwnd": final["f_cwnd"],
    }
    if s.probe[1]:
        out["q_probe"] = final["q_probe"]
    return out
