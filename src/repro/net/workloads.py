"""Collective traffic workloads for the fabric simulator.

The paper evaluates two representative traffic matrices (§5):

  * a random **permutation** (each host sends to exactly one other host and
    receives from exactly one) -- the building block of ring AllGather /
    AllReduce and iterative AlltoAll;
  * **all-to-all** (every host sends to every other host) -- one-shot
    AllReduce / AllGather / AlltoAll.

plus the §8.4 **FSDP hierarchical-ring** scenario (Llama 7B/70B/405B on a
1,024-GPU cluster, 8 parallel rings, random server placement).

A workload compiles down to a flat per-packet description consumed by the
engines:

  ``src[i]``       source host of packet i
  ``dst[i]``       destination host
  ``flow[i]``      flow index (src,dst pair id)
  ``seq[i]``       sequence number of the packet inside its flow
  ``t_release[i]`` slot at which the source NIC finishes serializing packet i
                   (hosts pace at line rate == 1 data packet / slot and
                   round-robin across their active flows, matching the
                   paper's uniform, synchronized senders)
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .topology import FatTree


@dataclasses.dataclass
class Workload:
    name: str
    n_hosts: int
    src: np.ndarray        # (P,) int64
    dst: np.ndarray        # (P,) int64
    flow: np.ndarray       # (P,) int64
    seq: np.ndarray        # (P,) int64
    t_release: np.ndarray  # (P,) float64  (slots)
    flow_src: np.ndarray   # (F,) int64
    flow_dst: np.ndarray   # (F,) int64
    flow_size: np.ndarray  # (F,) int64  packets per flow
    # Optional per-flow start slot (collective-phase schedules,
    # ``repro.phases``): the slotted engine gates each flow's first send on
    # it, the fast engine sees the same offsets folded into ``t_release``.
    # ``None`` (every static workload) means all-zero and is
    # bitwise-equivalent to a zero array on both engines.
    flow_start: Optional[np.ndarray] = None   # (F,) int64  (slots)

    @property
    def n_packets(self) -> int:
        return int(self.src.shape[0])

    @property
    def n_flows(self) -> int:
        return int(self.flow_src.shape[0])

    def packets_per_host(self) -> np.ndarray:
        return np.bincount(self.src, minlength=self.n_hosts)


def _packets_from_flows(name: str, n_hosts: int, flow_src: np.ndarray,
                        flow_dst: np.ndarray, flow_size: np.ndarray) -> Workload:
    """Expand per-flow sizes to per-packet records with host-paced release.

    Each host interleaves its flows round-robin (uniform collectives send the
    same amount on each flow at the same pace), emitting one packet per slot.
    """
    flow_src = np.asarray(flow_src, dtype=np.int64)
    flow_dst = np.asarray(flow_dst, dtype=np.int64)
    flow_size = np.asarray(flow_size, dtype=np.int64)
    n_flows = flow_src.shape[0]

    # Host-local flow index r (stable order) and flows-per-host F.
    order = np.argsort(flow_src, kind="stable")
    sorted_src = flow_src[order]
    # rank within host = position - first position of that host
    first = np.searchsorted(sorted_src, sorted_src, side="left")
    local_rank = np.arange(n_flows) - first
    flow_rank = np.empty(n_flows, dtype=np.int64)
    flow_rank[order] = local_rank
    flows_per_host = np.bincount(flow_src, minlength=n_hosts)

    if n_flows and (flow_size == flow_size[0]).all():
        # Uniform collectives (all the paper's workloads): packet j of the
        # host-local r-th flow goes out in slot j*F + r.  Fully vectorized.
        s = int(flow_size[0])
        flow_ids = np.repeat(np.arange(n_flows), s)
        seq = np.tile(np.arange(s), n_flows)
        F = flows_per_host[flow_src[flow_ids]]
        t_rel = (seq * F + flow_rank[flow_ids]).astype(np.float64)
        return Workload(
            name=name, n_hosts=n_hosts,
            src=flow_src[flow_ids], dst=flow_dst[flow_ids],
            flow=flow_ids, seq=seq, t_release=t_rel,
            flow_src=flow_src, flow_dst=flow_dst, flow_size=flow_size)

    # General (non-uniform sizes, possibly zero-size flows) fallback:
    # per-host python round-robin pacing, emitted FLOW-CONTIGUOUS -- the
    # slotted engine requires packets grouped by flow in flow-id order, so
    # the release times are computed in host-time order but written out
    # per flow.  Zero-size flows contribute no packets but keep their flow
    # row (searchsorted release binding and pkt_base edge-padding stay
    # well-formed downstream).
    rel_by_flow = [[] for _ in range(n_flows)]
    for h in range(n_hosts):
        fl = np.flatnonzero(flow_src == h)
        if len(fl) == 0:
            continue
        counters = np.zeros(len(fl), dtype=np.int64)
        sizes = flow_size[fl]
        t, r = 0, 0
        remaining = int(sizes.sum())
        while remaining > 0:
            fi = r % len(fl)
            r += 1
            if counters[fi] < sizes[fi]:
                rel_by_flow[int(fl[fi])].append(float(t))
                counters[fi] += 1
                remaining -= 1
                t += 1
    flow_l = np.repeat(np.arange(n_flows), flow_size)
    seq_l = (np.concatenate([np.arange(s) for s in flow_size.tolist()])
             if n_flows else np.empty(0, dtype=np.int64))
    rel_l = np.asarray([t for rs in rel_by_flow for t in rs],
                       dtype=np.float64)
    return Workload(
        name=name, n_hosts=n_hosts,
        src=flow_src[flow_l],
        dst=flow_dst[flow_l],
        flow=flow_l.astype(np.int64),
        seq=seq_l.astype(np.int64),
        t_release=rel_l,
        flow_src=flow_src, flow_dst=flow_dst, flow_size=flow_size,
    )


# --------------------------------------------------------------------------
# Traffic matrices
# --------------------------------------------------------------------------

def permutation(tree: FatTree, msg_packets: int, rng: np.random.Generator,
                inter_pod_only: bool = False) -> Workload:
    """Random permutation: host i -> perm(i), ``msg_packets`` packets each.

    ``inter_pod_only`` restricts to derangements where every (src, dst) pair
    crosses pods (used by the paper for Fig. 7 / App. F experiments).
    """
    n = tree.n_hosts
    if inter_pod_only:
        # Rejection sampling is infeasible (acceptance ~ (1-1/k)^n); build a
        # conflict-free perm by local swap repair of a random permutation.
        pod = tree.host_pod(np.arange(n))
        perm = rng.permutation(n)
        for _ in range(10_000):
            bad = np.flatnonzero(pod == pod[perm])
            if len(bad) == 0:
                break
            # Swap each conflicting position with a random other position;
            # strictly decreases expected conflicts.
            other = rng.integers(0, n, size=len(bad))
            for b, o in zip(bad.tolist(), other.tolist()):
                perm[b], perm[o] = perm[o], perm[b]
        else:  # pragma: no cover
            raise RuntimeError("could not build inter-pod permutation")
    else:
        while True:
            perm = rng.permutation(n)
            if (perm != np.arange(n)).all():
                break
    sizes = np.full(n, msg_packets, dtype=np.int64)
    return _packets_from_flows("permutation", n, np.arange(n), perm, sizes)


def all_to_all(tree: FatTree, msg_packets_per_dst: int,
               rng: Optional[np.random.Generator] = None) -> Workload:
    """All-to-all: every host sends ``msg_packets_per_dst`` to each other host."""
    n = tree.n_hosts
    srcs = np.repeat(np.arange(n), n - 1)
    dsts = np.concatenate([np.concatenate([np.arange(i), np.arange(i + 1, n)])
                           for i in range(n)])
    sizes = np.full(n * (n - 1), msg_packets_per_dst, dtype=np.int64)
    return _packets_from_flows("all_to_all", n, srcs, dsts, sizes)


def fsdp_rings(tree: FatTree, gpus_per_server: int, msg_packets: int,
               rng: np.random.Generator) -> Workload:
    """The paper's §8.4 FSDP scenario mapped onto this fat tree.

    ``n_hosts`` physical ports host ``n_hosts`` logical GPUs grouped into
    servers of ``gpus_per_server``; servers are placed at random on
    consecutive-port groups.  Inter-server traffic follows
    ``gpus_per_server`` parallel rings: logical GPU i sends to logical GPU
    (i + gpus_per_server) mod n -- i.e. each server sends ``gpus_per_server``
    parallel flows to the next server in the logical ring.
    """
    n = tree.n_hosts
    g = gpus_per_server
    if n % g:
        raise ValueError("host count must be divisible by gpus_per_server")
    n_servers = n // g
    # Random placement: logical server s occupies physical ports
    # place[s]*g .. place[s]*g+g-1.
    place = rng.permutation(n_servers)
    phys = (place[:, None] * g + np.arange(g)[None, :]).reshape(-1)  # logical gpu -> port
    logical_dst = (np.arange(n) + g) % n
    flow_src = phys
    flow_dst = phys[logical_dst]
    sizes = np.full(n, msg_packets, dtype=np.int64)
    return _packets_from_flows("fsdp_rings", n, flow_src, flow_dst, sizes)
