"""Shared batching helpers for the two fabric engines.

``fastsim`` (layered max-plus) and ``loopsim`` (slotted feedback) batch the
same way: per-point operands are padded host-side to shared shapes, stacked
onto one fused batch axis, and dispatched through a single jitted (and
optionally ``shard_map``-sharded) executable.  The shape-bucketing and
padding primitives live here so the two engines stop growing divergent
copies:

  * :func:`pow2_bucket` -- the power-of-two shape bucket both the planner
    and the engines use so nearby array sizes share one compile;
  * :func:`pad_tail` -- constant-fill tail padding along one axis;
  * :func:`pad_to_group_max` -- pad a group of same-rank arrays to their
    element-wise maximum shape (scheme tables, OFAN rotation orders);
  * :func:`shard_pad` -- round a stacked batch up to a multiple of the shard
    count by replicating the tail element (results are dropped);
  * :func:`rank_by` -- rank of each element among same-key valid elements,
    the associative-scan arbitration primitive the slotted engine uses for
    same-slot switch arrivals.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np
import jax
import jax.numpy as jnp


def pow2_bucket(n: int) -> int:
    """Next power of two >= ``n`` (and >= 1): sizes landing in one bucket
    share a compiled pipeline shape."""
    return 1 << max(0, int(n - 1).bit_length())


def pad_tail(x: np.ndarray, axis: int, target: int, fill=0) -> np.ndarray:
    """Pad ``x`` along ``axis`` up to ``target`` with constant ``fill``."""
    if x.shape[axis] >= target:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, target - x.shape[axis])
    return np.pad(x, widths, constant_values=fill)


def pad_to_group_max(arrays: Sequence[np.ndarray], fill=0) -> List[np.ndarray]:
    """Pad every array of a same-rank group to the element-wise max shape."""
    ndim = arrays[0].ndim
    shape = tuple(max(a.shape[ax] for a in arrays) for ax in range(ndim))
    out = []
    for a in arrays:
        for ax, tgt in enumerate(shape):
            a = pad_tail(a, ax, tgt, fill)
        out.append(a)
    return out


def shard_pad(stacked: Dict, n_batch: int, n_shards: int):
    """Round the stacked batch up to a multiple of ``n_shards`` by
    replicating the last element (padding results are dropped by the
    caller's span bookkeeping).  Returns the (possibly) padded pytree."""
    b_pad = -(-n_batch // n_shards) * n_shards
    if b_pad == n_batch:
        return stacked
    return jax.tree_util.tree_map(
        lambda x: np.concatenate(
            [x, np.repeat(x[-1:], b_pad - n_batch, axis=0)]), stacked)


def rank_by(keys: jnp.ndarray, valid: jnp.ndarray) -> jnp.ndarray:
    """Rank of each element among same-key valid elements (sort-based)."""
    m = keys.shape[0]
    k = jnp.where(valid, keys, jnp.int32(2**30))
    order = jnp.argsort(k, stable=True)
    ks = k[order]
    idx = jnp.arange(m, dtype=jnp.float32)
    flag = jnp.concatenate([jnp.ones((1,), bool), ks[1:] != ks[:-1]])
    start = jax.lax.associative_scan(
        lambda a, b: (jnp.where(b[1], b[0], jnp.maximum(a[0], b[0])),
                      a[1] | b[1]),
        (jnp.where(flag, idx, -1.0), flag))[0]
    rank_sorted = (idx - start).astype(jnp.int32)
    inv = jnp.zeros_like(order).at[order].set(jnp.arange(m))
    return jnp.where(valid, rank_sorted[inv], 0)
