"""Shared batching helpers for the two fabric engines.

``fastsim`` (layered max-plus) and ``loopsim`` (slotted feedback) batch the
same way: per-point operands are padded host-side to shared shapes, stacked
onto one fused batch axis, and dispatched through a single jitted (and
optionally ``shard_map``-sharded) executable.  The shape-bucketing and
padding primitives live here so the two engines stop growing divergent
copies:

  * :func:`pow2_bucket` -- the power-of-two shape bucket both the planner
    and the engines use so nearby array sizes share one compile;
  * :func:`k_buckets` -- the tree-size analog: group a campaign's fat-tree
    sizes so every tree pads to the largest ``k`` of its bucket and the whole
    bucket shares ONE compiled pipeline;
  * :class:`TreePad` -- scatter index maps between a real fat tree and the
    padded (bucket-max) fat tree: where each real switch / pointer / queue id
    lands in the padded coordinate space;
  * :func:`pad_tail` -- constant-fill tail padding along one axis;
  * :func:`pad_to_group_max` -- pad a group of same-rank arrays to their
    element-wise maximum shape (scheme tables, OFAN rotation orders);
  * :func:`shard_pad` -- round a stacked batch up to a multiple of the shard
    count by replicating the tail element (results are dropped);
  * :func:`rank_by` -- rank of each element among same-key valid elements,
    the associative-scan arbitration primitive the slotted engine uses for
    same-slot switch arrivals;
  * :func:`port_pad_penalty` -- the JSQ guard both engines add to their
    port-choice scores so tree-size padding can never elect a port beyond a
    point's logical ``k/2``.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np
import jax
import jax.numpy as jnp


def pow2_bucket(n: int) -> int:
    """Next power of two >= ``n`` (and >= 1): sizes landing in one bucket
    share a compiled pipeline shape.  ``n <= 0`` clamps to 1 -- degenerate
    empty workloads and zero slot budgets land in the smallest bucket
    (``(-1).bit_length() == 1``, so the unclamped formula returned 2 for
    ``n == 0``, violating the >= 1 / next-pow2 contract)."""
    return 1 << max(0, int(max(n, 1) - 1).bit_length())


def k_buckets(trees: Sequence[int]) -> Dict[int, int]:
    """Group fat-tree sizes into padding buckets: ``{k: k_pad}``.

    Greedy from the largest tree down: a tree joins the current bucket when
    padding it to the bucket head costs at most 2x in ``k``, otherwise it
    opens its own bucket.  For workloads whose packet count is linear in
    the host count (permutation, fsdp_rings) that bounds the padding waste
    at 8x packet rows -- k^3/4 hosts; all_to_all is quadratic in hosts, so
    its waste can reach ~64x at a full 2x pad (the cost-model-driven bucket
    policy in ROADMAP.md is the standing fix).  Every ``k`` of one bucket
    pads its topology operands to the bucket head and shares ONE compiled
    pipeline, so a campaign's dispatch count no longer scales with the
    number of tree sizes.  Buckets are campaign-relative (computed over
    the grid's ``trees`` axis): a single-size campaign never pads.
    """
    out: Dict[int, int] = {}
    head = 0
    for k in sorted(set(int(k) for k in trees), reverse=True):
        if head == 0 or head > 2 * k:
            head = k
        out[k] = head
    return out


class TreePad:
    """Index maps from a real fat tree's id spaces into a padded tree's.

    Both engines identify switches, DR/OFAN pointers and queues by dense
    ids derived from ``(pod, edge/agg, port)`` coordinates with modulus
    ``k``/``k/2``; running a small tree inside a larger compiled pipeline
    therefore needs every id-indexed operand scattered to the padded
    layout (real coordinates are unchanged -- they are simply sparse in the
    padded id space).  The maps below give, for each real id in order, its
    position in the padded space; scattering with them is monotone, so
    relative id order (and hence every sort-based arbitration) is
    preserved.  ``tree`` and ``padded`` are ``topology.FatTree``-likes
    (only ``k``/``half``/counts are used).
    """

    def __init__(self, tree, padded):
        if padded.k < tree.k:
            raise ValueError(f"cannot pad k={tree.k} down to k={padded.k}")
        self.tree, self.padded = tree, padded
        kr, hr = tree.k, tree.half
        hp = padded.half
        # Real switch id p*hr + e  ->  padded id p*hp + e  (edge and agg
        # layers share the (pod, index<k/2) coordinate scheme).
        self.switch = (np.arange(kr)[:, None] * hp
                       + np.arange(hr)[None, :]).reshape(-1)
        # Mid-layer queue id (x*hr + y)*hr + z -> (x*hp + y)*hp + z; the same
        # map serves UP_E/UP_A/DN_C/DN_A (all are k * (k/2)^2 spaces).
        self.mid = ((np.arange(kr)[:, None, None] * hp
                     + np.arange(hr)[None, :, None]) * hp
                    + np.arange(hr)[None, None, :]).reshape(-1)
        # OFAN edge pointer id  se*n_edges + de  (se-major, de-minor).
        ne_p = padded.n_edge_switches
        self.edge_pair = (self.switch[:, None] * ne_p
                          + self.switch[None, :]).reshape(-1)
        # OFAN/W-ECMP agg pointer id  ga*n_pods + dst_pod.
        self.agg_pod = (self.switch[:, None] * padded.n_pods
                        + np.arange(kr)[None, :]).reshape(-1)

    @property
    def noop(self) -> bool:
        return self.padded.k == self.tree.k

    def scatter(self, x: np.ndarray, idx: np.ndarray, size: int,
                axis: int = 0, fill=0) -> np.ndarray:
        """Place ``x``'s entries along ``axis`` at positions ``idx`` of a
        ``fill``-initialized axis of length ``size``."""
        shape = list(x.shape)
        shape[axis] = size
        out = np.full(shape, fill, dtype=x.dtype)
        sl = [slice(None)] * x.ndim
        sl[axis] = idx
        out[tuple(sl)] = x
        return out


def pad_tail(x: np.ndarray, axis: int, target: int, fill=0) -> np.ndarray:
    """Pad ``x`` along ``axis`` up to ``target`` with constant ``fill``."""
    if x.shape[axis] >= target:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, target - x.shape[axis])
    return np.pad(x, widths, constant_values=fill)


def pad_to_group_max(arrays: Sequence[np.ndarray], fill=0) -> List[np.ndarray]:
    """Pad every array of a same-rank group to the element-wise max shape."""
    ndim = arrays[0].ndim
    shape = tuple(max(a.shape[ax] for a in arrays) for ax in range(ndim))
    out = []
    for a in arrays:
        for ax, tgt in enumerate(shape):
            a = pad_tail(a, ax, tgt, fill)
        out.append(a)
    return out


def shard_pad(stacked: Dict, n_batch: int, n_shards: int):
    """Round the stacked batch up to a multiple of ``n_shards`` by
    replicating the last element (padding results are dropped by the
    caller's span bookkeeping).  Returns the (possibly) padded pytree."""
    b_pad = -(-n_batch // n_shards) * n_shards
    if b_pad == n_batch:
        return stacked
    return jax.tree_util.tree_map(
        lambda x: np.concatenate(
            [x, np.repeat(x[-1:], b_pad - n_batch, axis=0)]), stacked)


def port_pad_penalty(h: int, h_log) -> jnp.ndarray:
    """(h,) float32 additive JSQ score penalty masking padded port columns.

    Ports at indices >= ``h_log`` (the point's logical ``k/2``, a per-row
    operand) exist only because the pipeline is compiled for a larger padded
    tree; a huge penalty keeps ``argmin`` off them.  Real ports get ``0.0``,
    which is bitwise-neutral on the non-negative queue scores both engines
    build -- an unpadded point (``h_log == h``) is untouched.  Padded-tree
    queues are empty, so without this guard pre-convergence JSQ would
    happily elect a phantom empty port.
    """
    return jnp.where(jnp.arange(h) >= h_log, jnp.float32(1e9),
                     jnp.float32(0.0))


def rank_by(keys: jnp.ndarray, valid: jnp.ndarray) -> jnp.ndarray:
    """Rank of each element among same-key valid elements (sort-based)."""
    m = keys.shape[0]
    k = jnp.where(valid, keys, jnp.int32(2**30))
    order = jnp.argsort(k, stable=True)
    ks = k[order]
    idx = jnp.arange(m, dtype=jnp.float32)
    flag = jnp.concatenate([jnp.ones((1,), bool), ks[1:] != ks[:-1]])
    start = jax.lax.associative_scan(
        lambda a, b: (jnp.where(b[1], b[0], jnp.maximum(a[0], b[0])),
                      a[1] | b[1]),
        (jnp.where(flag, idx, -1.0), flag))[0]
    rank_sorted = (idx - start).astype(jnp.int32)
    inv = jnp.zeros_like(order).at[order].set(jnp.arange(m))
    return jnp.where(valid, rank_sorted[inv], 0)
