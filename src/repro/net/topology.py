"""k-ary 3-level fat-tree topology for the load-balancing fabric simulator.

Structure (standard fat-tree, k even):
  * ``k`` pods; each pod has ``k/2`` edge switches and ``k/2`` aggregation
    switches; each edge switch hosts ``k/2`` endpoints -> ``n = k^3/4`` hosts.
  * ``(k/2)^2`` core switches arranged in ``k/2`` *groups* of ``k/2``:
    core group ``a`` connects to aggregation switch index ``a`` of every pod.
    This is the "mandatory waypoint" property the paper's OFAN exploits:
    traffic leaving aggregation switch ``a`` of the source pod can only enter
    the destination pod through aggregation switch ``a``.

Queueing model: every directed inter-switch (and switch->host) link carries a
FIFO queue served at one data packet per slot.  Five queueing layers matter:

  ``UP_E``  edge -> aggregation      indexed (pod, edge, agg)
  ``UP_A``  aggregation -> core      indexed (pod, agg, core_sub)
  ``DN_C``  core -> aggregation      indexed (dst_pod, agg, core_sub)
  ``DN_A``  aggregation -> edge      indexed (pod, agg, edge)
  ``DN_E``  edge -> host             indexed (pod, edge, slot)  == host id

Host->edge uplinks are paced at the source (one packet per slot under the
ideal fixed-rate CCA) and therefore never queue; they contribute only
serialization + propagation latency.

Everything here is plain numpy precomputation; the simulation engines convert
to jnp arrays as needed.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

# Layer symbolic ids (stage order along an inter-pod path).
UP_E, UP_A, DN_C, DN_A, DN_E = 0, 1, 2, 3, 4
N_LAYERS = 5
LAYER_NAMES = ("E->A", "A->C", "C->A", "A->E", "E->H")

# A stage whose queue id is BYPASS is skipped (departure == arrival): used for
# intra-pod / intra-edge traffic that traverses fewer than 5 queues.
BYPASS = -1


@dataclasses.dataclass(frozen=True)
class FatTree:
    """Static description of a k-ary fat tree (no failure state)."""

    k: int

    def __post_init__(self):
        if self.k % 2 != 0 or self.k < 4:
            raise ValueError(f"fat-tree parameter k must be even and >= 4, got {self.k}")

    # ---- counts -----------------------------------------------------------
    @property
    def half(self) -> int:
        return self.k // 2

    @property
    def n_pods(self) -> int:
        return self.k

    @property
    def edges_per_pod(self) -> int:
        return self.half

    @property
    def aggs_per_pod(self) -> int:
        return self.half

    @property
    def hosts_per_edge(self) -> int:
        return self.half

    @property
    def hosts_per_pod(self) -> int:
        return self.half * self.half

    @property
    def n_hosts(self) -> int:
        return self.k * self.hosts_per_pod  # k^3/4

    @property
    def n_edge_switches(self) -> int:
        return self.k * self.half

    @property
    def n_agg_switches(self) -> int:
        return self.k * self.half

    @property
    def n_cores(self) -> int:
        return self.half * self.half

    @property
    def queues_per_mid_layer(self) -> int:
        # UP_E, UP_A, DN_C, DN_A all have k * (k/2)^2 queues.
        return self.k * self.half * self.half

    @property
    def n_queues(self) -> int:
        return 4 * self.queues_per_mid_layer + self.n_hosts

    # ---- host coordinate helpers (vectorized over numpy arrays) ----------
    def host_pod(self, h):
        return h // self.hosts_per_pod

    def host_edge(self, h):
        return (h % self.hosts_per_pod) // self.half

    def host_slot(self, h):
        return h % self.half

    def host_global_edge(self, h):
        """Global edge-switch id in [0, k*k/2)."""
        return self.host_pod(h) * self.half + self.host_edge(h)

    def host_id(self, pod, edge, slot):
        return (pod * self.half + edge) * self.half + slot

    # ---- per-layer queue ids ----------------------------------------------
    def qid_up_e(self, pod, edge, agg):
        return (pod * self.half + edge) * self.half + agg

    def qid_up_a(self, pod, agg, sub):
        return (pod * self.half + agg) * self.half + sub

    def qid_dn_c(self, dst_pod, agg, sub):
        return (dst_pod * self.half + agg) * self.half + sub

    def qid_dn_a(self, pod, agg, edge):
        return (pod * self.half + agg) * self.half + edge

    def qid_dn_e(self, host):
        return host

    def layer_sizes(self) -> Tuple[int, ...]:
        q = self.queues_per_mid_layer
        return (q, q, q, q, self.n_hosts)

    # ---- path stage computation (vectorized) ------------------------------
    def stage_queues(self, src: np.ndarray, dst: np.ndarray,
                     agg_choice: np.ndarray, sub_choice: np.ndarray) -> np.ndarray:
        """Per-packet queue id at each of the 5 stage layers.

        ``agg_choice`` in [0, k/2): which aggregation switch the packet uses on
        its way up (and, by the fat-tree waypoint property, also down).
        ``sub_choice`` in [0, k/2): which core inside group ``agg_choice``.

        Returns int32 array of shape (len(src), 5); BYPASS where a stage is
        skipped (intra-pod / intra-edge traffic).
        """
        src = np.asarray(src)
        dst = np.asarray(dst)
        agg_choice = np.asarray(agg_choice)
        sub_choice = np.asarray(sub_choice)
        p1, e1 = self.host_pod(src), self.host_edge(src)
        p2, e2 = self.host_pod(dst), self.host_edge(dst)
        inter_pod = p1 != p2
        same_edge = (p1 == p2) & (e1 == e2)
        intra_pod = (~inter_pod) & (~same_edge)

        n = src.shape[0]
        out = np.full((n, N_LAYERS), BYPASS, dtype=np.int64)
        # UP_E used whenever the packet leaves its edge switch.
        leaves_edge = ~same_edge
        out[leaves_edge, UP_E] = self.qid_up_e(p1, e1, agg_choice)[leaves_edge]
        # UP_A / DN_C only for inter-pod traffic.
        out[inter_pod, UP_A] = self.qid_up_a(p1, agg_choice, sub_choice)[inter_pod]
        out[inter_pod, DN_C] = self.qid_dn_c(p2, agg_choice, sub_choice)[inter_pod]
        # DN_A for anything that reached an aggregation switch.
        out[leaves_edge, DN_A] = self.qid_dn_a(p2, agg_choice, e2)[leaves_edge]
        # DN_E always.
        out[:, DN_E] = dst
        # (intra_pod packets: UP_E, DN_A, DN_E; same_edge: DN_E only)
        del intra_pod
        return out

    def n_hops(self, src, dst) -> np.ndarray:
        """Number of store-and-forward switch hops (for latency accounting)."""
        p1, e1 = self.host_pod(src), self.host_edge(src)
        p2, e2 = self.host_pod(dst), self.host_edge(dst)
        same_edge = (p1 == p2) & (e1 == e2)
        same_pod = p1 == p2
        return np.where(same_edge, 1, np.where(same_pod, 3, 5))


# --------------------------------------------------------------------------
# Failures
# --------------------------------------------------------------------------

@dataclasses.dataclass
class LinkState:
    """Alive/dead state of the bidirectional fabric links.

    ``ea[p, e, a]``  edge<->agg link in pod p between edge e and agg a.
    ``ac[p, a, c]``  agg<->core link between agg a of pod p and core (a, c).

    Following the paper's failure model, only edge-aggregation and
    aggregation-core links fail (host links and switches stay up), and a
    failed link is dead in both directions.
    """

    tree: FatTree
    ea: np.ndarray  # bool (k, k/2, k/2)
    ac: np.ndarray  # bool (k, k/2, k/2)

    @classmethod
    def all_up(cls, tree: FatTree) -> "LinkState":
        h = tree.half
        return cls(tree,
                   np.ones((tree.k, h, h), dtype=bool),
                   np.ones((tree.k, h, h), dtype=bool))

    @classmethod
    def random_failures(cls, tree: FatTree, p_fail: float,
                        rng: Optional[np.random.Generator] = None,
                        *, seed: Optional[int] = None) -> "LinkState":
        """Random i.i.d. link failures with probability ``p_fail``.

        Counter-keyed path (pass ``seed``): each link's fate is the Threefry
        stream of :mod:`repro.core.entropy` evaluated at (seed,
        SITE_LINK_FAIL, lane=tree.k, layer, flat link id) -- a pure function
        of the link's identity, stable across numpy versions and independent
        of draw order.  Legacy path (pass ``rng``): sequential ``Generator``
        draws, ``ea`` then ``ac``, kept so goldens recorded before the rekey
        stay reproducible.
        """
        h = tree.half
        if rng is not None:
            if seed is not None:
                raise ValueError("pass either rng (legacy) or seed, not both")
            ea = rng.random((tree.k, h, h)) >= p_fail
            ac = rng.random((tree.k, h, h)) >= p_fail
            return cls(tree, ea, ac)
        if seed is None:
            raise ValueError("random_failures needs rng (legacy) or seed=")
        from ..core import entropy as ent
        lo, hi = ent.key_words(seed)
        ids = np.arange(tree.k * h * h, dtype=np.uint32)
        u_ea = ent.draw_uniform(lo, hi, ent.SITE_LINK_FAIL, ids, slot=0,
                                lane=tree.k)
        u_ac = ent.draw_uniform(lo, hi, ent.SITE_LINK_FAIL, ids, slot=1,
                                lane=tree.k)
        return cls(tree,
                   (u_ea >= p_fail).reshape(tree.k, h, h),
                   (u_ac >= p_fail).reshape(tree.k, h, h))

    # ---- reachability / path validity -------------------------------------
    def inter_pod_path_alive(self, p1, e1, p2, e2, a, c):
        """Vectorized: is the (a, c) path from (p1,e1) to (p2,e2) fully alive?"""
        return (self.ea[p1, e1, a] & self.ac[p1, a, c]
                & self.ac[p2, a, c] & self.ea[p2, e2, a])

    def intra_pod_path_alive(self, p, e1, e2, a):
        return self.ea[p, e1, a] & self.ea[p, e2, a]

    def path_matrix(self, src: int, dst: int) -> np.ndarray:
        """Boolean (k/2, k/2) of valid (agg, sub) choices for src->dst.

        For intra-pod traffic the core sub-choice is irrelevant: the matrix is
        constant along axis 1.  For same-edge traffic everything is valid
        (the path does not traverse any failing link).
        """
        t = self.tree
        h = t.half
        p1, e1 = int(t.host_pod(src)), int(t.host_edge(src))
        p2, e2 = int(t.host_pod(dst)), int(t.host_edge(dst))
        a = np.arange(h)[:, None]
        c = np.arange(h)[None, :]
        if p1 != p2:
            return self.inter_pod_path_alive(p1, e1, p2, e2, a, c)
        if e1 != e2:
            return np.broadcast_to(self.intra_pod_path_alive(p1, e1, e2, a), (h, h)).copy()
        return np.ones((h, h), dtype=bool)

    def any_failure(self) -> bool:
        return not (self.ea.all() and self.ac.all())

    # ---- W-ECMP weights -----------------------------------------------------
    def wecmp_edge_weights(self, src_pod: int, src_edge: int,
                           dst_pod: int, dst_edge: int) -> np.ndarray:
        """Raw W-ECMP weight per uplink ``a`` of the source edge switch toward
        a destination edge switch: the number of distinct alive paths through
        aggregation switch ``a`` (paper App. F.4 / [51])."""
        h = self.tree.half
        w = np.zeros(h, dtype=np.int64)
        for a in range(h):
            if not self.ea[src_pod, src_edge, a]:
                continue
            if src_pod == dst_pod:
                w[a] = int(self.ea[dst_pod, dst_edge, a])
            else:
                cores = self.ac[src_pod, a, :] & self.ac[dst_pod, a, :]
                w[a] = int(cores.sum()) if self.ea[dst_pod, dst_edge, a] else 0
        return w

    def wecmp_agg_weights(self, src_pod: int, agg: int, dst_pod: int) -> np.ndarray:
        """Raw W-ECMP weight per core sub-link ``c`` of aggregation switch
        ``agg`` toward a destination pod (1 path per alive core pair)."""
        if src_pod == dst_pod:
            raise ValueError("agg weights are for inter-pod traffic only")
        return (self.ac[src_pod, agg, :] & self.ac[dst_pod, agg, :]).astype(np.int64)


# --------------------------------------------------------------------------
# rho_max  (Appendix A): maximum uniform sending rate when every flow splits
# equally across all of its valid shortest paths.
# --------------------------------------------------------------------------

def rho_max(tree: FatTree, links: LinkState,
            src: np.ndarray, dst: np.ndarray) -> float:
    """Per-flow rate (fraction of line rate) such that the most-loaded link
    carries exactly line rate, under equal splitting across valid paths.

    Returns 1.0 when no link carries more than one flow unit (e.g. the
    failure-free permutation case).  Returns 0.0 if some flow is fully
    disconnected (no valid path).
    """
    h = tree.half
    load = {
        UP_E: np.zeros((tree.k, h, h)),
        UP_A: np.zeros((tree.k, h, h)),
        DN_C: np.zeros((tree.k, h, h)),
        DN_A: np.zeros((tree.k, h, h)),
        DN_E: np.zeros(tree.n_hosts),
    }
    src = np.asarray(src)
    dst = np.asarray(dst)
    for s, d in zip(src.tolist(), dst.tolist()):
        p1, e1 = int(tree.host_pod(s)), int(tree.host_edge(s))
        p2, e2 = int(tree.host_pod(d)), int(tree.host_edge(d))
        load[DN_E][d] += 1.0
        if p1 == p2 and e1 == e2:
            continue
        pm = links.path_matrix(s, d)
        if p1 == p2:
            valid = pm[:, 0]
            tot = valid.sum()
            if tot == 0:
                return 0.0
            share = valid / tot
            load[UP_E][p1, e1, :] += share
            load[DN_A][p2, :, e2] += share
        else:
            tot = pm.sum()
            if tot == 0:
                return 0.0
            share = pm / tot
            load[UP_E][p1, e1, :] += share.sum(axis=1)
            load[UP_A][p1, :, :] += share
            load[DN_C][p2, :, :] += share
            load[DN_A][p2, :, e2] += share.sum(axis=1)
    worst = max(float(v.max()) for v in load.values())
    if worst <= 1.0:
        return 1.0
    return 1.0 / worst
