"""Layered max-plus fabric engine (the "fast" simulator).

TPU-idiomatic reformulation of a packet-level fat-tree simulation: with the
paper's uniform workloads (identical packet sizes, synchronized line-rate
senders) every queue is FIFO with unit service time (1 slot = one data-packet
serialization), so per-queue departure times obey the Lindley recursion

    d_i = max(a_i, d_{i-1}) + 1

which is an *associative* segmented max-plus scan: expanding,
``d_i = i + 1 + max_{j<=i, same queue}(a_j - j)``.  A 5-hop fat-tree traversal
therefore becomes five rounds of (lexsort by (queue, arrival), segmented
cumulative max, gather) -- dense, parallel, jit-compiled array ops instead of
an event loop.  The segmented cummax is the compute hot spot and has a Pallas
TPU kernel (``repro.kernels.lindley``); the default backend is
``jax.lax.associative_scan``.

Timing model
------------
* time unit: one data-packet slot ( (payload+header+gap) / line-rate );
* hosts pace at line rate (ideal fixed-rate CCA, §4) and carry a random
  fractional *phase* in [0,1): synchronized-but-not-atomically-aligned
  senders.  Phases are what give switch-local schemes (JSQ, RR) their
  "sticky flow" behavior (paper App. C) -- without sub-slot phases the
  arbitration would be ambiguous;
* propagation adds ``prop_slots`` per traversed link; it shifts arrival
  times but never changes queue dynamics;
* queue length seen by an arriving packet equals its waiting time in slots
  (unit service): ``occ_i = d_i - a_i - 1``.  Max/avg queue sizes and
  per-queue packet counts are derived from it.

Supported schemes: everything without ACK/ECN feedback -- ECMP, subflows,
host packet spraying, HOST DR, SIMPLE RR, SWITCH PKT (periodic re-permute),
RSQ, JSQ, SWITCH PKT AR (quantized JSQ), OFAN.  Feedback schemes (REPS, PLB,
MSwift) run on ``net.loopsim``.

Dynamic fault schedules (``repro.faults.FaultSchedule``, the ``fault=``
argument) time-slice the fabric into link-state epochs.  On this engine
failures act purely through *routing* (the max-plus pipeline has no drops):
each packet binds to the epoch whose reaction slot its integer release time
``wl.t_release`` has passed -- ``host_react`` delayed for host-visible
"pre" label choices (gathered host-side from per-epoch draws, so the
pipeline is unchanged) and ``switch_react`` delayed for switch-local OFAN
tables (an epoch axis on the pointer tables plus a per-packet seed-
independent ``ep_sw`` operand).  Binding at the seed-independent release
slot -- not the phase-adjusted arrival -- keeps the epoch map a static
operand shared by every seed.  rand/RR/JSQ port choices ignore link state
(exactly as they do under static failures here), so schedules are inert for
them by construction.  A single-epoch schedule is bitwise-identical to the
static ``links=`` path (tested in ``tests/test_faults.py``).

Dispatch granularities: :func:`simulate` (one point),
:func:`simulate_batch` (one point, seeds vmapped), and
:func:`simulate_megabatch` (many points sharing a pipeline shape fused onto
one batch axis, optionally ``shard_map``-sharded across devices) -- all
bitwise-identical per point.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from .topology import (FatTree, LinkState, N_LAYERS, LAYER_NAMES,
                       UP_E, UP_A, DN_C, DN_A, DN_E)
from .workloads import Workload
from ._batching import (TreePad, pad_tail as _pad_tail, pad_to_group_max,
                        port_pad_penalty, shard_pad)
from ..core.lb_schemes import LBScheme, precompute_host_choices
from ..core import entropy as ent
from ..core import ofan as ofan_mod
from ..obs.probes import QueueProbe, probe_shape

_NEG = -1.0e9


# ---------------------------------------------------------------------------
# Segmented max-plus scan.
# ---------------------------------------------------------------------------

def _segmented_cummax_ref(v: jnp.ndarray, seg_start: jnp.ndarray) -> jnp.ndarray:
    """Running max of ``v`` resetting wherever ``seg_start`` is True."""
    def combine(l, r):
        vl, fl = l
        vr, fr = r
        return jnp.where(fr, vr, jnp.maximum(vl, vr)), fl | fr
    out, _ = jax.lax.associative_scan(combine, (v, seg_start))
    return out


def segmented_cummax(v, seg_start, backend: str = "auto"):
    if backend in ("auto", "xla"):
        return _segmented_cummax_ref(v, seg_start)
    if backend == "pallas":
        from ..kernels.lindley import ops as _lops
        return _lops.segmented_cummax(v, seg_start)
    raise ValueError(backend)


def _ranks_and_starts(sorted_gkey: jnp.ndarray,
                      backend: str = "auto") -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Given group keys sorted ascending, return (rank within group, segment
    start flags)."""
    n = sorted_gkey.shape[0]
    if n == 0:      # zero-packet workload: no groups, no scan
        return (jnp.zeros((0,), jnp.int32), jnp.zeros((0,), bool))
    idx = jnp.arange(n, dtype=jnp.float32)
    flag = jnp.concatenate([jnp.ones((1,), bool),
                            sorted_gkey[1:] != sorted_gkey[:-1]])
    start = segmented_cummax(jnp.where(flag, idx, _NEG), flag, backend)
    rank = (idx - start).astype(jnp.int32)
    return rank, flag


# ---------------------------------------------------------------------------
# One queueing layer: Lindley over explicit queue ids.
# ---------------------------------------------------------------------------

def _lindley_layer(qid, a, tie, n_queues: int, backend: str):
    """FIFO service of one layer.  ``qid`` int32 (-1 => bypass).

    Returns (departure, counts[n_queues], occ): ``occ`` is the per-packet
    queue length seen on arrival (0 for bypass rows).  Occupancy sums are
    taken host-side over the unpadded packet slice so padding can never
    perturb the float reduction order (see :func:`_postprocess`).
    """
    npk = qid.shape[0]
    if npk == 0:    # zero-packet workload: the leading seg-start flag of
        # the scan below would be 1-long against 0-long values
        return a, jnp.zeros((n_queues,), jnp.int32), jnp.zeros((0,))
    real = qid >= 0
    qkey = jnp.where(real, qid, jnp.int32(2**30))
    order = jnp.lexsort((tie, a, qkey))
    inv = jnp.zeros_like(order).at[order].set(jnp.arange(npk))
    qs = qkey[order]
    av = a[order]
    idx = jnp.arange(npk, dtype=jnp.float32)
    flag = jnp.concatenate([jnp.ones((1,), bool), qs[1:] != qs[:-1]])
    m = segmented_cummax(av - idx, flag, backend)
    d_sorted = m + idx + 1.0
    real_s = qs < 2**30
    d_sorted = jnp.where(real_s, d_sorted, av)   # bypass: no service
    d = d_sorted[inv]
    occ = jnp.where(real, d - a - 1.0, 0.0)      # queue length seen on arrival
    counts = jnp.zeros((n_queues,), jnp.int32).at[
        jnp.where(real, qid, 0)].add(jnp.where(real, 1, 0))
    return d, counts, occ


# ---------------------------------------------------------------------------
# Rank-based switch port selection (SIMPLE RR / SWITCH PKT / OFAN).
# ---------------------------------------------------------------------------

def _ranked_ports(gkey, a, tie, active, select_fn, backend, extra=None):
    """Sort active packets by (group pointer key, arrival), compute the rank of
    each packet within its group, and map rank -> port via ``select_fn(gid,
    rank)``.  Inactive packets get port 0 (unused): masking them -- rather
    than letting them keep the pseudo-rank of the discard group -- keeps the
    reported per-packet ports deterministic under shape-bucketing padding
    (pad rows join the discard group and would otherwise shift the ranks,
    and hence the garbage ports, of real bypass packets).  ``extra`` (an
    optional per-packet operand, e.g. the fault-epoch index) is carried
    through the sort and handed to ``select_fn(gid, rank, extra)``."""
    npk = gkey.shape[0]
    g = jnp.where(active, gkey, jnp.int32(2**30))
    order = jnp.lexsort((tie, a, g))
    inv = jnp.zeros_like(order).at[order].set(jnp.arange(npk))
    gs = g[order]
    rank, _ = _ranks_and_starts(gs, backend)
    gid = jnp.where(gs < 2**30, gs, 0)
    if extra is None:
        port_sorted = select_fn(gid, rank)
    else:
        port_sorted = select_fn(gid, rank, extra[order])
    return jnp.where(active, port_sorted[inv], 0).astype(jnp.int32)


# ---------------------------------------------------------------------------
# JSQ layers (adaptive switch): padded per-switch scan.
# ---------------------------------------------------------------------------

def _jsq_layer(switch, a, tie, active, *, n_switches: int, pad: int, h: int,
               h_log, quanta: Optional[Tuple[float, ...]], buffer_pkts: int,
               noise, backend: str):
    """Joint port-choice + FIFO service for one adaptive layer.

    Returns (port, departure, occ_seen, max_rank).  ``noise`` is
    (n_switches, pad, h) pre-drawn uniforms for random tie-breaking.
    ``max_rank`` is the deepest per-switch arrival rank seen; the caller
    compares it against the *logical* pad limit (an operand, so megabatched
    runs padded to a group-wide grid can still flag exactly the elements a
    standalone run would re-pad).
    """
    npk = switch.shape[0]
    skey = jnp.where(active, switch, jnp.int32(2**30))
    order = jnp.lexsort((tie, a, skey))
    ss = skey[order]
    av = a[order]
    rank, _ = _ranks_and_starts(ss, backend)
    max_rank = (jnp.max(jnp.where(ss < 2**30, rank, 0)) if npk
                else jnp.int32(0))

    valid = ss < 2**30
    # Inactive packets scatter to row n_switches, which is out of bounds and
    # therefore dropped -- they must never clobber grid cells owned by real
    # packets of switch 0.
    rows = jnp.where(valid, ss, jnp.int32(n_switches))
    cols = jnp.clip(rank, 0, pad - 1)
    t_grid = jnp.full((n_switches, pad), jnp.float32(_NEG)).at[rows, cols].set(
        jnp.where(valid, av, _NEG))
    v_grid = jnp.zeros((n_switches, pad), bool).at[rows, cols].set(valid)

    thresholds = None
    if quanta is not None:
        thresholds = jnp.asarray(quanta, jnp.float32) * buffer_pkts
    # Ports beyond the point's logical k/2 exist only because the grid is
    # padded to a larger tree's width (shared guard with the slotted engine).
    port_pen = port_pad_penalty(h, h_log)

    def step(d_last, inp):
        t, ok, nz = inp
        qlen = jnp.ceil(jnp.maximum(d_last - t, 0.0))
        if thresholds is None:
            score = qlen + nz * 1e-3          # JSQ, random tie-break
        else:
            bin_ = jnp.sum(qlen[:, None] > thresholds[None, :], axis=1)
            score = bin_.astype(jnp.float32) + nz * 0.5
        p = jnp.argmin(score + port_pen)
        d_new = jnp.maximum(t, d_last[p]) + 1.0
        d_next = jnp.where(ok, d_last.at[p].set(d_new), d_last)
        return d_next, (p.astype(jnp.int32), jnp.where(ok, d_new, t),
                        qlen[p])

    def per_switch(times, oks, nzs):
        init = jnp.full((h,), jnp.float32(_NEG))
        _, (ports, deps, occs) = jax.lax.scan(step, init, (times, oks, nzs))
        return ports, deps, occs

    ports_g, deps_g, occs_g = jax.vmap(per_switch)(t_grid, v_grid, noise)
    port_sorted = ports_g[rows, cols]
    dep_sorted = deps_g[rows, cols]
    occ_sorted = occs_g[rows, cols]
    inv = jnp.zeros_like(order).at[order].set(jnp.arange(npk))
    port = jnp.where(active, port_sorted[inv], 0).astype(jnp.int32)
    dep = jnp.where(active, dep_sorted[inv], a)
    occ = jnp.where(active, occ_sorted[inv], 0.0)
    return port, dep, occ, max_rank


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LayerStats:
    counts: np.ndarray
    max_queue: float
    avg_wait: float


@dataclasses.dataclass
class FastSimResult:
    delivery: np.ndarray            # per-packet delivery time (slots)
    flow_completion: np.ndarray     # per-flow last-delivery (slots)
    cct: float                      # max over flows (slots)
    layers: Dict[str, LayerStats]
    max_queue: float                # max over all layers (packets)
    a_used: np.ndarray
    c_used: np.ndarray
    # Queue-occupancy time series, present only when the point ran with a
    # probe spec (see repro.obs.probes); per-layer max over the series
    # equals the corresponding LayerStats.max_queue exactly.
    probe: Optional[QueueProbe] = None

    def max_queue_layer(self, layer: int) -> float:
        return self.layers[LAYER_NAMES[layer]].max_queue


def _select_fn_for(mode: str, h, tables: dict):
    """Build select_fn(gid, rank)->port for rank-based modes.

    ``h`` is the *logical* port count of the point being simulated -- a
    per-row operand, not the compiled grid width: a point padded onto a
    larger tree's pipeline must still rotate over its own k/2 ports.
    """
    if mode == "rr":
        starts = tables["rr_starts"]          # (n_groups,)
        def f(gid, rank):
            return (starts[gid] + rank) % h
        return f
    if mode == "rr_reset":
        perms = tables["rr_perms"]            # (n_groups, n_epochs, h)
        starts = tables["rr_starts"]
        wraps = tables["reset_wraps"]
        n_epochs = perms.shape[1]
        def f(gid, rank):
            epoch = jnp.minimum(rank // (wraps * h), n_epochs - 1)
            return perms[gid, epoch, (starts[gid] + rank) % h]
        return f
    if mode == "ofan":
        orders = tables["orders"]             # (n_epochs, n_ptrs, W)
        starts = tables["starts"]             # (n_epochs, n_ptrs)
        lens = tables["lens"]                 # (n_epochs, n_ptrs)
        def f(gid, rank, ep):
            L = jnp.maximum(lens[ep, gid], 1)
            return orders[ep, gid, (starts[ep, gid] + rank) % L]
        return f
    raise ValueError(mode)


@dataclasses.dataclass
class SimPlan:
    """Seed-independent preparation of one (tree, workload, scheme, links)
    simulation point.

    Splitting this out of :func:`simulate` is what makes seed replication
    batchable: everything here is identical across seeds, while
    :func:`_draw_seed_inputs` produces the per-seed arrays that become the
    leading ``vmap`` axis in :func:`simulate_batch`.
    """
    tree: FatTree
    wl: Workload
    scheme: LBScheme
    prop_slots: float
    links: Optional[LinkState]
    backend: str
    jsq_pad_factor: float
    static_args: dict = dataclasses.field(default_factory=dict)
    # Fault-epoch state: one LinkState per epoch ([links] for static points),
    # per-epoch flow path matrices (None entries for failure-free epochs) and
    # the host-reaction epoch index of each packet (see _prepare).
    ep_links: list = dataclasses.field(default_factory=list)
    pv: Optional[list] = None
    ep_host: Optional[np.ndarray] = None
    n_reset_epochs: int = 1
    pad_e: int = 0
    pad_a: int = 0
    quanta: Optional[Tuple[float, ...]] = None
    tables_e_keys: Tuple[str, ...] = ()
    tables_a_keys: Tuple[str, ...] = ()

    @property
    def jsq(self) -> bool:
        return self.scheme.edge_mode in ("jsq", "jsq_quant")

    def build_run(self, batch, *, pad_e=None, pad_a=None, n_shards=1,
                  tree=None, probes=None):
        """``batch``: False | "seed" | "mega" (see :func:`_build_run`).
        ``pad_e``/``pad_a`` override the plan's own JSQ grid padding when a
        megabatch pads members to a group-wide maximum; ``tree`` overrides
        the plan's own tree when a megabatch pads members onto a k-bucket's
        largest fat tree.  ``probes`` (a ProbeSpec / (stride, samples)
        tuple) adds the per-layer queue-occupancy series output."""
        tree = self.tree if tree is None else tree
        scheme = self.scheme
        if batch is True:
            batch = "seed"
        probe_stride, probe_samples = probe_shape(probes)
        return _build_run(h=tree.half, n_pods=tree.n_pods,
                          n_edges=tree.n_edge_switches,
                          n_aggs=tree.n_agg_switches, n_hosts=tree.n_hosts,
                          edge_mode=scheme.edge_mode, agg_mode=scheme.agg_mode,
                          quanta=self.quanta, buffer_pkts=scheme.buffer_pkts,
                          reset_wraps=scheme.reset_wraps,
                          pad_e=self.pad_e if pad_e is None else pad_e,
                          pad_a=self.pad_a if pad_a is None else pad_a,
                          prop=float(self.prop_slots), backend=self.backend,
                          tables_e_keys=self.tables_e_keys,
                          tables_a_keys=self.tables_a_keys, batch=batch,
                          n_shards=n_shards, probe_stride=probe_stride,
                          probe_samples=probe_samples)


def _prepare(tree: FatTree, wl: Workload, scheme: LBScheme, prop_slots: float,
             links: Optional[LinkState], backend: str,
             jsq_pad_factor: float, fault=None) -> SimPlan:
    """Host-side precomputation shared by every seed of a simulation point."""
    if scheme.needs_feedback:
        raise ValueError(f"{scheme.name} needs ACK feedback; use net.loopsim")
    if fault is not None:
        if links is not None:
            raise ValueError("pass either links= or fault=, not both")
        comp = fault.compile(tree)
        ep_links = list(comp.links)
        links = ep_links[0]             # epoch-0 state for host-side consumers
        host_starts = comp.react_starts("host")
        switch_starts = comp.react_starts("switch")
    else:
        ep_links = [links]
        host_starts = switch_starts = np.zeros(1, np.int32)
    plan = SimPlan(tree=tree, wl=wl, scheme=scheme, prop_slots=prop_slots,
                   links=links, backend=backend, jsq_pad_factor=jsq_pad_factor)
    plan.ep_links = ep_links
    src, dst = wl.src, wl.dst
    p1 = tree.host_pod(src).astype(np.int32)
    e1 = tree.host_edge(src).astype(np.int32)
    p2 = tree.host_pod(dst).astype(np.int32)
    e2 = tree.host_edge(dst).astype(np.int32)
    inter_pod = (p1 != p2)
    leaves_edge = inter_pod | (e1 != e2)
    # Per-packet fault-epoch binding at the seed-independent integer release
    # slot: react starts are nondecreasing, so the epoch visible to packet p
    # is the last one whose reaction slot its release has passed (floored at
    # 0 -- pre-reaction routing sees the base epoch).  Static points get the
    # all-zeros map.
    ep_host = np.maximum(
        np.searchsorted(host_starts, wl.t_release, side="right") - 1,
        0).astype(np.int32)
    ep_sw = np.maximum(
        np.searchsorted(switch_starts, wl.t_release, side="right") - 1,
        0).astype(np.int32)
    plan.ep_host = ep_host
    plan.static_args = dict(p1=p1, e1=e1, p2=p2, e2=e2,
                            dst=dst.astype(np.int32), inter_pod=inter_pod,
                            leaves_edge=leaves_edge, ep_sw=ep_sw,
                            # Logical port count: an operand, so a point
                            # padded onto a larger tree's pipeline still
                            # rotates/sprays over its own k/2 ports.
                            h_log=np.int32(tree.half))

    # ---- path validity under failures (host visibility: converged state) --
    if scheme.edge_mode == "pre":
        pv = [np.stack([l.path_matrix(int(s), int(d))
                        for s, d in zip(wl.flow_src, wl.flow_dst)])
              if (l is not None and l.any_failure()) else None
              for l in ep_links]
        if any(x is not None for x in pv):
            plan.pv = pv

    h = tree.half
    plan.tables_e_keys = plan.tables_a_keys = scheme.table_keys()
    if scheme.edge_mode == "rr_reset":
        max_cnt = int(np.bincount(tree.host_global_edge(src)[leaves_edge],
                                  minlength=tree.n_edge_switches).max()
                      ) if leaves_edge.any() else 1
        plan.n_reset_epochs = max(
            1, int(np.ceil(max_cnt / (scheme.reset_wraps * h))))

    # ---- JSQ padding (workload-dependent, seed-independent) ----------------
    if plan.jsq:
        cnt_e = np.bincount(tree.host_global_edge(src)[leaves_edge],
                            minlength=tree.n_edge_switches)
        plan.pad_e = max(int(cnt_e.max()), 1)
        per_pod = np.bincount(p1[inter_pod], minlength=tree.n_pods)
        plan.pad_a = max(int(np.ceil(jsq_pad_factor * per_pod.max() / h)) + 64,
                         64)
    plan.quanta = (tuple(scheme.quanta) if scheme.edge_mode == "jsq_quant"
                   else None)
    # Logical JSQ pad limits travel as operands: a megabatch may execute this
    # point on a grid padded to a *group-wide* maximum, yet the overflow-and-
    # retry decision must match what a standalone run with this plan's own
    # padding would do.
    plan.static_args["pad_lim_e"] = np.int32(plan.pad_e if plan.jsq else 2**30)
    plan.static_args["pad_lim_a"] = np.int32(plan.pad_a if plan.jsq else 2**30)
    return plan


def _draw_seed_inputs(plan: SimPlan, seed: int) -> dict:
    """Per-seed randomness, drawn in the exact order the pre-batching engine
    used so results stay bit-identical run-to-run and serial-to-batched."""
    tree, wl, scheme = plan.tree, plan.wl, plan.scheme
    h = tree.half
    npk = wl.n_packets
    rng = np.random.default_rng(seed)

    phases = rng.random(wl.n_hosts).astype(np.float32)
    t_rel = (wl.t_release + phases[wl.src]).astype(np.float32)
    # Flow-static tie keys: consistent switch arbitration across slots (gives
    # RR/JSQ their sticky-flow behavior, App. C).
    tie = rng.random(wl.n_flows).astype(np.float32)[wl.flow]

    a_pre = c_pre = None
    if scheme.edge_mode == "pre":
        if plan.pv is None:
            a_pre, c_pre = precompute_host_choices(
                scheme, tree, wl.flow, wl.seq, wl.flow_src, wl.flow_dst, rng)
        else:
            # One sequential draw per epoch (epoch order extends the static
            # stream: a single-epoch schedule consumes exactly the static
            # path's draws), then gather each packet's host-reaction epoch.
            per_ep = [precompute_host_choices(
                scheme, tree, wl.flow, wl.seq, wl.flow_src, wl.flow_dst, rng,
                path_valid=pv_e) for pv_e in plan.pv]
            pk = np.arange(npk)
            a_pre = np.stack([a for a, _ in per_ep])[plan.ep_host, pk]
            c_pre = np.stack([c for _, c in per_ep])[plan.ep_host, pk]
        a_pre = a_pre.astype(np.int32)
        c_pre = c_pre.astype(np.int32)
    rand_a = rng.integers(0, h, npk).astype(np.int32)
    rand_c = rng.integers(0, h, npk).astype(np.int32)

    # ---- switch tables ------------------------------------------------------
    n_edges = tree.n_edge_switches
    n_aggs = tree.n_agg_switches
    tables_e: dict = {}
    tables_a: dict = {}
    if scheme.edge_mode in ("rr", "rr_reset"):
        tables_e["rr_starts"] = rng.integers(0, h, n_edges).astype(np.int32)
        tables_a["rr_starts"] = rng.integers(0, h, n_aggs).astype(np.int32)
        if scheme.edge_mode == "rr_reset":
            n_ep = plan.n_reset_epochs
            tables_e["rr_perms"] = np.argsort(
                rng.random((n_edges, n_ep, h)), axis=-1).astype(np.int32)
            tables_a["rr_perms"] = np.argsort(
                rng.random((n_aggs, n_ep, h)), axis=-1).astype(np.int32)
    elif scheme.edge_mode == "ofan":
        # One table build per fault epoch (epoch order; [links] for static
        # points, so E=1 consumes the static stream).  Pointer tables carry
        # an epoch axis -- width-padded to the widest epoch, pad columns
        # sit beyond every epoch's ``lens`` modulo and are never selected.
        ots = [ofan_mod.build_tables(tree, rng, links=l)
               for l in plan.ep_links]
        def _eps(arrs):
            return np.stack(pad_to_group_max([np.asarray(a) for a in arrs]))
        tables_e = {"orders": _eps([ot.edge_orders for ot in ots]),
                    "starts": _eps([ot.edge_starts for ot in ots]),
                    "lens": _eps([ot.edge_len for ot in ots])}
        tables_a = {"orders": _eps([ot.agg_orders for ot in ots]),
                    "starts": _eps([ot.agg_starts for ot in ots]),
                    "lens": _eps([ot.agg_len for ot in ots])}

    # JSQ tie-break noise comes from the counter streams (core.entropy),
    # keyed on (seed, site, logical switch id, arrival rank, port): the
    # same function the slotted engine evaluates in-loop, precomputed here
    # because the fast engine knows its arrival ranks host-side.  Growing
    # the rank axis (pad-overflow retry, megabatch group-wide padding)
    # extends the grid without perturbing existing entries.
    noise_e = noise_a = np.zeros((1, 1, 1), np.float32)
    if plan.jsq:
        noise_e = ent.uniform_grid(seed, ent.SITE_FAST_EDGE_JSQ,
                                   n_edges, plan.pad_e, h)
        noise_a = ent.uniform_grid(seed, ent.SITE_FAST_AGG_JSQ,
                                   n_aggs, plan.pad_a, h)

    return dict(t_rel=t_rel, tie=tie,
                a_pre=a_pre if a_pre is not None else np.zeros(npk, np.int32),
                c_pre=c_pre if c_pre is not None else np.zeros(npk, np.int32),
                rand_a=rand_a, rand_c=rand_c,
                noise_e=noise_e, noise_a=noise_a,
                te=tuple(np.asarray(tables_e[k]) for k in plan.tables_e_keys),
                ta=tuple(np.asarray(tables_a[k]) for k in plan.tables_a_keys))


def _postprocess(out: dict, wl: Workload, probes=None) -> FastSimResult:
    """Assemble a FastSimResult from one (unbatched) pipeline output tree."""
    delivery = out["delivery"]
    flow_completion = np.full(wl.n_flows, -np.inf)
    np.maximum.at(flow_completion, wl.flow, delivery)
    # Zero-packet flows (msg_packets=0, empty phases) receive no delivery
    # and would stay -inf; they complete instantly by definition.
    flow_completion[np.isneginf(flow_completion)] = 0.0
    layers = {}
    max_q = 0.0
    for li, name in enumerate(LAYER_NAMES):
        cnts = out["counts"][li]
        occ = np.asarray(out["occ"][li])
        mq = float(occ.max()) if occ.size else 0.0
        n_real = int(out["n_real"][li])
        # Host-side f64 sum over the (already unpadded) per-packet occupancy:
        # every dispatch granularity reduces the identical array, so padding
        # and fusion can never perturb the average through reduction order.
        aw = float(occ.sum(dtype=np.float64)) / max(n_real, 1)
        layers[name] = LayerStats(counts=cnts, max_queue=mq, avg_wait=aw)
        max_q = max(max_q, mq)
    probe = (QueueProbe(probe_shape(probes)[0], np.asarray(out["probe_q"]))
             if "probe_q" in out else None)
    return FastSimResult(delivery=delivery, flow_completion=flow_completion,
                         cct=float(delivery.max()) if delivery.size else 0.0,
                         layers=layers,
                         max_queue=max_q, a_used=out["a_used"],
                         c_used=out["c_used"], probe=probe)


def simulate(tree: FatTree, wl: Workload, scheme: LBScheme, seed: int = 0,
             prop_slots: float = 12.0, collect_stats: bool = True,
             links: Optional[LinkState] = None,
             backend: str = "auto", jsq_pad_factor: float = 4.0,
             probes=None, fault=None) -> FastSimResult:
    """Run one collective under ``scheme`` on the fast engine.

    ``fault`` (a ``repro.faults.FaultSchedule``) is the dynamic alternative
    to a static ``links`` pattern -- see the module docstring for the
    epoch-binding semantics on this engine.
    """
    plan = _prepare(tree, wl, scheme, prop_slots, links, backend,
                    jsq_pad_factor, fault=fault)
    run = plan.build_run(batch=False, probes=probes)
    out = run({**plan.static_args, **_draw_seed_inputs(plan, seed)})
    out = jax.tree_util.tree_map(np.asarray, out)
    if bool(out["overflow"]):
        if jsq_pad_factor > 64:
            raise RuntimeError("JSQ pad overflow even with huge padding")
        return simulate(tree, wl, scheme, seed=seed, prop_slots=prop_slots,
                        collect_stats=collect_stats, links=links,
                        backend=backend, jsq_pad_factor=jsq_pad_factor * 2,
                        probes=probes, fault=fault)
    return _postprocess(out, wl, probes)


def simulate_batch(tree: FatTree, wl: Workload, scheme: LBScheme,
                   seeds, prop_slots: float = 12.0,
                   collect_stats: bool = True,
                   links: Optional[LinkState] = None, backend: str = "auto",
                   jsq_pad_factor: float = 4.0, probes=None,
                   fault=None) -> list:
    """Run one simulation point for many seeds as a single vmapped dispatch.

    Per-seed randomness is drawn host-side exactly as :func:`simulate` draws
    it and stacked into a leading batch axis; the jitted pipeline is then
    ``jax.vmap``-ed over that axis, so the whole replicate set costs one
    compile + one dispatch.  Results are identical (bitwise, per seed) to
    serial :func:`simulate` calls; JSQ pad overflows are re-run with a larger
    pad only for the seeds that overflowed, matching the serial retry.
    """
    seeds = list(seeds)
    if not seeds:
        return []
    plan = _prepare(tree, wl, scheme, prop_slots, links, backend,
                    jsq_pad_factor, fault=fault)
    per_seed = [_draw_seed_inputs(plan, s) for s in seeds]
    stacked = jax.tree_util.tree_map(lambda *xs: np.stack(xs), *per_seed)
    run = plan.build_run(batch=True, probes=probes)
    out = run({**plan.static_args, **stacked})
    out = jax.tree_util.tree_map(np.asarray, out)

    results: dict = {}
    retry = []
    for i, s in enumerate(seeds):
        if bool(out["overflow"][i]):
            retry.append(s)
        else:
            out_i = jax.tree_util.tree_map(lambda x: x[i], out)
            results[s] = _postprocess(out_i, wl, probes)
    if retry:
        if jsq_pad_factor > 64:
            raise RuntimeError("JSQ pad overflow even with huge padding")
        redone = simulate_batch(tree, wl, scheme, retry,
                                prop_slots=prop_slots,
                                collect_stats=collect_stats, links=links,
                                backend=backend,
                                jsq_pad_factor=jsq_pad_factor * 2,
                                probes=probes, fault=fault)
        results.update(dict(zip(retry, redone)))
    return [results[s] for s in seeds]


# ---------------------------------------------------------------------------
# Megabatch: fuse (scheme x load x failure x seed) onto one batch axis.
# ---------------------------------------------------------------------------

# Per-packet pipeline arguments (padded to the bucketed packet count).
_PKT_KEYS = ("p1", "e1", "p2", "e2", "dst", "inter_pod", "leaves_edge",
             "ep_sw", "t_rel", "tie", "a_pre", "c_pre", "rand_a", "rand_c")


def _pipeline_identity(plan: SimPlan) -> Tuple:
    """Everything two plans must agree on to share one megabatched dispatch
    (shapes of per-packet arrays and JSQ grids are padded, and tree sizes
    pad to the group's largest k; this is the rest)."""
    return (plan.scheme.shape_key(), plan.tables_e_keys, plan.tables_a_keys,
            float(plan.prop_slots), plan.backend)


def _repad_elem(d: dict, plan: SimPlan, tp: TreePad) -> dict:
    """Re-lay one point's switch-id-indexed operands into the padded tree's
    id space (:class:`~._batching.TreePad`).  Per-packet coordinate arrays
    are untouched: real (pod, edge, port) coordinates are simply sparse in
    the padded id space, and the scatter maps are monotone, so every
    sort-based arbitration sees the same relative order as the standalone
    run.  Padded table rows are only ever indexed by inert pad packets."""
    if tp.noop:
        return d
    pt = tp.padded
    d = dict(d)
    n_sw = pt.n_edge_switches            # == n_agg_switches

    def _sw(x):
        return tp.scatter(x, tp.switch, n_sw)

    for key, keys, ptr_idx, n_ptr in (
            ("te", plan.tables_e_keys, tp.edge_pair, n_sw * n_sw),
            ("ta", plan.tables_a_keys, tp.agg_pod, n_sw * pt.n_pods)):
        tbl = dict(zip(keys, d[key]))
        if "rr_starts" in tbl:
            tbl["rr_starts"] = _sw(tbl["rr_starts"])
        if "rr_perms" in tbl:
            tbl["rr_perms"] = _sw(_pad_tail(tbl["rr_perms"], 2, pt.half))
        if "orders" in tbl:      # OFAN pointer tables, (n_epochs, n_ptr, W)
            tbl["orders"] = tp.scatter(tbl["orders"], ptr_idx, n_ptr, axis=1)
            tbl["starts"] = tp.scatter(tbl["starts"], ptr_idx, n_ptr, axis=1)
            tbl["lens"] = tp.scatter(tbl["lens"], ptr_idx, n_ptr, axis=1)
        d[key] = tuple(tbl[k] for k in keys)
    if plan.jsq:
        for k in ("noise_e", "noise_a"):
            d[k] = _sw(_pad_tail(d[k], 2, pt.half))
    return d


def simulate_megabatch(items, *, prop_slots: float = 12.0,
                       backend: str = "auto", jsq_pad_factor: float = 4.0,
                       npk_pad: Optional[int] = None, n_shards=1,
                       k_pad: Optional[int] = None, probes=None) -> list:
    """Run many simulation points as ONE fused, jitted dispatch.

    ``items`` is a sequence of ``(tree, wl, scheme, seeds, links)`` tuples
    -- optionally ``(tree, wl, scheme, seeds, links, fault)`` with a
    ``repro.faults.FaultSchedule`` sixth element (mixed freely with
    5-tuples; ``links`` must then be None) -- whose points lower to the
    same compiled pipeline (equal ``LBScheme.shape_key()``, same backend)
    -- e.g. flow_ecmp, subflow_mptcp, host_pkt and host_dr grids on any
    mix of workloads, failure patterns, fault schedules and tree sizes.
    Fault epochs are per-packet gather indices bounded by each member's
    own epoch count, so epoch axes simply zero-pad to the group maximum
    alongside the other table axes and static/flapping members fuse.  Per-seed inputs are drawn host-side
    exactly as :func:`simulate` draws them, padded to shared shapes (packet
    arrays up to ``npk_pad``, JSQ noise grids and scheme tables up to
    group-wide maxima, switch-indexed tables scattered into the padded
    ``k_pad`` tree's id space; pad packets are inert bypass rows with
    ``dst = -1`` and padded switches never receive traffic), stacked onto
    one fused batch axis, and executed by a single ``vmap``-ed -- and, with
    ``n_shards > 1`` (or ``"auto"``), ``shard_map``-sharded -- dispatch.

    ``k_pad`` (default: the largest tree among the items) is the fat-tree
    size every member's topology operands pad to; the planner passes the
    k-bucket head so campaigns sweeping tree size share one compile.

    Returns one list of :class:`FastSimResult` per item (aligned with its
    ``seeds``); every result is bitwise-identical to the standalone
    :func:`simulate` call with the same arguments, including the JSQ
    pad-overflow retry decision (tested in ``tests/test_sweep.py`` and
    ``tests/test_differential.py``).
    """
    items = [(it[0], it[1], it[2], list(it[3]), it[4],
              it[5] if len(it) > 5 else None) for it in items]
    if not items or all(not it[3] for it in items):
        return [[] for _ in items]

    plans = [_prepare(tree, wl, scheme, prop_slots, links, backend,
                      jsq_pad_factor, fault=fz)
             for (tree, wl, scheme, _, links, fz) in items]
    idents = {_pipeline_identity(p) for p in plans}
    if len(idents) > 1:
        raise ValueError(f"megabatch items span {len(idents)} pipeline "
                         f"identities; group by LBScheme.shape_key() first")

    k_max = max(p.tree.k for p in plans)
    k_pad = k_max if k_pad is None else max(int(k_pad), k_max)
    tree_pad = next((p.tree for p in plans if p.tree.k == k_pad),
                    FatTree(k_pad))
    pads = [TreePad(p.tree, tree_pad) for p in plans]

    npk_max = max(p.wl.n_packets for p in plans)
    npk_pad = npk_max if npk_pad is None else max(int(npk_pad), npk_max)
    pad_e_m = max(p.pad_e for p in plans)
    pad_a_m = max(p.pad_a for p in plans)
    jsq = plans[0].jsq

    elems: list = []          # merged (static + per-seed) dicts, padded
    spans: list = []          # (item index, seed) per fused-axis element
    for i, ((tree, wl, scheme, seeds, links, fz), plan) in enumerate(
            zip(items, plans)):
        for s in seeds:
            d = _repad_elem({**plan.static_args,
                             **_draw_seed_inputs(plan, s)}, plan, pads[i])
            for k in _PKT_KEYS:
                d[k] = _pad_tail(d[k], 0, npk_pad,
                                 fill=-1 if k == "dst" else 0)
            if jsq:
                d["noise_e"] = _pad_tail(d["noise_e"], 1, pad_e_m)
                d["noise_a"] = _pad_tail(d["noise_a"], 1, pad_a_m)
            elems.append(d)
            spans.append((i, s))

    # Scheme tables (RR permutation epochs, OFAN rotation orders) are padded
    # per-position to the group-wide maximum shape; padded entries are only
    # ever indexed by inert packets, whose outputs are discarded.
    for key in ("te", "ta"):
        for j in range(len(elems[0][key])):
            padded = pad_to_group_max([d[key][j] for d in elems])
            for d, t in zip(elems, padded):
                d[key] = d[key][:j] + (t,) + d[key][j + 1:]

    stacked = jax.tree_util.tree_map(lambda *xs: np.stack(xs), *elems)

    n_batch = len(elems)
    if n_shards == "auto":
        n_shards = max(1, min(len(jax.devices()), n_batch))
    n_shards = int(n_shards)
    stacked = shard_pad(stacked, n_batch, n_shards)

    run = plans[0].build_run("mega", pad_e=pad_e_m, pad_a=pad_a_m,
                             n_shards=n_shards, tree=tree_pad, probes=probes)
    out = run(stacked)
    out = jax.tree_util.tree_map(np.asarray, out)

    results = [dict() for _ in items]
    retries: Dict[int, list] = {}
    for b, (i, s) in enumerate(spans):
        if bool(out["overflow"][b]):
            retries.setdefault(i, []).append(s)
            continue
        out_b = jax.tree_util.tree_map(lambda x: x[b], out)
        npk_i = plans[i].wl.n_packets
        for k in ("delivery", "a_used", "c_used"):
            out_b[k] = out_b[k][:npk_i]
        out_b["occ"] = out_b["occ"][:, :npk_i]
        if not pads[i].noop:
            # Gather per-queue packet counts back onto the real tree's queue
            # ids (padded queues hold zero: no real packet ever lands there).
            out_b["counts"] = ([c[pads[i].mid] for c in out_b["counts"][:4]]
                               + [out_b["counts"][4][:plans[i].tree.n_hosts]])
        results[i][s] = _postprocess(out_b, plans[i].wl, probes)

    # JSQ pad overflow: re-run exactly the (item, seed) cells a standalone
    # run would re-pad, through the seed-batched path (whose retry is itself
    # bitwise-identical to serial simulate).
    for i, retry_seeds in retries.items():
        tree, wl, scheme, _, links, fz = items[i]
        redone = simulate_batch(tree, wl, scheme, retry_seeds,
                                prop_slots=prop_slots, links=links,
                                backend=backend,
                                jsq_pad_factor=jsq_pad_factor * 2,
                                probes=probes, fault=fz)
        results[i].update(dict(zip(retry_seeds, redone)))

    return [[results[i][s] for s in seeds]
            for i, (_, _, _, seeds, _, _) in enumerate(items)]


# Positional order of the pipeline arguments; the first _N_STATIC are
# seed-independent (vmap in_axes=None in the seed-batched variant), the rest
# carry the seed batch axis.  In the megabatched variant ("mega") *every*
# argument carries the fused (scheme x load x failure x seed) axis.
_ARG_ORDER = ("p1", "e1", "p2", "e2", "dst", "inter_pod", "leaves_edge",
              "ep_sw", "pad_lim_e", "pad_lim_a", "h_log",
              "t_rel", "tie", "a_pre", "c_pre", "rand_a", "rand_c",
              "noise_e", "noise_a", "te", "ta")
_N_STATIC = 11


@functools.lru_cache(maxsize=64)
def _build_run(*, h, n_pods, n_edges, n_aggs, n_hosts, edge_mode, agg_mode,
               quanta, buffer_pkts, reset_wraps, pad_e, pad_a, prop, backend,
               tables_e_keys, tables_a_keys, batch, n_shards=1,
               probe_stride=0, probe_samples=0):
    """Compile the 5-layer pipeline for a given (scheme-shape, tree) config.

    ``batch`` selects the dispatch variant:

      * ``False``  -- one unbatched simulation (the serial baseline);
      * ``"seed"`` -- seed-vmapped: per-seed arguments carry a leading batch
        axis, seed-independent arguments are broadcast (``in_axes=None``);
      * ``"mega"`` -- megabatched: *every* argument carries the fused
        (scheme x load x failure x seed) leading axis, so schemes/loads that
        lower to the same pipeline stack into ONE dispatch.  With
        ``n_shards > 1`` the fused axis is additionally ``shard_map``-ed
        across the first ``n_shards`` devices (the batch size must be a
        multiple of ``n_shards``; the caller pads).

    The cache key is the *pipeline shape*: two schemes with the same
    modes/padding share one compiled executable, which the sweep planner
    exploits when fusing campaign grid points into megabatches.
    """

    mid = n_pods * h * h   # queues per middle layer

    def pipeline(p1, e1, p2, e2, dst, inter_pod, leaves_edge, ep_sw,
                 pad_lim_e, pad_lim_a, h_log, t_rel, tie,
                 a_pre, c_pre, rand_a, rand_c, noise_e, noise_a, te, ta):
        tbl_e = dict(zip(tables_e_keys, te))
        tbl_a = dict(zip(tables_a_keys, ta))
        if "rr_starts" in tbl_e:
            tbl_e["reset_wraps"] = reset_wraps
            tbl_a["reset_wraps"] = reset_wraps
        overflow = jnp.asarray(False)
        counts, occs, n_real = [], [], []
        # Probe inputs per layer: the arrival times that place each packet's
        # observed occupancy into a stride window, and the active mask that
        # keeps bypass/pad rows out of the series.
        p_arr, p_act = [], []

        a_t = t_rel + prop                      # arrival at source edge switch
        edge_switch = p1 * h + e1

        # ---------- UP_E ----------
        if edge_mode == "pre":
            a_used = a_pre
        elif edge_mode == "rand":
            a_used = rand_a
        elif edge_mode in ("rr", "rr_reset"):
            a_used = _ranked_ports(edge_switch, a_t, tie, leaves_edge,
                                   _select_fn_for("rr" if edge_mode == "rr"
                                                  else "rr_reset", h_log,
                                                  tbl_e),
                                   backend)
        elif edge_mode == "ofan":
            dst_edge = p2 * h + e2
            gkey = edge_switch * n_edges + dst_edge
            a_used = _ranked_ports(gkey, a_t, tie, leaves_edge,
                                   _select_fn_for("ofan", h_log, tbl_e),
                                   backend, extra=ep_sw)
        if edge_mode in ("jsq", "jsq_quant"):
            a_used, d, occ, max_rank = _jsq_layer(
                edge_switch, a_t, tie, leaves_edge, n_switches=n_edges,
                pad=pad_e, h=h, h_log=h_log, quanta=quanta,
                buffer_pkts=buffer_pkts, noise=noise_e, backend=backend)
            overflow |= max_rank >= pad_lim_e
            qid = jnp.where(leaves_edge, edge_switch * h + a_used, -1)
            cnt = jnp.zeros((mid,), jnp.int32).at[
                jnp.where(qid >= 0, qid, 0)].add(jnp.where(qid >= 0, 1, 0))
            counts.append(cnt); occs.append(occ)
            n_real.append(jnp.sum(leaves_edge))
        else:
            qid = jnp.where(leaves_edge, edge_switch * h + a_used, -1)
            d, cnt, occ = _lindley_layer(qid, a_t, tie, mid, backend)
            counts.append(cnt); occs.append(occ)
            n_real.append(jnp.sum(leaves_edge))
        p_arr.append(a_t); p_act.append(leaves_edge)
        a_t = jnp.where(leaves_edge, d + prop, a_t)

        # ---------- UP_A ----------
        agg_switch = p1 * h + a_used
        if agg_mode == "pre":
            c_used = c_pre
        elif agg_mode == "rand":
            c_used = rand_c
        elif agg_mode in ("rr", "rr_reset"):
            c_used = _ranked_ports(agg_switch, a_t, tie, inter_pod,
                                   _select_fn_for("rr" if agg_mode == "rr"
                                                  else "rr_reset", h_log,
                                                  tbl_a),
                                   backend)
        elif agg_mode == "ofan":
            gkey = agg_switch * n_pods + p2
            c_used = _ranked_ports(gkey, a_t, tie, inter_pod,
                                   _select_fn_for("ofan", h_log, tbl_a),
                                   backend, extra=ep_sw)
        if agg_mode in ("jsq", "jsq_quant"):
            c_used, d, occ, max_rank = _jsq_layer(
                agg_switch, a_t, tie, inter_pod, n_switches=n_aggs,
                pad=pad_a, h=h, h_log=h_log, quanta=quanta,
                buffer_pkts=buffer_pkts, noise=noise_a, backend=backend)
            overflow |= max_rank >= pad_lim_a
            qid = jnp.where(inter_pod, agg_switch * h + c_used, -1)
            cnt = jnp.zeros((mid,), jnp.int32).at[
                jnp.where(qid >= 0, qid, 0)].add(jnp.where(qid >= 0, 1, 0))
            counts.append(cnt); occs.append(occ)
            n_real.append(jnp.sum(inter_pod))
        else:
            qid = jnp.where(inter_pod, agg_switch * h + c_used, -1)
            d, cnt, occ = _lindley_layer(qid, a_t, tie, mid, backend)
            counts.append(cnt); occs.append(occ)
            n_real.append(jnp.sum(inter_pod))
        p_arr.append(a_t); p_act.append(inter_pod)
        a_t = jnp.where(inter_pod, d + prop, a_t)

        # ---------- DN_C (forced: core (a_used, c_used) -> agg a_used of p2) --
        qid = jnp.where(inter_pod, (p2 * h + a_used) * h + c_used, -1)
        d, cnt, occ = _lindley_layer(qid, a_t, tie, mid, backend)
        counts.append(cnt); occs.append(occ)
        n_real.append(jnp.sum(inter_pod))
        p_arr.append(a_t); p_act.append(inter_pod)
        a_t = jnp.where(inter_pod, d + prop, a_t)

        # ---------- DN_A (forced: agg a_used -> edge e2) ----------
        qid = jnp.where(leaves_edge, (p2 * h + a_used) * h + e2, -1)
        d, cnt, occ = _lindley_layer(qid, a_t, tie, mid, backend)
        counts.append(cnt); occs.append(occ)
        n_real.append(jnp.sum(leaves_edge))
        p_arr.append(a_t); p_act.append(leaves_edge)
        a_t = jnp.where(leaves_edge, d + prop, a_t)

        # ---------- DN_E (forced: edge -> host) ----------
        d, cnt, occ = _lindley_layer(dst, a_t, tie, n_hosts, backend)
        counts.append(cnt); occs.append(occ)
        # dst == -1 marks shape-bucketing pad packets (inert bypass rows);
        # without padding this equals dst.shape[0] exactly.
        n_real.append(jnp.sum(dst >= 0))
        p_arr.append(a_t); p_act.append(dst >= 0)
        delivery = d + prop

        out = {"delivery": delivery,
               "counts": counts,
               "occ": jnp.stack(occs),
               "n_real": jnp.stack([jnp.asarray(x, jnp.int32) for x in n_real]),
               "a_used": a_used, "c_used": c_used,
               "overflow": overflow}
        if probe_samples:
            # Scatter-max each packet's observed occupancy into the stride
            # window of its arrival time; inactive rows drop out entirely
            # (mode="drop"), arrivals past the horizon clamp into the last
            # window.  Per-layer max over the series therefore reduces the
            # exact value set LayerStats.max_queue reduces.
            stride = jnp.float32(probe_stride)
            last = probe_samples - 1
            qsr = jnp.zeros((N_LAYERS, probe_samples), jnp.float32)
            for li in range(N_LAYERS):
                si = jnp.clip((p_arr[li] // stride).astype(jnp.int32),
                              0, last)
                qsr = qsr.at[li, jnp.where(p_act[li], si, probe_samples)].max(
                    jnp.where(p_act[li], occs[li], 0.0), mode="drop")
            out["probe_q"] = qsr
        return out

    n_args = len(_ARG_ORDER)
    if batch == "mega":
        fn = jax.vmap(pipeline, in_axes=(0,) * n_args)
        if n_shards > 1:
            from jax.experimental.shard_map import shard_map
            from jax.sharding import Mesh, PartitionSpec
            mesh = Mesh(np.asarray(jax.devices()[:n_shards]), ("b",))
            fn = shard_map(fn, mesh=mesh, in_specs=PartitionSpec("b"),
                           out_specs=PartitionSpec("b"))
        jitted = jax.jit(fn)
    elif batch:                       # "seed" (True kept for back-compat)
        in_axes = (None,) * _N_STATIC + (0,) * (n_args - _N_STATIC)
        jitted = jax.jit(jax.vmap(pipeline, in_axes=in_axes))
    else:
        jitted = jax.jit(pipeline)

    def run(kw: dict):
        return jitted(*(kw[k] for k in _ARG_ORDER))

    return run
