"""Collective-phase training schedules (see ``phases.schedule``)."""
from .schedule import (CompiledPhases, Phase, PhaseSchedule,
                       phases_from_dict)

__all__ = ["CompiledPhases", "Phase", "PhaseSchedule", "phases_from_dict"]
