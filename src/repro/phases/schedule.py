"""Collective-phase training schedules on the fused campaign axis.

Production training traffic is *phased*: every iteration repeats a fixed
sequence of collectives -- MoE all-to-all dispatch/combine bursts, the
gradient all-reduce, FSDP ring shards -- and the metric that matters is
the *iteration time*, not any single snapshot's FCT ("High-speed
Networking for Giga-Scale AI Factories"; PRIME, arxiv 2507.23012).  This
module makes that traffic a first-class campaign axis, mirroring
``repro.faults.FaultSchedule``:

* :class:`Phase` -- one collective step (kind, bytes, participants).
* :class:`PhaseSchedule` -- a declarative sequence of phases repeated for
  ``iterations`` training steps.  ``from_model`` derives one from a named
  ``repro/configs`` model (e.g. ``"deepseek-v3-671b"``) + parallelism
  layout; each phase's implementation (one-shot vs rotation) is chosen by
  ``repro.collectives.planner`` from the phase's bytes and axis size.
* :class:`CompiledPhases` -- ``compile(tree, load)`` lowers the schedule
  into ONE fused ``net.workloads.Workload``: per-phase traffic matrices
  (ring permutation for all-reduce, one-shot or rotation-round for
  all-to-all, hierarchical rings for FSDP) concatenated with
  globally-offset flow ids, per-packet ``t_release`` shifted by the phase
  start slot (the fast engine's phase binding) and a per-flow
  ``flow_start`` array (the slotted engine's per-row gate operand).

Like ``FaultSchedule``, a schedule rides the fused campaign axis:
``Campaign.phases`` is a grid axis, the planner folds the phased packet
count into the fused key (``n_dispatches == n_shapes`` still holds), and
a single-phase schedule with zero start offset is bitwise-identical to
the equivalent static workload on both engines.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
import json
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..collectives.planner import FabricModel, plan_all_reduce, plan_all_to_all
from ..net import workloads
from ..net.topology import FatTree
from ..net.workloads import Workload


@dataclasses.dataclass(frozen=True)
class Phase:
    """One collective step of a training iteration.

    ``bytes`` follows the collectives planner's convention: total bytes
    for ``all_reduce``, bytes per (src, dst) pair for ``all_to_all``, and
    per-ring-hop bytes for ``fsdp_ring``.  ``n`` is the size of the
    parallelism axis the collective runs over (expert-parallel degree,
    data-parallel degree, ...) -- it drives the planner's one-shot vs
    rotation decision, while the simulated traffic always spans the
    campaign tree's hosts.  ``gap_slots`` adds idle slots after the
    phase's send window (compute between collectives).
    """
    name: str
    collective: str            # 'all_reduce' | 'all_to_all' | 'fsdp_ring'
    bytes: float
    n: int
    intra_pod: bool = False
    gap_slots: int = 0

    def __post_init__(self):
        if self.collective not in ("all_reduce", "all_to_all", "fsdp_ring"):
            raise ValueError(f"unknown collective {self.collective!r}")

    def to_dict(self) -> Dict:
        return {"name": self.name, "collective": self.collective,
                "bytes": float(self.bytes), "n": int(self.n),
                "intra_pod": bool(self.intra_pod),
                "gap_slots": int(self.gap_slots)}

    @classmethod
    def from_dict(cls, d: Dict) -> "Phase":
        return cls(name=d["name"], collective=d["collective"],
                   bytes=float(d["bytes"]), n=int(d["n"]),
                   intra_pod=bool(d.get("intra_pod", False)),
                   gap_slots=int(d.get("gap_slots", 0)))


@dataclasses.dataclass
class CompiledPhases:
    """A schedule lowered onto one tree + load: the fused workload plus the
    per-phase bookkeeping the runner needs for iteration-time records.

    ``workload.flow_start`` carries the per-flow phase start (slots); the
    fast engine sees the same offsets folded into ``t_release``.  Packet
    and flow index ranges are per *phase instance* (schedule phases x
    iterations), in schedule order.
    """
    workload: Workload
    phase_start: np.ndarray       # (n_instances,) int64 start slot
    pkt_lo: np.ndarray            # (n_instances,) int64 packet range
    pkt_hi: np.ndarray
    names: Tuple[str, ...]        # per instance
    impls: Tuple[str, ...]        # planner-chosen impl per instance
    iter_of: np.ndarray           # (n_instances,) int64 iteration index

    @property
    def n_instances(self) -> int:
        return int(self.phase_start.shape[0])


def _pair_counts(collective: str, impl: str, n_hosts: int) -> Tuple[int, int]:
    """(n_flows, flows_per_host) of a phase's traffic matrix on the tree."""
    if collective == "all_to_all" and impl == "xla":
        return n_hosts * (n_hosts - 1), n_hosts - 1
    # ring permutation / rotation round / fsdp rings: one flow per host
    return n_hosts, 1


@dataclasses.dataclass(frozen=True)
class PhaseSchedule:
    """A named sequence of collective phases repeated ``iterations`` times.

    ``slack`` scales each phase's send window beyond its serialization
    time (``flows_per_host * packets_per_flow`` slots at 1 pkt/slot) to
    leave drain room before the next phase starts; ``gpus_per_server``
    parameterizes the ``fsdp_ring`` traffic mapping.  Per-flow packet
    counts normalize so the largest phase sends ``load.msg_packets``
    packets per flow and the others scale by their byte ratio (minimum 1
    for any phase with positive traffic; degenerate phases -- ``n <= 1``
    or ``bytes <= 0`` -- compile to zero flows, the collectives planner's
    empty-plan edge).
    """
    name: str
    phases: Tuple[Phase, ...]
    iterations: int = 1
    slack: float = 1.5
    gpus_per_server: int = 4

    def __post_init__(self):
        if not self.phases:
            raise ValueError("PhaseSchedule needs at least one phase")
        if self.iterations < 1:
            raise ValueError("iterations must be >= 1")
        if self.slack <= 0:
            raise ValueError("slack must be positive")

    # -- identity ---------------------------------------------------------
    @property
    def n_phases(self) -> int:
        return len(self.phases)

    @property
    def n_instances(self) -> int:
        return self.n_phases * self.iterations

    def label(self) -> str:
        """Stable human-prefixed identity used in records and resume keys."""
        digest = hashlib.md5(json.dumps(
            [p.to_dict() for p in self.phases], sort_keys=True
        ).encode()).hexdigest()[:8]
        return (f"{self.name}-{self.n_phases}p{self.iterations}i"
                f"-s{self.slack:g}-{digest}")

    # -- (de)serialization ------------------------------------------------
    def to_dict(self) -> Dict:
        return {"kind": "phases", "name": self.name,
                "phases": [p.to_dict() for p in self.phases],
                "iterations": int(self.iterations),
                "slack": float(self.slack),
                "gpus_per_server": int(self.gpus_per_server)}

    @classmethod
    def from_dict(cls, d: Dict) -> "PhaseSchedule":
        return cls(name=d["name"],
                   phases=tuple(Phase.from_dict(p) for p in d["phases"]),
                   iterations=int(d.get("iterations", 1)),
                   slack=float(d.get("slack", 1.5)),
                   gpus_per_server=int(d.get("gpus_per_server", 4)))

    # -- derivation from a model config -----------------------------------
    @classmethod
    def from_model(cls, model: str, ep: int = 8, dp: int = 8,
                   tokens_per_rank: int = 4096, iterations: int = 1,
                   smoke: bool = False, **kw) -> "PhaseSchedule":
        """Derive the per-iteration collective sequence of a named
        ``repro/configs`` model under an (ep, dp) parallelism layout.

        Phases, in iteration order:

        * MoE dispatch + combine all-to-alls (one pair per MoE layer,
          folded into two aggregate phases) when the config has experts:
          each rank routes ``experts_per_tok`` activations of width
          ``moe_d_ff`` per token across the ``ep`` axis.
        * the gradient all-reduce over the ``dp`` axis (parameter bytes
          approximated by the dense transformer stack).
        * an FSDP ring all-gather phase when the config shards parameters
          over pods (``fsdp_over_pod``, e.g. DeepSeek-V3 671B).
        """
        from ..configs import get_config
        cfg = get_config(model, smoke=smoke)
        dt = 2 if cfg.dtype == "bfloat16" else 4
        phases: List[Phase] = []
        n_moe = cfg.n_layers - cfg.n_dense_layers
        if cfg.n_experts and cfg.experts_per_tok and n_moe > 0 and ep > 1:
            # Per (src, dst) pair bytes of one layer's dispatch a2a,
            # aggregated over the MoE layers of the iteration.
            pair = (tokens_per_rank * cfg.experts_per_tok * cfg.d_model
                    * dt / max(ep, 1))
            phases.append(Phase("moe_dispatch", "all_to_all",
                                bytes=pair * n_moe, n=ep))
            phases.append(Phase("moe_combine", "all_to_all",
                                bytes=pair * n_moe, n=ep))
        # Gradient all-reduce across data parallel: dense params only
        # (expert grads reduce inside the EP groups).
        dense_params = (cfg.n_layers * (4 * cfg.d_model * cfg.d_model
                                        + 2 * cfg.d_model * cfg.d_ff)
                        + cfg.vocab * cfg.d_model)
        phases.append(Phase("grad_allreduce", "all_reduce",
                            bytes=dense_params * dt, n=dp))
        if cfg.fsdp_over_pod:
            phases.append(Phase("fsdp_allgather", "fsdp_ring",
                                bytes=dense_params * dt / max(dp, 1), n=dp))
        return cls(name=model, phases=tuple(phases),
                   iterations=iterations, **kw)

    # -- lowering ---------------------------------------------------------
    def plans(self, fabric: Optional[FabricModel] = None) -> Tuple:
        """Per-phase ``collectives.planner.Plan`` (impl + estimate).  A
        degenerate phase (``n <= 1`` / ``bytes <= 0``) yields the planner's
        empty plan."""
        fabric = fabric if fabric is not None else FabricModel()
        out = []
        for p in self.phases:
            if p.collective == "all_reduce":
                out.append(plan_all_reduce(p.bytes, p.n, fabric,
                                           intra_pod=p.intra_pod))
            elif p.collective == "all_to_all":
                out.append(plan_all_to_all(p.bytes, p.n, fabric,
                                           intra_pod=p.intra_pod))
            else:   # fsdp_ring: always the hierarchical-ring mapping
                out.append(plan_all_reduce(p.bytes, p.n, fabric,
                                           intra_pod=False))
        return tuple(out)

    def _impl_of(self, phase: Phase, plan) -> str:
        if phase.collective == "fsdp_ring":
            return "fsdp_ring"
        if phase.collective == "all_reduce":
            return "ring"
        # all_to_all: planner picks one-shot ('xla') vs a rotation round
        return "rotation" if plan.impl == "rotation" else "xla"

    @functools.lru_cache(maxsize=64)
    def _shape(self) -> Tuple[Tuple[str, str, int], ...]:
        """(collective, impl, packets-per-flow-weight) per phase, with the
        largest phase normalized to weight 1.0 scaled later by the load's
        ``msg_packets``.  Degenerate phases get weight 0."""
        plans = self.plans()
        vols = []
        for p, pl in zip(self.phases, plans):
            degenerate = p.n <= 1 or p.bytes <= 0 or pl.impl == "none"
            vols.append(0.0 if degenerate else float(p.bytes))
        top = max(vols) if any(v > 0 for v in vols) else 1.0
        out = []
        for p, pl, v in zip(self.phases, plans, vols):
            out.append((p.collective, self._impl_of(p, pl), v / top))
        return tuple(out)

    def msg_packets(self, load_msg_packets: int) -> Tuple[int, ...]:
        """Packets per flow for each phase: the largest phase sends the
        load's ``msg_packets``, others scale by byte ratio (min 1 when
        non-degenerate, 0 when degenerate)."""
        base = int(load_msg_packets)
        out = []
        for _, _, w in self._shape():
            out.append(0 if w <= 0 else max(1, int(round(w * base))) if base
                       else 0)
        return tuple(out)

    def n_packets(self, k: int, load_msg_packets: int) -> int:
        """Total packet count on a k-ary fat tree WITHOUT materializing the
        workload -- the planner's bucketing input (must agree exactly with
        ``compile``'s output size)."""
        n_hosts = k ** 3 // 4
        mps = self.msg_packets(load_msg_packets)
        total = 0
        for (coll, impl, _), m in zip(self._shape(), mps):
            if m <= 0:
                continue
            n_flows, _ = _pair_counts(coll, impl, n_hosts)
            total += n_flows * m
        return total * self.iterations

    def compile(self, tree: FatTree, load_msg_packets: int,
                rng_seed: int = 0,
                gpus_per_server: Optional[int] = None) -> CompiledPhases:
        """Lower the schedule onto ``tree`` into one fused workload.

        Phase traffic matrices (per instance ``i = it * n_phases + p``):

        * ``all_reduce`` -> the ring-neighbor permutation host
          ``h -> (h+1) % n_hosts`` (what the fabric sees from ring RS+AG).
        * ``all_to_all`` with planner impl ``'xla'`` -> one-shot
          ``workloads.all_to_all``; impl ``'rotation'`` -> one rotation
          round, a random derangement seeded ``(rng_seed, i)`` (rounds are
          shape-identical, so one round represents the steady state).
        * ``fsdp_ring`` -> ``workloads.fsdp_rings`` with random server
          placement seeded ``(rng_seed, i)``.

        Phase ``i+1`` starts ``slack * window_i + gap_slots`` after phase
        ``i``: hosts pace 1 packet/slot, so a phase's serialization window
        is ``flows_per_host * msg_packets`` slots.  All phase workloads
        are built on the uniform (vectorized, flow-contiguous) path of
        ``_packets_from_flows``, so the concatenation stays
        flow-contiguous -- the slotted engine's layout invariant.
        """
        n_hosts = tree.n_hosts
        g = gpus_per_server if gpus_per_server is not None \
            else self.gpus_per_server
        mps = self.msg_packets(load_msg_packets)
        shape = self._shape()

        srcs, dsts, flows, seqs, rels = [], [], [], [], []
        fsrcs, fdsts, fsizes, fstarts = [], [], [], []
        starts, lows, highs, names, impls, iters = [], [], [], [], [], []
        start = 0
        pkt_off = 0
        flow_off = 0
        for it in range(self.iterations):
            for pi, (phase, (coll, impl, _), m) in enumerate(
                    zip(self.phases, shape, mps)):
                inst = it * self.n_phases + pi
                if m <= 0:
                    wl = workloads._packets_from_flows(
                        phase.name, n_hosts,
                        np.empty(0, np.int64), np.empty(0, np.int64),
                        np.empty(0, np.int64))
                elif coll == "all_reduce":
                    ring = (np.arange(n_hosts) + 1) % n_hosts
                    wl = workloads._packets_from_flows(
                        phase.name, n_hosts, np.arange(n_hosts), ring,
                        np.full(n_hosts, m, np.int64))
                elif coll == "fsdp_ring":
                    wl = workloads.fsdp_rings(
                        tree, g, m,
                        np.random.default_rng((rng_seed, inst)))
                elif impl == "xla":
                    wl = workloads.all_to_all(tree, m)
                else:   # rotation round
                    wl = workloads.permutation(
                        tree, m, np.random.default_rng((rng_seed, inst)))
                _, per_host = _pair_counts(coll, impl, n_hosts)
                window = int(math.ceil(self.slack * per_host * m)) \
                    + phase.gap_slots

                srcs.append(wl.src); dsts.append(wl.dst)
                flows.append(wl.flow + flow_off)
                seqs.append(wl.seq)
                rels.append(wl.t_release + start)
                fsrcs.append(wl.flow_src); fdsts.append(wl.flow_dst)
                fsizes.append(wl.flow_size)
                fstarts.append(np.full(wl.n_flows, start, np.int64))
                starts.append(start)
                lows.append(pkt_off); highs.append(pkt_off + wl.n_packets)
                names.append(phase.name)
                impls.append(impl)
                iters.append(it)
                pkt_off += wl.n_packets
                flow_off += wl.n_flows
                start += window

        fused = Workload(
            name=f"phases:{self.label()}", n_hosts=n_hosts,
            src=np.concatenate(srcs), dst=np.concatenate(dsts),
            flow=np.concatenate(flows), seq=np.concatenate(seqs),
            t_release=np.concatenate(rels),
            flow_src=np.concatenate(fsrcs), flow_dst=np.concatenate(fdsts),
            flow_size=np.concatenate(fsizes),
            flow_start=np.concatenate(fstarts) if flow_off else
            np.empty(0, np.int64))
        return CompiledPhases(
            workload=fused,
            phase_start=np.asarray(starts, np.int64),
            pkt_lo=np.asarray(lows, np.int64),
            pkt_hi=np.asarray(highs, np.int64),
            names=tuple(names), impls=tuple(impls),
            iter_of=np.asarray(iters, np.int64))


def phases_from_dict(d: Optional[Dict]) -> Optional[PhaseSchedule]:
    """Inverse of ``PhaseSchedule.to_dict`` accepting ``None``
    (the static-workload row of a ``Campaign.phases`` axis)."""
    if d is None:
        return None
    if d.get("kind") != "phases":
        raise ValueError(f"not a phase schedule dict: {d.get('kind')!r}")
    return PhaseSchedule.from_dict(d)
