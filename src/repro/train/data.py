"""Data pipeline: deterministic synthetic LM token streams with sharded,
double-buffered host loading.

Production shape: every (host, step) pair derives its batch shard from a
stateless counter-based RNG, so restarts resume mid-epoch bit-exactly from
the checkpointed step (no data-loader state to save), stragglers can't skew
the stream, and elastic re-sharding just re-partitions the index space.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Optional

import numpy as np
import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # synthetic structure: orderly enough that a model can reduce loss
    ngram: int = 3


def batch_for_step(cfg: DataConfig, step: int,
                   lo: int = 0, hi: Optional[int] = None) -> np.ndarray:
    """Tokens for sequences [lo, hi) of the step's global batch.

    Counter-based: tokens = f(seed, step, sequence_index) -- no stream state.
    The synthetic distribution is an ngram-ish recurrence so cross-entropy
    is learnable (used by the convergence example/test).
    """
    hi = cfg.global_batch if hi is None else hi
    rows = []
    for idx in range(lo, hi):
        # one Philox counter per (step, sequence): shard boundaries cannot
        # change the stream => elastic re-sharding is bit-exact
        rng = np.random.Generator(np.random.Philox(
            key=cfg.seed, counter=np.array([step, idx, 0, 0], np.uint64)))
        base = rng.integers(0, cfg.vocab, size=cfg.seq_len, dtype=np.int64)
        toks = base
        # ngram-ish recurrence: most tokens are a deterministic mix of the
        # previous tokens (predictable => loss can fall well below ln(V))
        for k in range(1, cfg.ngram):
            mix = np.roll(toks, k) * (k + 7)
            toks = np.where(rng.random(cfg.seq_len) < 0.8,
                            (mix + 13) % cfg.vocab, toks)
        toks[0] = base[0]
        rows.append(toks)
    return np.stack(rows).astype(np.int32)


class Loader:
    """Double-buffered background loader for one host's batch shard."""

    def __init__(self, cfg: DataConfig, lo: int = 0, hi: Optional[int] = None,
                 start_step: int = 0, prefetch: int = 2):
        self.cfg = cfg
        self.lo, self.hi = lo, hi
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        step = self._step
        while not self._stop.is_set():
            batch = batch_for_step(self.cfg, step, self.lo, self.hi)
            try:
                self._q.put((step, batch), timeout=1.0)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator[tuple]:
        while True:
            yield self._q.get()

    def close(self):
        self._stop.set()
