"""Hand-rolled optimizers (no optax in this environment).

* AdamW -- fp32 moments, decoupled weight decay; moments inherit the
  parameter sharding so optimizer state is fully FSDP-sharded.
* Adafactor -- factored second moment (row/col accumulators), the standard
  choice for the 100B+ archs where Adam moments would not fit HBM.

API: ``opt = make(name, lr=...); state = opt.init(params);
updates, state = opt.update(grads, state, params); params = apply(params,
updates)``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable  # (grads, state, params) -> (new_params, new_state)


def _tmap(f, *trees):
    return jax.tree_util.tree_map(f, *trees)


def adamw(lr: float = 1e-3, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1,
          warmup_steps: int = 100) -> Optimizer:
    def init(params):
        zeros = _tmap(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return {"mu": zeros,
                "nu": _tmap(lambda p: jnp.zeros(p.shape, jnp.float32), params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        sched = lr * jnp.minimum(1.0, step / warmup_steps)
        mu = _tmap(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                   state["mu"], grads)
        nu = _tmap(lambda v, g: b2 * v + (1 - b2)
                   * jnp.square(g.astype(jnp.float32)), state["nu"], grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, m, v):
            u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - sched * u).astype(p.dtype)
        new_params = _tmap(upd, params, mu, nu)
        return new_params, {"mu": mu, "nu": nu, "step": step}

    return Optimizer(init, update)


def adafactor(lr: float = 1e-2, decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0, warmup_steps: int = 100,
              min_dim_size_to_factor: int = 128) -> Optimizer:
    """Factored Adafactor (Shazeer & Stern).  Factors the trailing two dims
    of >=2D params when both exceed ``min_dim_size_to_factor``."""

    def _factored(shape):
        return (len(shape) >= 2 and shape[-1] >= min_dim_size_to_factor
                and shape[-2] >= min_dim_size_to_factor)

    def init(params):
        def per(p):
            if _factored(p.shape):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"acc": _tmap(per, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        beta = 1.0 - (step.astype(jnp.float32) + 1.0) ** -decay
        sched = lr * jnp.minimum(1.0, step / warmup_steps)

        def per(g, acc, p):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if "vr" in acc:
                vr = beta * acc["vr"] + (1 - beta) * g2.mean(axis=-1)
                vc = beta * acc["vc"] + (1 - beta) * g2.mean(axis=-2)
                rfac = jax.lax.rsqrt(
                    vr / jnp.maximum(vr.mean(axis=-1, keepdims=True), eps)
                    + eps)
                cfac = jax.lax.rsqrt(vc + eps)
                u = g * rfac[..., None] * cfac[..., None, :]
                new_acc = {"vr": vr, "vc": vc}
            else:
                v = beta * acc["v"] + (1 - beta) * g2
                u = g * jax.lax.rsqrt(v + eps)
                new_acc = {"v": v}
            # update clipping by RMS
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            newp = (p.astype(jnp.float32) - sched * u).astype(p.dtype)
            return newp, new_acc

        flat_p, tdef = jax.tree_util.tree_flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_a = tdef.flatten_up_to(state["acc"])
        outs = [per(g, a, p) for g, a, p in zip(flat_g, flat_a, flat_p)]
        new_params = tdef.unflatten([o[0] for o in outs])
        new_acc = tdef.unflatten([o[1] for o in outs])
        return new_params, {"acc": new_acc, "step": step}

    return Optimizer(init, update)


def make(name: str, **kw) -> Optimizer:
    if name == "adamw":
        return adamw(**kw)
    if name == "adafactor":
        return adafactor(**kw)
    raise ValueError(name)


def state_logical_axes(opt_name: str, param_axes):
    """Optimizer-state sharding mirrors parameter sharding."""
    if opt_name == "adamw":
        return {"mu": param_axes, "nu": param_axes,
                "step": ()}

    def per(ax):
        ax = tuple(ax)
        return {"vr": ax[:-1], "vc": ax[:-2] + ax[-1:]} \
            if len(ax) >= 2 else {"v": ax}
    # NOTE: factored accumulators of non-factored params keep full axes;
    # resolved leaf-by-leaf at sharding time (shapes decide).
    return {"acc": jax.tree_util.tree_map(
        lambda ax: ax, param_axes, is_leaf=lambda x: isinstance(x, tuple)),
        "step": ()}
