"""Fault tolerance & elasticity for multi-pod training.

Pieces:

* ``ResilientLoop`` -- wraps the train loop with checkpoint/restart:
  periodic async checkpoints, automatic restore-on-start, bounded retry with
  exponential backoff around transient step failures, and a health callback
  so an external orchestrator can fence a bad pod.

* ``elastic_remesh`` -- rebuilds the mesh after losing pods/hosts (e.g. 2
  pods -> 1) and re-shards a checkpointed train state onto it.  Works
  because checkpoints are mesh-agnostic (full logical arrays) and sharding
  rules re-resolve against the new mesh (divisibility-aware).

* ``StragglerMitigator`` -- tracks per-step wall times; when the rolling
  p50/last ratio exceeds a threshold it flags the step so the driver can
  (a) skip non-critical work (eval/logging), and -- at cluster scope --
  (b) shrink the DCN reduction group via ``elastic_remesh`` (bounded
  staleness: the slow pod's gradients are dropped for that step, matching
  the paper's observation that stragglers gate collective completion).

The same machinery backs the ``examples/fault_tolerant_train.py`` demo,
which kills the loop mid-run and restarts it bit-exactly.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Optional

import numpy as np
import jax

from . import checkpoint as ckpt_mod
from ..core.retry import retry_call
from ..models import sharding as sh


@dataclasses.dataclass
class FTConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    keep_last: int = 3
    max_retries: int = 3
    backoff_s: float = 1.0
    straggler_ratio: float = 2.0
    straggler_window: int = 20


class StragglerMitigator:
    def __init__(self, cfg: FTConfig):
        self.cfg = cfg
        self.times: deque = deque(maxlen=cfg.straggler_window)

    def record(self, dt: float) -> bool:
        """Returns True when this step was a straggler."""
        straggler = False
        if len(self.times) >= 5:
            p50 = float(np.median(self.times))
            straggler = dt > self.cfg.straggler_ratio * p50
        self.times.append(dt)
        return straggler


class ResilientLoop:
    """Checkpointed, retrying train loop driver."""

    def __init__(self, step_fn: Callable, state: Any, ft: FTConfig,
                 state_shardings: Any = None,
                 health_cb: Optional[Callable[[str], None]] = None):
        self.step_fn = step_fn
        self.ft = ft
        self.health_cb = health_cb or (lambda msg: None)
        self.ckpt = ckpt_mod.AsyncCheckpointer(ft.ckpt_dir, ft.keep_last)
        self.straggler = StragglerMitigator(ft)
        self.state_shardings = state_shardings

        # restore-on-start
        latest = ckpt_mod.latest_step(ft.ckpt_dir)
        if latest is not None:
            target = jax.tree_util.tree_map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
            state, extra = ckpt_mod.restore(ft.ckpt_dir, target,
                                            shardings=state_shardings)
            self.start_step = int(extra.get("global_step", latest))
            self.health_cb(f"restored checkpoint at step {self.start_step}")
        else:
            self.start_step = 0
        self.state = state

    def run(self, batches: Callable[[int], Any], n_steps: int,
            metrics_cb: Optional[Callable] = None):
        step = self.start_step
        while step < n_steps:
            batch = batches(step)
            t0 = time.monotonic()

            def one_step(batch=batch):
                state, metrics = self.step_fn(self.state, batch)
                jax.block_until_ready(metrics["loss"])
                return state, metrics

            self.state, metrics = retry_call(
                one_step, max_retries=self.ft.max_retries,
                backoff_s=self.ft.backoff_s,
                on_retry=lambda attempt, e, _d, step=step: self.health_cb(
                    f"step {step} attempt {attempt} failed: {e!r}; "
                    f"backing off"),
                on_exhausted=lambda e: self.ckpt.wait())
            dt = time.monotonic() - t0
            if self.straggler.record(dt):
                self.health_cb(f"straggler step {step}: {dt:.3f}s")
            if metrics_cb:
                metrics_cb(step, metrics, dt)
            step += 1
            if step % self.ft.ckpt_every == 0:
                self.ckpt.save(self.state, step,
                               extra={"global_step": step})
        self.ckpt.save(self.state, step, extra={"global_step": step})
        self.ckpt.wait()
        return self.state


def elastic_remesh(ckpt_dir: str, make_mesh: Callable, model, tcfg,
                   step: Optional[int] = None):
    """Restore a checkpoint onto a *new* mesh (e.g. after losing a pod).

    Returns (state, mesh).  Sharding rules re-resolve divisibility against
    the new mesh, so e.g. a 512-chip state reloads onto 256 chips with the
    fsdp axis automatically widened per shard.
    """
    from . import train_step as ts
    mesh = make_mesh()
    with sh.use_mesh(mesh):
        shapes = model.param_shapes()
        state_shapes = {
            "params": shapes,
            "opt": None,  # resolved below via template init on specs
            "step": jax.ShapeDtypeStruct((), np.int32),
        }
        # build a template by evaluating shapes of the optimizer init
        import jax.numpy as jnp
        from . import optimizer as opt_mod
        opt = opt_mod.make(model.cfg.optimizer, lr=tcfg.learning_rate)
        opt_shapes = jax.eval_shape(opt.init, shapes)
        state_shapes["opt"] = opt_shapes
        shardings = ts.shardings_for_state(model, mesh, tcfg)
        state, extra = ckpt_mod.restore(ckpt_dir, state_shapes, step=step,
                                        shardings=shardings)
    return state, mesh, extra
