"""Fault-tolerant checkpointing (no orbax in this environment).

Layout (one directory per step):

    <dir>/step_000123/
        manifest.msgpack      -- tree structure, shapes, dtypes, shard info,
                                 per-tensor checksums, config fingerprint
        arr_00000.npy ...     -- one file per leaf (per-host shard in a real
                                 multi-host run; full arrays here)
        _COMMITTED            -- atomic commit marker (written last)

Guarantees:
  * step-atomic: readers only consider directories with ``_COMMITTED``;
  * integrity: crc32 per tensor, verified on restore;
  * async: ``save_async`` snapshots to host RAM synchronously (cheap) and
    writes in a background thread so the train loop never blocks on disk;
  * elastic restore: ``restore`` takes target ShapeDtypeStructs + shardings
    and re-shards (device_put) onto whatever mesh the restarted job has --
    including a *smaller* mesh after losing a pod;
  * retention: ``keep_last`` pruning.
"""
from __future__ import annotations

import concurrent.futures as cf
import os
import pathlib
import shutil
import threading
import zlib
from typing import Any, Optional

import msgpack
import numpy as np
import jax


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, jax.tree_util.tree_structure(tree)


def save(tree: Any, directory: str, step: int, keep_last: int = 3,
         extra: Optional[dict] = None) -> str:
    """Synchronous atomic checkpoint; returns the committed path."""
    base = pathlib.Path(directory)
    ckpt = base / f"step_{step:08d}"
    tmp = base / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    paths, leaves, _ = _flatten_with_paths(tree)
    entries = []
    for i, (p, leaf) in enumerate(zip(paths, leaves)):
        arr = np.asarray(leaf)
        fname = f"arr_{i:05d}.npy"
        np.save(tmp / fname, arr)
        entries.append({
            "path": p, "file": fname, "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "crc": zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF,
        })
    manifest = {"step": step, "entries": entries, "extra": extra or {}}
    (tmp / "manifest.msgpack").write_bytes(msgpack.packb(manifest))
    (tmp / "_COMMITTED").write_bytes(b"ok")
    if ckpt.exists():
        shutil.rmtree(ckpt)
    os.replace(tmp, ckpt)
    _prune(base, keep_last)
    return str(ckpt)


class AsyncCheckpointer:
    """Snapshot-to-host synchronously, write in the background.

    ``wait()`` joins outstanding writes (call before exit / next save of the
    same step).  A failed write is re-raised on the next call, mirroring the
    orbax contract."""

    def __init__(self, directory: str, keep_last: int = 3):
        self.directory = directory
        self.keep_last = keep_last
        self._pool = cf.ThreadPoolExecutor(max_workers=1)
        self._future: Optional[cf.Future] = None

    def save(self, tree: Any, step: int, extra: Optional[dict] = None):
        self.wait()
        host_tree = jax.tree_util.tree_map(lambda x: np.asarray(x), tree)
        self._future = self._pool.submit(
            save, host_tree, self.directory, step, self.keep_last, extra)

    def wait(self) -> Optional[str]:
        if self._future is not None:
            result = self._future.result()
            self._future = None
            return result
        return None


def latest_step(directory: str) -> Optional[int]:
    base = pathlib.Path(directory)
    if not base.exists():
        return None
    steps = []
    for d in base.iterdir():
        if d.name.startswith("step_") and (d / "_COMMITTED").exists():
            steps.append(int(d.name.split("_")[1]))
    return max(steps) if steps else None


def restore(directory: str, target: Any, step: Optional[int] = None,
            shardings: Any = None, strict_integrity: bool = True):
    """Restore into the structure of ``target`` (ShapeDtypeStructs or
    arrays).  With ``shardings`` (same-structure NamedShardings) the arrays
    are device_put onto the current mesh -- elastic re-sharding comes free
    since the on-disk layout is mesh-agnostic."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {directory}")
    ckpt = pathlib.Path(directory) / f"step_{step:08d}"
    manifest = msgpack.unpackb((ckpt / "manifest.msgpack").read_bytes())

    paths, leaves, treedef = _flatten_with_paths(target)
    by_path = {e["path"]: e for e in manifest["entries"]}
    out = []
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(leaves))
    if len(shard_leaves) != len(leaves):
        shard_leaves = [None] * len(leaves)
    for p, leaf, shard in zip(paths, leaves, shard_leaves):
        e = by_path.get(p)
        if e is None:
            raise KeyError(f"checkpoint missing leaf {p}")
        arr = np.load(ckpt / e["file"])
        if strict_integrity:
            crc = zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF
            if crc != e["crc"]:
                raise IOError(f"checksum mismatch for {p} in {ckpt}")
        want_shape = tuple(getattr(leaf, "shape", arr.shape))
        if tuple(arr.shape) != want_shape:
            raise ValueError(f"shape mismatch for {p}: "
                             f"{arr.shape} vs {want_shape}")
        arr = arr.astype(getattr(leaf, "dtype", arr.dtype))
        out.append(jax.device_put(arr, shard) if shard is not None
                   else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), manifest.get("extra",
                                                                    {})


def _prune(base: pathlib.Path, keep_last: int):
    steps = sorted(d for d in base.iterdir()
                   if d.name.startswith("step_")
                   and (d / "_COMMITTED").exists())
    for d in steps[:-keep_last]:
        shutil.rmtree(d, ignore_errors=True)
