"""Sharded training step: microbatched gradient accumulation, remat,
cross-pod gradient reduction through the DR collective engine, optional
gradient compression.

Overlap design: the accumulation loop is a ``lax.scan`` over microbatches --
XLA overlaps microbatch i+1's forward with the tail of microbatch i's
backward collectives; the cross-pod (DCN) gradient reduction happens once
per step on the accumulated grads, optionally compressed (bf16/int8 + error
feedback) and scheduled as DR rotation rounds instead of one monolithic
all-reduce.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..models import sharding as sh
from ..models.registry import Model
from . import optimizer as opt_mod
from ..collectives import compression


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    warmup_steps: int = 100
    microbatch: int = 0               # 0: use cfg.microbatch (or 1)
    grad_clip: float = 1.0
    compress_dcn: Optional[str] = None   # None | 'bf16' | 'int8'
    seed: int = 0


def make_train_state(model: Model, params, tcfg: TrainConfig):
    opt = opt_mod.make(model.cfg.optimizer, lr=tcfg.learning_rate,
                       warmup_steps=tcfg.warmup_steps)
    return {"params": params, "opt": opt.init(params),
            "step": jnp.zeros((), jnp.int32)}


def _global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def build_train_step(model: Model, tcfg: TrainConfig):
    """Returns train_step(state, batch) -> (state, metrics).

    ``batch["tokens"]`` is (GB, S); with microbatching the leading dim is
    reshaped to (n_micro, GB/n_micro, S) and scanned.
    """
    opt = opt_mod.make(model.cfg.optimizer, lr=tcfg.learning_rate,
                       warmup_steps=tcfg.warmup_steps)
    n_micro = tcfg.microbatch or model.cfg.microbatch or 1

    def loss_fn(params, mb):
        return model.loss(params, mb)

    def train_step(state, batch):
        params = state["params"]

        if n_micro > 1:
            mb_batch = jax.tree_util.tree_map(
                lambda x: x.reshape((n_micro, x.shape[0] // n_micro)
                                    + x.shape[1:]), batch)

            def accum(carry, mb):
                gsum, lsum = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                gsum = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (gsum, lsum + l), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(accum, (zeros, 0.0), mb_batch)
            grads = jax.tree_util.tree_map(lambda g: g / n_micro, gsum)
            loss = lsum / n_micro
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)

        # Cross-pod DCN reduction with optional compression.  Within
        # pjit/GSPMD the batch sharding already implies gradient psums; the
        # explicit compression path is applied when enabled (shard_map over
        # 'pod') -- otherwise GSPMD's implicit reduction stands.
        if tcfg.compress_dcn is not None:
            grads = compression.compressed_psum_pod(grads, tcfg.compress_dcn)

        gnorm = _global_norm(grads)
        scale = jnp.minimum(1.0, tcfg.grad_clip / jnp.maximum(gnorm, 1e-6))
        grads = jax.tree_util.tree_map(lambda g: g * scale, grads)

        new_params, new_opt = opt.update(grads, state["opt"], params)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        return new_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


def shardings_for_state(model: Model, mesh, tcfg: TrainConfig):
    """NamedShardings for the train state pytree (params + opt + step)."""
    axes = model.logical_axes()
    shapes = model.param_shapes()

    def ns(ax, spec):
        return sh.named_sharding(ax, spec.shape, mesh)

    p_shard = jax.tree_util.tree_map(
        ns, axes, shapes, is_leaf=lambda x: isinstance(x, tuple))

    if model.cfg.optimizer == "adamw":
        opt_shard = {"mu": p_shard, "nu": p_shard,
                     "step": sh.named_sharding((), (), mesh)}
    else:
        def acc_shard(ax, spec):
            ax = tuple(ax)
            if (len(spec.shape) >= 2 and spec.shape[-1] >= 128
                    and spec.shape[-2] >= 128):
                return {"vr": sh.named_sharding(ax[:-1], spec.shape[:-1],
                                                mesh),
                        "vc": sh.named_sharding(
                            ax[:-2] + ax[-1:],
                            spec.shape[:-2] + spec.shape[-1:], mesh)}
            return {"v": sh.named_sharding(ax, spec.shape, mesh)}
        opt_shard = {"acc": jax.tree_util.tree_map(
            acc_shard, axes, shapes,
            is_leaf=lambda x: isinstance(x, tuple)),
            "step": sh.named_sharding((), (), mesh)}
    return {"params": p_shard, "opt": opt_shard,
            "step": sh.named_sharding((), (), mesh)}


def batch_shardings(model: Model, mesh, specs: dict):
    return jax.tree_util.tree_map(
        lambda s: sh.named_sharding(
            ("batch",) + (None,) * (len(s.shape) - 1), s.shape, mesh),
        specs)
