"""Logical-axis sharding rules + the runtime mesh context.

Model code annotates arrays with *logical* axes; the rules map them to mesh
axes, dropping any mapping that does not divide evenly (MaxText-style
fallback) so every architecture lowers on every mesh.

The production mesh axes:
  * ``pod``   -- DCN-connected pods: pure data parallelism (gradient
                 all-reduce crosses the fat-tree the paper studies);
  * ``data``  -- intra-pod FSDP: batch sharding + parameter/optimizer
                 sharding over the fsdp logical axis;
  * ``model`` -- tensor/expert parallelism.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> preferred mesh axes (first that divides wins; tuple values
# mean "shard jointly over these axes")
DEFAULT_RULES = {
    "batch": (("pod", "data"), ("data",), ("pod",)),
    "fsdp": (("data",),),
    "model": (("model",),),
    "experts": (("model",),),
    "kv_heads": (("model",),),           # cache head sharding (preferred)
    "seq_model": (("model",),),          # sequence sharding (EP token split)
    "seq_cache": (("model",),),          # KV-cache length sharding (decode)
    "vocab": (("model",),),
    "replicated": ((),),
}

# When several dims of one array resolve to the same mesh axis, the lower
# priority number wins (e.g. shard KV caches by heads when divisible, by
# sequence otherwise).
_PRIORITY = {"kv_heads": 0, "experts": 0, "model": 0, "vocab": 0,
             "batch": 1, "fsdp": 1, "seq_cache": 3, "seq_model": 3}

_ctx = threading.local()


def current_mesh() -> Optional[Mesh]:
    return getattr(_ctx, "mesh", None)


def current_rules() -> dict:
    return getattr(_ctx, "rules", None) or DEFAULT_RULES


def serve_rules(cfg, mesh=None) -> Optional[dict]:
    """Serving layout: replicate weights over the data axis (TP-only) when
    they fit HBM -- FSDP weight sharding forces per-layer all-gathers that
    dominate inference collectives (measured 47 GB/device on 32k prefill).
    Models too big to replicate (DeepSeek-V3) keep the FSDP layout."""
    from ..launch.roofline import params_count
    try:
        total_b = params_count(cfg)["total"] * 2          # bf16
    except Exception:
        return rules_for(cfg)
    mesh = mesh or current_mesh()
    model_sz = mesh.shape.get("model", 1) if mesh is not None else 1
    if total_b / max(model_sz, 1) <= 2 * 2**30:           # <=2 GiB/device
        rules = dict(DEFAULT_RULES)
        rules["fsdp"] = ((),)
        return rules
    return rules_for(cfg)


def rules_for(cfg) -> Optional[dict]:
    """Per-config rule overrides: the 100B+ archs FSDP-shard parameters and
    gradients across pods too (ZeRO-3 over the DCN) -- without it the fp32
    grad-accumulation buffers alone blow the per-chip HBM."""
    if getattr(cfg, "fsdp_over_pod", False):
        rules = dict(DEFAULT_RULES)
        rules["fsdp"] = (("pod", "data"), ("data",))
        return rules
    return None


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], rules: Optional[dict] = None):
    prev = getattr(_ctx, "mesh", None)
    prev_rules = getattr(_ctx, "rules", None)
    _ctx.mesh = mesh
    _ctx.rules = rules
    try:
        if mesh is not None:
            with mesh:
                yield
        else:
            yield
    finally:
        _ctx.mesh = prev
        _ctx.rules = prev_rules


def _axes_size(mesh: Mesh, axes) -> int:
    sz = 1
    for a in axes:
        sz *= mesh.shape[a]
    return sz


def resolve(logical, dim_size: int, mesh: Optional[Mesh] = None):
    """Logical axis name -> mesh axes (or None) honoring divisibility."""
    mesh = mesh or current_mesh()
    if mesh is None or logical is None:
        return None
    for axes in current_rules().get(logical, ((),)):
        axes = tuple(a for a in axes if a in mesh.shape)
        if not axes:
            continue
        if dim_size % _axes_size(mesh, axes) == 0:
            return axes if len(axes) > 1 else axes[0]
    return None


def spec_for(logical_axes, shape, mesh: Optional[Mesh] = None) -> P:
    """PartitionSpec for an array with the given logical axes.

    Duplicate mesh-axis assignments are resolved by _PRIORITY (a mesh axis
    can shard only one dim): e.g. a KV cache with both ``kv_heads`` and
    ``seq_cache`` mapping to 'model' shards heads when they divide, else
    falls back to sequence sharding."""
    mesh = mesh or current_mesh()
    resolved = [resolve(lg, s, mesh)
                for lg, s in zip(logical_axes, shape)]
    order = sorted(range(len(resolved)),
                   key=lambda i: _PRIORITY.get(logical_axes[i], 2))
    keep = [None] * len(resolved)
    taken = set()
    for i in order:
        r = resolved[i]
        if r is None:
            continue
        axes = r if isinstance(r, tuple) else (r,)
        if any(a in taken for a in axes):
            continue
        taken.update(axes)
        keep[i] = r
    return P(*keep)


def constrain(x, *logical_axes):
    """with_sharding_constraint via logical axes (no-op without a mesh)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = spec_for(logical_axes, x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(logical_axes, shape, mesh: Optional[Mesh] = None):
    mesh = mesh or current_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, spec_for(logical_axes, shape, mesh))


def model_axis_size() -> int:
    mesh = current_mesh()
    if mesh is None or "model" not in mesh.shape:
        return 1
    return mesh.shape["model"]


def data_axis_names():
    """Mesh axes that carry data parallelism (for gradient reductions)."""
    mesh = current_mesh()
    if mesh is None:
        return ()
    return tuple(a for a in ("pod", "data") if a in mesh.shape)
