"""Mixture-of-Experts layer with explicit expert-parallel dispatch.

This is where the paper's technique lands in the trainer: the EP dispatch is
an **AllToAll across the model axis**, and the paper (§2, §5) treats MoE
training traffic as exactly this collective.  Three implementations:

  * ``dense``    -- every expert on every token (tiny smoke configs; oracle);
  * ``a2a``      -- shard_map with ``jax.lax.all_to_all`` (XLA's native
                    collective; on the DCN this is what hash-based fabric LB
                    must carry in one shot);
  * ``rotation`` -- shard_map with the (n-1)-round **destination rotation**
                    decomposition via ``ppermute`` (the DR discipline of the
                    paper applied at the collective layer: every round is a
                    permutation, per-destination balanced).

Capacity-factor token dropping (standard production MoE) bounds buffer
shapes; dropped tokens pass through the residual stream.

Token layout inside shard_map: batch sharded over (pod, data), sequence
sharded over model (classic DeepSpeed-MoE EP+SP), experts sharded over model,
expert weights additionally FSDP-sharded over data and all-gathered on use
(ZeRO-3 style; the gather's transpose is a reduce-scatter in backward).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from . import layers as L
from . import sharding as sh


def param_shapes(cfg, n_moe_layers: int):
    d = L.dtype_of(cfg)
    D, E, F = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    sd = jax.ShapeDtypeStruct
    p = {
        "router": sd((n_moe_layers, D, E), jnp.float32),
        "w_gate": sd((n_moe_layers, E, D, F), d),
        "w_up": sd((n_moe_layers, E, D, F), d),
        "w_down": sd((n_moe_layers, E, F, D), d),
    }
    if cfg.n_shared_experts:
        Fs = F * cfg.n_shared_experts
        p.update({"ws_gate": sd((n_moe_layers, D, Fs), d),
                  "ws_up": sd((n_moe_layers, D, Fs), d),
                  "ws_down": sd((n_moe_layers, Fs, D), d)})
    return p


def _route(x2d, router, k):
    """x2d (T, D) -> (gates (T,k) fp32, experts (T,k) int32)."""
    logits = x2d.astype(jnp.float32) @ router
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates, idx.astype(jnp.int32)


def _seg_rank(sorted_keys):
    n = sorted_keys.shape[0]
    idx = jnp.arange(n, dtype=jnp.float32)
    flag = jnp.concatenate([jnp.ones((1,), bool),
                            sorted_keys[1:] != sorted_keys[:-1]])
    start = jax.lax.associative_scan(
        lambda a, b: (jnp.where(b[1], b[0], jnp.maximum(a[0], b[0])),
                      a[1] | b[1]),
        (jnp.where(flag, idx, -1.0), flag))[0]
    return (idx - start).astype(jnp.int32)


def _dispatch(x2d, gates, experts, E, C):
    """Scatter tokens into per-expert capacity buffers.

    Returns (buf (E, C, D), gate_buf (E, C), tok_buf (E, C) token index or -1).
    """
    T, k = experts.shape
    flat_e = experts.reshape(-1)
    flat_g = gates.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    order = jnp.argsort(flat_e, stable=True)
    se, sg, stk = flat_e[order], flat_g[order], flat_t[order]
    rank = _seg_rank(se)
    keep = rank < C
    row = jnp.where(keep, se, E)
    col = jnp.where(keep, rank, 0)
    D = x2d.shape[1]
    buf = jnp.zeros((E, C, D), x2d.dtype).at[row, col].set(
        x2d[stk], mode="drop")
    gate_buf = jnp.zeros((E, C), jnp.float32).at[row, col].set(
        sg, mode="drop")
    tok_buf = jnp.full((E, C), -1, jnp.int32).at[row, col].set(
        stk, mode="drop")
    return buf, gate_buf, tok_buf


def _expert_mlp(buf, wg, wu, wd):
    """buf (E, C, D); weights (E, D, F)/(E, F, D)."""
    g = jnp.einsum("ecd,edf->ecf", buf, wg)
    u = jnp.einsum("ecd,edf->ecf", buf, wu)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(buf.dtype) * u
    return jnp.einsum("ecf,efd->ecd", h, wd)


def _expert_mlp_zero3(buf, wg, wu, wd, fsdp_ax="data", unroll=False):
    """Scan over local experts, gathering ONE expert's FSDP-sharded weights
    at a time (live set = one expert's weights, ~90 MB for DeepSeek-V3,
    instead of all E_loc experts at once -- the difference between fitting
    and not fitting the 61-layer config in HBM).

    buf (E_loc, C, D); wg/wu (E_loc, D_shard, F); wd (E_loc, F, D_shard).
    """
    def body(_, xs):
        x_e, wg_e, wu_e, wd_e = xs
        wg_f = jax.lax.all_gather(wg_e, fsdp_ax, axis=0, tiled=True)
        wu_f = jax.lax.all_gather(wu_e, fsdp_ax, axis=0, tiled=True)
        wd_f = jax.lax.all_gather(wd_e, fsdp_ax, axis=1, tiled=True)
        g = x_e @ wg_f
        u = x_e @ wu_f
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x_e.dtype) * u
        return None, h @ wd_f
    _, ys = jax.lax.scan(body, None, (buf, wg, wu, wd), unroll=unroll)
    return ys


def _a2a(x, axis, *, split, concat, impl, axis_size):
    """AllToAll over a mesh axis: XLA native, or the paper's DR rotation.

    Rotation (destination-based rotation at the collective layer): n-1
    ``ppermute`` rounds; in round r every shard sends the chunk destined to
    peer (me+r) -- a pure permutation per round, so every link carries
    exactly one chunk (the Theta(1)-queue discipline of §6-7 mapped onto the
    collective schedule)."""
    if impl != "rotation" or axis_size == 1:
        return jax.lax.all_to_all(x, axis, split_axis=split,
                                  concat_axis=concat, tiled=True)
    n = axis_size
    me = jax.lax.axis_index(axis)
    chunks = jnp.stack(jnp.split(x, n, axis=split), axis=0)  # (n, ...)
    out_shape = list(chunks.shape[1:])
    out_shape[concat] *= n
    out = jnp.zeros(out_shape, x.dtype)
    csz = chunks.shape[1:][concat]

    def put(arr, block, pos):
        start = [0] * arr.ndim
        start[concat] = pos * csz
        return jax.lax.dynamic_update_slice(arr, block, tuple(start))

    # own chunk: tiled-a2a layout puts data received from peer j at slot j.
    out = put(out, jnp.take(chunks, me, axis=0), me)
    for r in range(1, n):
        send = jnp.take(chunks, (me + r) % n, axis=0)
        recv = jax.lax.ppermute(send, axis,
                                [(i, (i + r) % n) for i in range(n)])
        out = put(out, recv, (me - r) % n)
    return out


def moe_block(cfg, p, x, *, impl: Optional[str] = None):
    """x (B, S, D) -> (B, S, D).  Routed experts + optional shared expert."""
    impl = impl or cfg.moe_impl
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.experts_per_tok
    mesh = sh.current_mesh()
    ep = sh.model_axis_size() if mesh is not None else 1

    y_shared = 0.0
    if cfg.n_shared_experts:
        y_shared = L.swiglu(x, p["ws_gate"], p["ws_up"], p["ws_down"])

    seq_shard = (S % ep == 0) and S >= ep
    if impl == "dense" or mesh is None or ep == 1 or E % ep:
        # oracle: compute all experts for all tokens (tiny configs only)
        x2d = x.reshape(-1, D)
        gates, idx = _route(x2d, p["router"], k)
        g = jnp.einsum("td,edf->tef", x2d, p["w_gate"])
        u = jnp.einsum("td,edf->tef", x2d, p["w_up"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
        y_all = jnp.einsum("tef,efd->ted", h, p["w_down"])
        sel = jax.nn.one_hot(idx, E, dtype=jnp.float32)   # (T,k,E)
        w = jnp.einsum("tke,tk->te", sel, gates)
        y = jnp.einsum("te,ted->td", w, y_all).astype(x.dtype)
        return y.reshape(B, S, D) + y_shared

    # ---- expert-parallel shard_map path ------------------------------------
    batch_axes = sh.resolve("batch", B, mesh)
    batch_tuple = (batch_axes if isinstance(batch_axes, tuple)
                   else ((batch_axes,) if batch_axes else ()))
    x_spec = P(batch_axes, "model" if seq_shard else None, None)
    fsdp_ax = sh.resolve("fsdp", cfg.d_model, mesh) or "data"
    w_spec = P("model", fsdp_ax, None)                     # (E, D, F)
    wd_spec = P("model", None, fsdp_ax)                    # (E, F, D)
    r_spec = P(None, None)

    dp = sh._axes_size(mesh, batch_tuple) if batch_tuple else 1
    if seq_shard:
        T_loc = (B // dp) * (S // ep)
    else:
        # decode path: tokens replicated over 'model'; each shard takes a
        # slice of ceil(T/ep) tokens, results psum'd back (the EP decode
        # all-reduce)
        T_loc = -(-((B // dp) * S) // ep)
    C = max(8, -(-int(cfg.capacity_factor * T_loc * k) // E))

    def inner(x_loc, router, wg, wu, wd):
        Bl, Sl, _ = x_loc.shape
        x2d_full = x_loc.reshape(-1, D)
        Tfull = x2d_full.shape[0]
        if seq_shard:
            x2d = x2d_full
        else:
            me = jax.lax.axis_index("model")
            c = T_loc
            pad = c * ep - Tfull
            xp = jnp.pad(x2d_full, ((0, pad), (0, 0)))
            x2d = jax.lax.dynamic_slice_in_dim(xp, me * c, c, axis=0)
        T = x2d.shape[0]
        gates, idx = _route(x2d, router, k)
        buf, gate_buf, tok_buf = _dispatch(x2d, gates, idx, E, C)
        # a2a: (E, C, D) -> (E/ep, C*ep, D) on each shard
        buf = _a2a(buf, "model", split=0, concat=1, impl=impl, axis_size=ep)
        # per-expert ZeRO-3 weight gathering (memory-bounded)
        y = _expert_mlp_zero3(buf, wg, wu, wd, fsdp_ax,
                              unroll=cfg.scan_unroll)
        y = _a2a(y, "model", split=1, concat=0, impl=impl, axis_size=ep)
        # combine: scatter-add gated outputs back to token positions
        flat_y = (y * gate_buf[..., None]).astype(x2d.dtype).reshape(E * C, D)
        flat_tok = tok_buf.reshape(E * C)
        out = jnp.zeros_like(x2d).at[
            jnp.where(flat_tok >= 0, flat_tok, T)].add(flat_y, mode="drop")
        if not seq_shard:
            me = jax.lax.axis_index("model")
            c = T_loc
            pad = c * ep - Tfull
            full = jnp.zeros((c * ep, D), x2d.dtype)
            full = jax.lax.dynamic_update_slice_in_dim(full, out, me * c, 0)
            full = jax.lax.psum(full, "model")
            out = full[:Tfull]
        return out.reshape(Bl, Sl, D)

    y = shard_map(
        inner,
        mesh=mesh,
        in_specs=(x_spec, r_spec, w_spec, w_spec, wd_spec),
        out_specs=x_spec,
        check_rep=False,
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    return y + y_shared
