"""Whisper-style encoder-decoder backbone.

The conv/audio frontend is a STUB per the assignment: ``input_specs`` feeds
precomputed frame embeddings (B, n_frames, D).  The encoder is a
bidirectional transformer over frames with a learned positional table; the
decoder is a causal transformer with cross-attention whose K/V are
precomputed once from the encoder output (and cached for decode).

Deviation note: the original uses learned absolute positions in the decoder
(448 max); our assigned shapes stress 32k-token decoding, so the decoder
self-attention uses RoPE instead (recorded in DESIGN.md).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import layers as L
from . import sharding as sh
from ..kernels.flash_attn import ops as attn_ops


def param_shapes(cfg):
    d = L.dtype_of(cfg)
    sd = jax.ShapeDtypeStruct
    D, H, Hkv, hd, F = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                        cfg.head_dim, cfg.d_ff)
    ne, nd = cfg.n_encoder_layers, cfg.n_layers

    enc_layer = {
        "ln1": sd((ne, D), d), "ln2": sd((ne, D), d),
        "wq": sd((ne, D, H * hd), d), "wk": sd((ne, D, Hkv * hd), d),
        "wv": sd((ne, D, Hkv * hd), d), "wo": sd((ne, H * hd, D), d),
        "w_gate": sd((ne, D, F), d), "w_up": sd((ne, D, F), d),
        "w_down": sd((ne, F, D), d),
    }
    dec_layer = {
        "ln1": sd((nd, D), d), "ln2": sd((nd, D), d), "ln3": sd((nd, D), d),
        "wq": sd((nd, D, H * hd), d), "wk": sd((nd, D, Hkv * hd), d),
        "wv": sd((nd, D, Hkv * hd), d), "wo": sd((nd, H * hd, D), d),
        "xq": sd((nd, D, H * hd), d), "xk": sd((nd, D, Hkv * hd), d),
        "xv": sd((nd, D, Hkv * hd), d), "xo": sd((nd, H * hd, D), d),
        "w_gate": sd((nd, D, F), d), "w_up": sd((nd, D, F), d),
        "w_down": sd((nd, F, D), d),
    }
    return {
        "embed": sd((cfg.vocab, D), d),
        "enc_pos": sd((cfg.n_frontend_tokens, D), d),
        "enc_in": sd((cfg.frontend_dim or D, D), d),
        "enc_norm": sd((D,), d),
        "final_norm": sd((D,), d),
        "lm_head": sd((D, cfg.vocab), d),
        "encoder": enc_layer,
        "decoder": dec_layer,
    }


def logical_axes(cfg):
    shapes = param_shapes(cfg)

    def ax(name, spec):
        table = {
            "embed": ("vocab", "fsdp"), "lm_head": ("fsdp", "vocab"),
            "enc_pos": (None, "fsdp"), "enc_in": (None, "fsdp"),
        }
        if name in table:
            return table[name]
        if len(spec.shape) == 3:
            if name in ("wo", "xo", "w_down"):
                return (None, "model", "fsdp")
            return (None, "fsdp", "model")
        return (None,) * len(spec.shape)

    out = {}
    for k, v in shapes.items():
        if isinstance(v, dict):
            out[k] = {kk: ax(kk, vv) for kk, vv in v.items()}
        else:
            out[k] = ax(k, v)
    return out


def init_params(cfg, key):
    shapes = param_shapes(cfg)
    leaves, treedef = jax.tree_util.tree_flatten(shapes)
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, spec in zip(keys, leaves):
        if len(spec.shape) >= 2:
            w = (jax.random.normal(k, spec.shape, jnp.float32)
                 * spec.shape[-2] ** -0.5)
        else:
            w = jnp.ones(spec.shape, jnp.float32)
        out.append(w.astype(spec.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def encode(cfg, params, frames):
    """frames (B, T, frontend_dim) -> (B, T, D)."""
    x = frames.astype(L.dtype_of(cfg)) @ params["enc_in"]
    x = x + params["enc_pos"][None, :x.shape[1]]
    x = sh.constrain(x, "batch", None, None)
    positions = jnp.arange(x.shape[1])[None, :]

    def body(carry, lp):
        h = L.rms_norm(carry, lp["ln1"], cfg.norm_eps)
        B, S, _ = h.shape
        H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        q = (h @ lp["wq"]).reshape(B, S, H, hd)
        k = (h @ lp["wk"]).reshape(B, S, Hkv, hd)
        v = (h @ lp["wv"]).reshape(B, S, Hkv, hd)
        attn = attn_ops.attention(
            jnp.moveaxis(q, 1, 2), jnp.moveaxis(k, 1, 2),
            jnp.moveaxis(v, 1, 2), causal=False, backend="xla")
        y = carry + jnp.moveaxis(attn, 1, 2).reshape(B, S, H * hd) @ lp["wo"]
        h2 = L.rms_norm(y, lp["ln2"], cfg.norm_eps)
        y = y + L.swiglu(h2, lp["w_gate"], lp["w_up"], lp["w_down"])
        return y, None

    x, _ = jax.lax.scan(body, x, params["encoder"], unroll=cfg.scan_unroll)
    return sh.constrain(L.rms_norm(x, params["enc_norm"], cfg.norm_eps),
                        "batch", None, None)


def _cross_kv(cfg, params, enc_out):
    """Precompute decoder cross-attention K/V per layer: (nd, B, T, Hkv, hd)."""
    B, T, D = enc_out.shape
    Hkv, hd = cfg.n_kv_heads, cfg.head_dim

    def body(_, lp):
        k = (enc_out @ lp["xk"]).reshape(B, T, Hkv, hd)
        v = (enc_out @ lp["xv"]).reshape(B, T, Hkv, hd)
        return None, (k, v)
    _, (ks, vs) = jax.lax.scan(body, None, params["decoder"], unroll=cfg.scan_unroll)
    ks = sh.constrain(ks, None, "batch", None, None, None)
    vs = sh.constrain(vs, None, "batch", None, None, None)
    return ks, vs


def _dec_layer(cfg, lp, x, positions, self_cache, cross_kv, cache_index,
               mode):
    h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
    attn, nc = L.gqa_attention(h, lp, cfg, positions, self_cache,
                               cache_index, mode)
    x = x + attn
    h = L.rms_norm(x, lp["ln3"], cfg.norm_eps)
    x = x + L.cross_attention(
        h, cross_kv, {"wq": lp["xq"], "wo": lp["xo"]}, cfg)
    h = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
    x = x + L.swiglu(h, lp["w_gate"], lp["w_up"], lp["w_down"])
    return x, nc


def forward(cfg, params, tokens, *, frames=None, enc_out=None, mode="train",
            cache=None, cache_index: int = 0, remat: Optional[bool] = None):
    """Decoder forward.  Provide ``frames`` (train/prefill; encoder runs) or
    a cache carrying precomputed cross K/V (decode)."""
    remat = cfg.remat if remat is None else remat
    caches = cache or {}
    if enc_out is None and frames is not None:
        enc_out = encode(cfg, params, frames)
    if enc_out is not None:
        xk, xv = _cross_kv(cfg, params, enc_out)
    else:
        xk, xv = caches["cross_k"], caches["cross_v"]

    x = L.embed(tokens, params["embed"])
    x = sh.constrain(x, "batch", None, None)
    positions = cache_index + jnp.arange(x.shape[1])[None, :]

    def body(lp, xx, pos, sc, kv, ci):
        return _dec_layer(cfg, lp, xx, pos, sc, kv, ci, mode)
    if remat and mode == "train":
        body = jax.checkpoint(body, policy=L.remat_policy_of(cfg))

    self_cache = caches.get("self")
    if self_cache is None:
        def scan_fn(carry, inp):
            lp, k, v = inp
            y, _ = body(lp, carry, positions, None, (k, v), 0)
            return y, None
        x, _ = jax.lax.scan(scan_fn, x, (params["decoder"], xk, xv), unroll=cfg.scan_unroll)
        new_self = None
    else:
        def scan_fn(carry, inp):
            lp, k, v, sc = inp
            y, nc = body(lp, carry, positions, sc, (k, v), cache_index)
            return y, nc
        x, new_self = jax.lax.scan(scan_fn, x,
                                   (params["decoder"], xk, xv, self_cache),
                                   unroll=cfg.scan_unroll)

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(x, params["lm_head"])
    logits = sh.constrain(logits, "batch", None, "vocab")
    if cache is not None:
        return logits, {"self": new_self, "cross_k": xk, "cross_v": xv}
    return logits


def cache_shapes(cfg, batch: int, max_len: int):
    d = L.dtype_of(cfg)
    sd = jax.ShapeDtypeStruct
    nd = cfg.n_layers
    T = cfg.n_frontend_tokens
    Hkv, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "self": {"k": sd((nd, batch, max_len, Hkv, hd), d),
                 "v": sd((nd, batch, max_len, Hkv, hd), d)},
        "cross_k": sd((nd, batch, T, Hkv, hd), d),
        "cross_v": sd((nd, batch, T, Hkv, hd), d),
    }


def cache_logical_axes(cfg):
    return {
        "self": {"k": (None, "batch", "seq_cache", "kv_heads", None),
                 "v": (None, "batch", "seq_cache", "kv_heads", None)},
        "cross_k": (None, "batch", None, None, None),
        "cross_v": (None, "batch", None, None, None),
    }
