"""Multi-head Latent Attention (DeepSeek-V3).

Training path materializes per-head K/V from the compressed latent; the
decode path caches only (c_kv, k_rope) = (kv_lora_rank + rope_head_dim) per
token -- the memory win that makes 32k-context batch-128 decode feasible --
and uses the absorbed-weights formulation so no per-head K/V is ever
materialized at decode time.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers as L
from . import sharding as sh
from ..kernels.flash_attn import ops as attn_ops


def param_shapes(cfg):
    d = L.dtype_of(cfg)
    sd = jax.ShapeDtypeStruct
    D, H = cfg.d_model, cfg.n_heads
    qr, kr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    nl = cfg.n_layers
    return {
        "wq_a": sd((nl, D, qr), d),            # q down-projection
        "q_norm": sd((nl, qr), d),
        "wq_b": sd((nl, qr, H * (dn + dr)), d),
        "wkv_a": sd((nl, D, kr + dr), d),      # kv down-projection (+k_rope)
        "kv_norm": sd((nl, kr), d),
        "wk_b": sd((nl, kr, H * dn), d),
        "wv_b": sd((nl, kr, H * dv), d),
        "wo": sd((nl, H * dv, D), d),
    }


def _project_q(x, p, cfg, positions):
    B, S, _ = x.shape
    H, dn, dr = cfg.n_heads, cfg.nope_head_dim, cfg.rope_head_dim
    q = L.rms_norm(x @ p["wq_a"], p["q_norm"], cfg.norm_eps) @ p["wq_b"]
    q = q.reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = L.apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_attention(x, p, cfg, positions, cache=None, cache_index=0,
                  mode: str = "train"):
    """MLA attention.  Returns (out, new_cache).

    ``train``: no cache, chunked causal flash attention.
    ``prefill``: same attention math, but also writes the *compressed*
      (c_kv, k_rope) cache at [cache_index, cache_index+S).
    ``decode``: absorbed-weights attention over the compressed cache.
    """
    B, S, D = x.shape
    H = cfg.n_heads
    dn, dr, dv = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    kr = cfg.kv_lora_rank

    q_nope, q_rope = _project_q(x, p, cfg, positions)
    kv = x @ p["wkv_a"]                                # (B,S,kr+dr)
    c_kv = L.rms_norm(kv[..., :kr], p["kv_norm"], cfg.norm_eps)
    k_rope = L.apply_rope(kv[..., kr:], positions, cfg.rope_theta)  # shared

    new_cache = None
    if cache is not None:
        cc = jax.lax.dynamic_update_slice_in_dim(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), cache_index, 1)
        cr = jax.lax.dynamic_update_slice_in_dim(
            cache["k_rope"], k_rope.astype(cache["k_rope"].dtype),
            cache_index, 1)
        new_cache = {"c_kv": cc, "k_rope": cr}

    if mode == "decode":
        assert new_cache is not None
        out = _absorbed_attention(q_nope, q_rope, new_cache, p, cfg,
                                  cache_index + S)
        return out @ p["wo"], new_cache

    # train / prefill: materialized per-head K/V, chunked causal attention.
    # Heads shard over 'model' (128 heads / 16 = 8) -- without this the
    # per-head K/V blow past HBM on the 61-layer config.
    k_nope = (c_kv @ p["wk_b"]).reshape(B, S, H, dn)
    v = (c_kv @ p["wv_b"]).reshape(B, S, H, dv)
    k = jnp.concatenate([k_nope,
                         jnp.broadcast_to(k_rope[:, :, None, :],
                                          (B, S, H, dr))], axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    q = sh.constrain(q, "batch", None, "model", None)
    k = sh.constrain(k, "batch", None, "model", None)
    v = sh.constrain(v, "batch", None, "model", None)
    out = attn_ops.attention(
        jnp.moveaxis(q, 1, 2), jnp.moveaxis(k, 1, 2), jnp.moveaxis(v, 1, 2),
        causal=True, scale=(dn + dr) ** -0.5, backend="xla")
    out = jnp.moveaxis(out, 1, 2).reshape(B, S, H * dv).astype(x.dtype)
    return out @ p["wo"], new_cache


def _absorbed_attention(q_nope, q_rope, cache, p, cfg, valid_len):
    """Decode with the compressed cache only.

    scores = q_nope^T (W_kb c) + q_rope^T k_rope
           = (W_kb^T q_nope)^T c + q_rope^T k_rope     (absorb W_kb into q)
    out_h  = (probs . c) W_vb_h                        (absorb W_vb after).
    """
    B, S, H, dn = q_nope.shape
    kr = cfg.kv_lora_rank
    dr = cfg.rope_head_dim
    dv = cfg.v_head_dim
    Tmax = cache["c_kv"].shape[1]
    scale = (dn + dr) ** -0.5

    wk = p["wk_b"].reshape(kr, H, dn)
    q_abs = jnp.einsum("bshd,khd->bshk", q_nope, wk,
                       preferred_element_type=jnp.float32)   # (B,S,H,kr)
    # contract against the bf16 cache with f32 accumulation (no cache cast)
    logits = (jnp.einsum("bshk,btk->bhst", q_abs.astype(cache["c_kv"].dtype),
                         cache["c_kv"],
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bshd,btd->bhst", q_rope, cache["k_rope"],
                           preferred_element_type=jnp.float32)) * scale
    qpos = valid_len - S + jnp.arange(S)
    mask = jnp.arange(Tmax)[None, :] <= qpos[:, None]
    logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    ctx = jnp.einsum("bhst,btk->bshk", probs.astype(cache["c_kv"].dtype),
                     cache["c_kv"],
                     preferred_element_type=jnp.float32)     # (B,S,H,kr)
    wv = p["wv_b"].reshape(kr, H, dv)
    out = jnp.einsum("bshk,khd->bshd", ctx.astype(wv.dtype), wv,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, S, H * dv).astype(p["wo"].dtype)


def cache_shapes(cfg, batch: int, max_len: int):
    d = L.dtype_of(cfg)
    sd = jax.ShapeDtypeStruct
    return {
        "c_kv": sd((cfg.n_layers, batch, max_len, cfg.kv_lora_rank), d),
        "k_rope": sd((cfg.n_layers, batch, max_len, cfg.rope_head_dim), d),
    }
