"""Uniform model API over the zoo families.

``Model`` wraps a config with family-dispatched functions:

  param_shapes / init_params / logical_axes
  loss(params, batch)                        -> scalar LM loss
  prefill(params, batch, cache)              -> (logits, cache)
  decode_step(params, tokens, cache, index)  -> (logits, cache)
  cache_shapes(batch, max_len) / cache_logical_axes
  input_specs(shape_cfg)                     -> batch ShapeDtypeStructs

Batch layout: {"tokens": (B, S) int32} plus, per family, "frames"
(audio stub) or "vision_embeds" (VLM stub).  LM loss is next-token
cross-entropy over tokens (frontend positions excluded).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from . import transformer, mamba2, hybrid, encdec
from ..configs.base import ModelConfig, ShapeConfig


def _xent(logits, labels, mask):
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # ---- family dispatch ---------------------------------------------------
    @property
    def _mod(self):
        fam = self.cfg.family
        if fam in ("dense", "moe", "vlm"):
            return transformer
        if fam == "ssm":
            return mamba2
        if fam == "hybrid":
            return hybrid
        if fam == "encdec":
            return encdec
        raise ValueError(fam)

    def param_shapes(self):
        return self._mod.param_shapes(self.cfg)

    def init_params(self, key):
        return self._mod.init_params(self.cfg, key)

    def logical_axes(self):
        return self._mod.logical_axes(self.cfg)

    def cache_shapes(self, batch: int, max_len: int):
        return self._mod.cache_shapes(self.cfg, batch, max_len)

    def cache_logical_axes(self):
        return self._mod.cache_logical_axes(self.cfg)

    # ---- forward paths -----------------------------------------------------
    def _fwd(self, params, batch, **kw):
        cfg = self.cfg
        if cfg.family == "encdec":
            return encdec.forward(cfg, params, batch["tokens"],
                                  frames=batch.get("frames"), **kw)
        if cfg.family == "vlm":
            return transformer.forward(
                cfg, params, batch["tokens"],
                vision_embeds=batch.get("vision_embeds"), **kw)
        return self._mod.forward(cfg, params, batch["tokens"], **kw)

    def loss(self, params, batch):
        logits = self._fwd(params, batch, mode="train")
        tokens = batch["tokens"]
        B, S = tokens.shape
        # frontend positions (vision/audio) are excluded from the loss: the
        # logits tail [-S:] aligns with the token stream.
        logits = logits[:, -S:]
        labels = tokens[:, 1:]
        mask = jnp.ones_like(labels, jnp.float32)
        return _xent(logits[:, :-1], labels, mask)

    def prefill(self, params, batch, cache):
        return self._fwd(params, batch, mode="prefill", cache=cache,
                         cache_index=0)

    def decode_step(self, params, tokens, cache, index):
        return self._fwd(params, {"tokens": tokens}, mode="decode",
                         cache=cache, cache_index=index)

    # ---- dry-run input specs -------------------------------------------------
    def input_specs(self, shape: ShapeConfig) -> Dict[str, Any]:
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        sd = jax.ShapeDtypeStruct
        if shape.kind == "train":
            batch = {"tokens": sd((B, S), i32)}
            if cfg.family == "vlm":
                n_img = min(cfg.n_frontend_tokens, S // 2)
                batch = {"tokens": sd((B, S - n_img), i32),
                         "vision_embeds": sd((B, n_img,
                                              cfg.frontend_dim or cfg.d_model),
                                             jnp.bfloat16
                                             if cfg.dtype == "bfloat16"
                                             else jnp.float32)}
            if cfg.family == "encdec":
                batch["frames"] = sd((B, cfg.n_frontend_tokens,
                                      cfg.frontend_dim or cfg.d_model),
                                     jnp.bfloat16 if cfg.dtype == "bfloat16"
                                     else jnp.float32)
            return batch
        if shape.kind == "prefill":
            batch = {"tokens": sd((B, S), i32)}
            if cfg.family == "vlm":
                n_img = min(cfg.n_frontend_tokens, S // 2)
                batch = {"tokens": sd((B, S - n_img), i32),
                         "vision_embeds": sd((B, n_img,
                                              cfg.frontend_dim or cfg.d_model),
                                             jnp.bfloat16
                                             if cfg.dtype == "bfloat16"
                                             else jnp.float32)}
            if cfg.family == "encdec":
                batch["frames"] = sd((B, cfg.n_frontend_tokens,
                                      cfg.frontend_dim or cfg.d_model),
                                     jnp.bfloat16 if cfg.dtype == "bfloat16"
                                     else jnp.float32)
            return batch
        # decode: one new token against an S-long cache
        return {"tokens": sd((B, 1), i32)}


# ---------------------------------------------------------------------------
# Registry (backed by repro.configs.base)
# ---------------------------------------------------------------------------
from ..configs import base as _cfg_base

get_config = _cfg_base.get_config
list_architectures = _cfg_base.list_architectures


def get_model(name: str, smoke: bool = False) -> Model:
    return Model(get_config(name, smoke))
