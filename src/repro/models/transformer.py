"""Decoder-only transformer covering the dense and MoE LM families.

One module, composed per-config:
  * attention: GQA (+RoPE, optional qkv bias) or MLA (DeepSeek compressed
    latent) -- ``cfg.mla``;
  * MLP: dense SwiGLU, or MoE (expert-parallel AllToAll / DR-rotation) with
    ``cfg.n_dense_layers`` leading dense layers (DeepSeek-V3 layout);
  * optional stubbed modality frontend (``cfg.family == 'vlm'``): precomputed
    patch/frame embeddings projected and prepended to the token stream.

Params are layer-stacked per section ("dense" / "moe") and consumed via
``lax.scan`` -- the HLO stays small even for 61-layer x 256-expert models,
which is what keeps 512-device compiles tractable.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import layers as L
from . import mla as mla_mod
from . import moe as moe_mod
from . import sharding as sh


# ---------------------------------------------------------------------------
# Param shapes
# ---------------------------------------------------------------------------

def _attn_shapes(cfg, nl):
    d = L.dtype_of(cfg)
    sd = jax.ShapeDtypeStruct
    if cfg.mla:
        shp = mla_mod.param_shapes(cfg)
        return {k: sd((nl,) + v.shape[1:], v.dtype) for k, v in shp.items()}
    D, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    out = {
        "wq": sd((nl, D, H * hd), d), "wk": sd((nl, D, Hkv * hd), d),
        "wv": sd((nl, D, Hkv * hd), d), "wo": sd((nl, H * hd, D), d),
    }
    if cfg.qkv_bias:
        out.update({"bq": sd((nl, H * hd), d), "bk": sd((nl, Hkv * hd), d),
                    "bv": sd((nl, Hkv * hd), d)})
    return out


def _dense_mlp_shapes(cfg, nl):
    d = L.dtype_of(cfg)
    sd = jax.ShapeDtypeStruct
    D, F = cfg.d_model, cfg.d_ff
    return {"w_gate": sd((nl, D, F), d), "w_up": sd((nl, D, F), d),
            "w_down": sd((nl, F, D), d)}


def _norm_shapes(cfg, nl):
    d = L.dtype_of(cfg)
    sd = jax.ShapeDtypeStruct
    return {"ln1": sd((nl, cfg.d_model), d), "ln2": sd((nl, cfg.d_model), d)}


def param_shapes(cfg):
    d = L.dtype_of(cfg)
    sd = jax.ShapeDtypeStruct
    n_moe = (cfg.n_layers - cfg.n_dense_layers) if cfg.n_experts else 0
    n_dense = cfg.n_layers - n_moe
    p = {"embed": sd((cfg.vocab, cfg.d_model), d),
         "final_norm": sd((cfg.d_model,), d)}
    if not cfg.tie_embeddings:
        p["lm_head"] = sd((cfg.d_model, cfg.vocab), d)
    if cfg.family == "vlm":
        p["vision_proj"] = sd((cfg.frontend_dim or cfg.d_model,
                               cfg.d_model), d)
    if n_dense:
        p["dense"] = {**_norm_shapes(cfg, n_dense),
                      **_attn_shapes(cfg, n_dense),
                      **_dense_mlp_shapes(cfg, n_dense)}
    if n_moe:
        p["moe"] = {**_norm_shapes(cfg, n_moe),
                    **_attn_shapes(cfg, n_moe),
                    **{k: v for k, v in moe_mod.param_shapes(
                        cfg, n_moe).items()}}
    return p


# Logical sharding axes per param leaf name (fsdp over embed/ff dims, tensor
# parallel over head/expert dims).
_LOGICAL = {
    "embed": ("vocab", "fsdp"),
    "lm_head": ("fsdp", "vocab"),
    "final_norm": (None,),
    "vision_proj": (None, "fsdp"),
    "ln1": (None, None), "ln2": (None, None),
    "wq": (None, "fsdp", "model"), "wk": (None, "fsdp", "model"),
    "wv": (None, "fsdp", "model"), "wo": (None, "model", "fsdp"),
    "bq": (None, "model"), "bk": (None, "model"), "bv": (None, "model"),
    "w_gate": (None, "fsdp", "model"), "w_up": (None, "fsdp", "model"),
    "w_down": (None, "model", "fsdp"),
    # MLA
    "wq_a": (None, "fsdp", None), "q_norm": (None, None),
    "wq_b": (None, None, "model"),
    "wkv_a": (None, "fsdp", None), "kv_norm": (None, None),
    "wk_b": (None, None, "model"), "wv_b": (None, None, "model"),
    # MoE
    "router": (None, "fsdp", None),
    "ws_gate": (None, "fsdp", "model"), "ws_up": (None, "fsdp", "model"),
    "ws_down": (None, "model", "fsdp"),
}
_MOE_EXPERT = {"w_gate": ("experts", "fsdp", None),
               "w_up": ("experts", "fsdp", None),
               "w_down": ("experts", None, "fsdp")}


def logical_axes(cfg):
    """Pytree (same structure as param_shapes) of logical axis tuples."""
    shapes = param_shapes(cfg)

    def annotate(tree, moe_section):
        out = {}
        for k, v in tree.items():
            if isinstance(v, dict):
                out[k] = annotate(v, k == "moe")
                continue
            if moe_section and k in _MOE_EXPERT:
                ax = _MOE_EXPERT[k]
            else:
                ax = _LOGICAL.get(k, (None,) * len(v.shape))
            # layer-stacked leaves get a leading None
            if len(ax) == len(v.shape) - 1:
                ax = (None,) + ax
            ax = tuple(ax[:len(v.shape)])
            ax = ax + (None,) * (len(v.shape) - len(ax))
            out[k] = ax
        return out

    return annotate(shapes, False)


def init_params(cfg, key):
    shapes = param_shapes(cfg)
    leaves, treedef = jax.tree_util.tree_flatten(shapes)
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, spec in zip(keys, leaves):
        if len(spec.shape) >= 2:
            fan_in = spec.shape[-2]
            w = jax.random.normal(k, spec.shape, jnp.float32) * fan_in ** -0.5
        else:
            w = jnp.ones(spec.shape, jnp.float32)
        out.append(w.astype(spec.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _attn(cfg, p, h, positions, lc, cache_index, mode):
    if cfg.mla:
        return mla_mod.mla_attention(h, p, cfg, positions, lc, cache_index,
                                     mode)
    return L.gqa_attention(h, p, cfg, positions, lc, cache_index, mode)


def _layer(cfg, use_moe, p, x, positions, lc, cache_index, mode):
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    attn_out, new_cache = _attn(cfg, p, h, positions, lc, cache_index, mode)
    x = x + attn_out
    h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    if use_moe:
        x = x + moe_mod.moe_block(cfg, p, h)
    else:
        x = x + L.swiglu(h, p["w_gate"], p["w_up"], p["w_down"])
    return x, new_cache


def _run_section(cfg, use_moe, params, x, positions, cache, cache_index,
                 mode, remat):
    def body(lp, xx, pos, lc, ci):
        return _layer(cfg, use_moe, lp, xx, pos, lc, ci, mode)
    if remat and mode == "train":
        body = jax.checkpoint(body, policy=L.remat_policy_of(cfg))
    if cache is None:
        def scan_fn(carry, lp):
            y, _ = body(lp, carry, positions, None, 0)
            return y, None
        x, _ = jax.lax.scan(scan_fn, x, params, unroll=cfg.scan_unroll)
        return x, None

    if cfg.scan_unroll:
        def scan_fn(carry, inp):
            lp, lc = inp
            y, nc = body(lp, carry, positions, lc, cache_index)
            return y, nc
        x, new_cache = jax.lax.scan(scan_fn, x, (params, cache), unroll=True)
        return x, new_cache

    # Cached path: fori_loop with in-place cache updates.  A scan over
    # (params, cache) cannot alias its xs into its stacked ys, doubling KV
    # memory; a loop carry aliases in place (the 32k-context decode cells
    # only fit this way).
    nl = jax.tree_util.tree_leaves(params)[0].shape[0]

    def body_l(l, carry):
        xx, full_cache = carry
        lp = jax.tree_util.tree_map(
            lambda a: jax.lax.dynamic_index_in_dim(a, l, 0, keepdims=False),
            params)
        lc = jax.tree_util.tree_map(
            lambda a: jax.lax.dynamic_index_in_dim(a, l, 0, keepdims=False),
            full_cache)
        y, nc = body(lp, xx, positions, lc, cache_index)
        full_cache = jax.tree_util.tree_map(
            lambda full, new_: jax.lax.dynamic_update_index_in_dim(
                full, new_.astype(full.dtype), l, 0), full_cache, nc)
        return y, full_cache

    x, new_cache = jax.lax.fori_loop(0, nl, body_l, (x, cache))
    return x, new_cache


def forward(cfg, params, tokens, *, mode: str = "train", cache=None,
            cache_index: int = 0, vision_embeds=None,
            remat: Optional[bool] = None):
    """tokens (B, S) -> logits (or (logits, new_cache) when cache given)."""
    remat = cfg.remat if remat is None else remat
    x = L.embed(tokens, params["embed"])
    if vision_embeds is not None:
        v = vision_embeds.astype(x.dtype) @ params["vision_proj"]
        x = jnp.concatenate([v, x], axis=1)
    x = sh.constrain(x, "batch", None, None)
    B, S, _ = x.shape
    positions = cache_index + jnp.arange(S)[None, :]

    has_moe = "moe" in params
    caches = cache or {}
    new_caches = {}
    if "dense" in params:
        x, nc = _run_section(cfg, False, params["dense"], x, positions,
                             caches.get("dense"), cache_index, mode, remat)
        if nc is not None:
            new_caches["dense"] = nc
    if has_moe:
        x, nc = _run_section(cfg, True, params["moe"], x, positions,
                             caches.get("moe"), cache_index, mode, remat)
        if nc is not None:
            new_caches["moe"] = nc

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params.get("lm_head")
    logits = L.unembed(x, head if head is not None else params["embed"].T)
    logits = sh.constrain(logits, "batch", None, "vocab")
    return (logits, new_caches) if cache is not None else logits


def cache_shapes(cfg, batch: int, max_len: int):
    d = L.dtype_of(cfg)
    sd = jax.ShapeDtypeStruct
    n_moe = (cfg.n_layers - cfg.n_dense_layers) if cfg.n_experts else 0
    n_dense = cfg.n_layers - n_moe

    def sec(nl):
        if cfg.mla:
            base = mla_mod.cache_shapes(cfg, batch, max_len)
            return {k: sd((nl,) + v.shape[1:], v.dtype)
                    for k, v in base.items()}
        return {"k": sd((nl, batch, max_len, cfg.n_kv_heads, cfg.head_dim), d),
                "v": sd((nl, batch, max_len, cfg.n_kv_heads, cfg.head_dim), d)}

    out = {}
    if n_dense:
        out["dense"] = sec(n_dense)
    if n_moe:
        out["moe"] = sec(n_moe)
    return out


def cache_logical_axes(cfg):
    """Logical axes for cache leaves: batch over data, seq over model."""
    if cfg.mla:
        per = {"c_kv": (None, "batch", "seq_cache", None),
               "k_rope": (None, "batch", "seq_cache", None)}
    else:
        # heads shard when divisible (priority), else sequence
        per = {"k": (None, "batch", "seq_cache", "kv_heads", None),
               "v": (None, "batch", "seq_cache", "kv_heads", None)}
    n_moe = (cfg.n_layers - cfg.n_dense_layers) if cfg.n_experts else 0
    out = {}
    if cfg.n_layers - n_moe:
        out["dense"] = dict(per)
    if n_moe:
        out["moe"] = dict(per)
    return out
