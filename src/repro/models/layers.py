"""Shared neural building blocks (pure functions over param pytrees).

Conventions:
  * params are dicts of jnp arrays; layer-stacked params carry a leading
    ``L`` axis and are consumed via ``lax.scan`` (small HLO, fast compiles
    even for 61-layer models on 512 devices);
  * math is float32 inside norms/softmax, params/activations in cfg.dtype;
  * attention goes through ``repro.kernels.flash_attn.ops.attention``
    (Pallas on TPU, jnp reference on CPU).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from . import sharding as sh
from ..kernels.flash_attn import ops as attn_ops


def dtype_of(cfg):
    return jnp.dtype(cfg.dtype)


def remat_policy_of(cfg):
    import jax
    if getattr(cfg, "remat_policy", "nothing") == "dots":
        return jax.checkpoint_policies.checkpoint_dots
    return jax.checkpoint_policies.nothing_saveable



# ---------------------------------------------------------------------------
# Norms / activations / embeddings
# ---------------------------------------------------------------------------

def rms_norm(x, w, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def swiglu(x, w_gate, w_up, w_down):
    g = x @ w_gate
    u = x @ w_up
    return (jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u) @ w_down


def embed(tokens, table):
    return table[tokens]


def unembed(x, table):
    """Logits in float32 (loss stability)."""
    return (x.astype(jnp.float32) @ table.astype(jnp.float32))


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float = 1e4):
    """x: (..., S, H, D) or (..., S, D); positions (..., S)."""
    D = x.shape[-1]
    freqs = rope_freqs(D, theta)                      # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    if x.ndim == ang.ndim + 1:                         # has head axis
        ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention (training / prefill / cached decode)
# ---------------------------------------------------------------------------

def gqa_attention(x, p, cfg, positions, cache=None, cache_index=0,
                  mode: str = "train", backend: str = "auto"):
    """Multi-head GQA attention with RoPE.

    x (B, S, D).  ``cache``: optional dict {"k": (B, S_max, Hkv, hd),
    "v": ...}.  ``mode``:
      train   -- no cache; causal flash attention;
      prefill -- causal flash attention over the S new tokens, cache written
                 at [cache_index, cache_index+S);
      decode  -- cache written, attention over the whole (padded) cache.
    Returns (out, new_cache).
    """
    B, S, _ = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, Hkv, hd)
    v = v.reshape(B, S, Hkv, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    # shard attention over 'model': by heads when divisible, else by query
    # sequence (prefill/train) -- replicated attention on a non-dividing
    # head count costs ~50 GB/device of activation gathers at 32k prefill
    if (sh.resolve("model", H) is None and S > 1
            and sh.resolve("seq_model", S) is not None):
        q = sh.constrain(q, "batch", "seq_model", None, None)
    else:
        q = sh.constrain(q, "batch", None, "model", None)
    k = sh.constrain(k, "batch", None, "kv_heads", None)
    v = sh.constrain(v, "batch", None, "kv_heads", None)

    new_cache = None
    if cache is not None:
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(
            cache["k"].dtype), cache_index, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(
            cache["v"].dtype), cache_index, axis=1)
        new_cache = {"k": ck, "v": cv}

    if mode == "decode":
        assert new_cache is not None
        kv_len = cache["k"].shape[1]
        out = _cached_attention(q, new_cache["k"], new_cache["v"],
                                cache_index + S, kv_len)
        return out.reshape(B, S, H * hd) @ p["wo"], new_cache

    out = attn_ops.attention(
        jnp.moveaxis(q, 1, 2), jnp.moveaxis(k, 1, 2), jnp.moveaxis(v, 1, 2),
        causal=True, backend=backend)
    out = jnp.moveaxis(out, 1, 2).reshape(B, S, H * hd)
    return out @ p["wo"], new_cache


def _cached_attention(q, k, v, valid_len, kv_len):
    """Decode/prefill attention over a (possibly padded) KV cache.

    q (B, S, H, hd); k/v (B, S_max, Hkv, hd); positions >= valid_len masked.
    Works with seq-sharded caches: the softmax reductions over the cache axis
    are plain jnp reductions that GSPMD turns into cross-shard all-reduces.
    """
    B, S, H, hd = q.shape
    Hkv = k.shape[2]
    group = H // Hkv
    # keep the (huge) cache in bf16; accumulate the contraction in f32 --
    # halves decode HBM traffic vs casting k/v up front
    qg = (q * (hd ** -0.5)).reshape(B, S, Hkv, group, hd)
    logits = jnp.einsum("bskgd,btkd->bskgt", qg, k,
                        preferred_element_type=jnp.float32)
    # causal-and-valid: key t visible to query s iff t <= qpos_s (< valid_len)
    qpos = valid_len - S + jnp.arange(S)
    cmask = jnp.arange(kv_len)[None, :] <= qpos[:, None]     # (S, T)
    logits = jnp.where(cmask[None, :, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bskgt,btkd->bskgd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, S, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Cross attention (whisper decoder)
# ---------------------------------------------------------------------------

def cross_attention(x, enc_kv, p, cfg):
    """x (B, S, D); enc_kv: precomputed (k, v) each (B, T, Hkv, hd)."""
    B, S, _ = x.shape
    H, hd = cfg.n_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k, v = enc_kv
    out = attn_ops.attention(
        jnp.moveaxis(q, 1, 2), jnp.moveaxis(k, 1, 2), jnp.moveaxis(v, 1, 2),
        causal=False, backend="xla")
    return jnp.moveaxis(out, 1, 2).reshape(B, S, H * hd) @ p["wo"]


def init_linear(key, shape, dtype, scale=None):
    fan_in = shape[0]
    if scale is None:
        scale = fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)
