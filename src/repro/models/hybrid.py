"""Zamba2-style hybrid: a Mamba2 backbone with a *shared* transformer block
(attention + MLP, single parameter copy) applied every ``shared_attn_every``
layers.

The shared block's parameters are reused at every application, but each
application needs its own KV cache (activations differ), so the cache for
the shared block is stacked (n_applications, ...).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import layers as L
from . import mamba2 as m2
from . import sharding as sh


def _n_apps(cfg):
    return cfg.n_layers // cfg.shared_attn_every


def param_shapes(cfg):
    d = L.dtype_of(cfg)
    sd = jax.ShapeDtypeStruct
    D, H, Hkv, hd, F = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                        cfg.head_dim, cfg.d_ff)
    p = {"embed": sd((cfg.vocab, D), d),
         "final_norm": sd((D,), d),
         "layers": m2.layer_shapes(cfg, cfg.n_layers),
         "shared": {
             "ln1": sd((D,), d), "ln2": sd((D,), d),
             "wq": sd((D, H * hd), d), "wk": sd((D, Hkv * hd), d),
             "wv": sd((D, Hkv * hd), d), "wo": sd((H * hd, D), d),
             "w_gate": sd((D, F), d), "w_up": sd((D, F), d),
             "w_down": sd((F, D), d),
         }}
    if not cfg.tie_embeddings:
        p["lm_head"] = sd((D, cfg.vocab), d)
    return p


def logical_axes(cfg):
    base = m2.logical_axes(cfg)

    shared = {"ln1": (None,), "ln2": (None,),
              "wq": ("fsdp", "model"), "wk": ("fsdp", "model"),
              "wv": ("fsdp", "model"), "wo": ("model", "fsdp"),
              "w_gate": ("fsdp", "model"), "w_up": ("fsdp", "model"),
              "w_down": ("model", "fsdp")}
    out = {"embed": ("vocab", "fsdp"), "final_norm": (None,),
           "layers": base["layers"], "shared": shared}
    if not cfg.tie_embeddings:
        out["lm_head"] = ("fsdp", "vocab")
    return out


def init_params(cfg, key):
    shapes = param_shapes(cfg)
    leaves, treedef = jax.tree_util.tree_flatten(shapes)
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, spec in zip(keys, leaves):
        if len(spec.shape) >= 2 and spec.shape[-1] > 8:
            w = (jax.random.normal(k, spec.shape, jnp.float32)
                 * spec.shape[-2] ** -0.5)
        else:
            w = jnp.ones(spec.shape, jnp.float32) * 0.1
        out.append(w.astype(spec.dtype))
    p = jax.tree_util.tree_unflatten(treedef, out)
    p["layers"]["A_log"] = jnp.zeros_like(p["layers"]["A_log"])
    p["layers"]["dt_bias"] = jnp.full_like(p["layers"]["dt_bias"], -2.0)
    return p


def _shared_block(cfg, p, x, positions, cache, cache_index, mode):
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    attn, nc = L.gqa_attention(h, p, cfg, positions, cache, cache_index, mode)
    x = x + attn
    h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + L.swiglu(h, p["w_gate"], p["w_up"], p["w_down"])
    return x, nc


def forward(cfg, params, tokens, *, mode="train", cache=None,
            cache_index: int = 0, remat: Optional[bool] = None):
    remat = cfg.remat if remat is None else remat
    x = L.embed(tokens, params["embed"])
    x = sh.constrain(x, "batch", None, None)
    B, S, _ = x.shape
    positions = cache_index + jnp.arange(S)[None, :]
    k = cfg.shared_attn_every
    napp = _n_apps(cfg)

    def mbody(lp, xx, lc):
        return m2._layer(cfg, lp, xx, lc, mode)

    def sbody(p_, xx, pos, c_, ci):
        return _shared_block(cfg, p_, xx, pos, c_, ci, mode)
    if remat and mode == "train":
        mbody = jax.checkpoint(mbody, policy=L.remat_policy_of(cfg))
        sbody = jax.checkpoint(sbody, policy=L.remat_policy_of(cfg))

    # group mamba layers: (napp, k, ...) stacked params
    lp = jax.tree_util.tree_map(
        lambda a: a.reshape((napp, k) + a.shape[1:]), params["layers"])
    caches = cache or {}
    new_m, new_s = [], []
    for g in range(napp):
        glp = jax.tree_util.tree_map(lambda a: a[g], lp)
        gc = (jax.tree_util.tree_map(lambda a: a[g], caches["mamba"])
              if cache else None)
        if gc is None:
            def scan_fn(carry, inp):
                y, _ = mbody(inp, carry, None)
                return y, None
            x, _ = jax.lax.scan(scan_fn, x, glp, unroll=cfg.scan_unroll)
        else:
            def scan_fn(carry, inp):
                p_, c_ = inp
                y, nc = mbody(p_, carry, c_)
                return y, nc
            x, nc = jax.lax.scan(scan_fn, x, (glp, gc), unroll=cfg.scan_unroll)
            new_m.append(nc)
        sc = (jax.tree_util.tree_map(lambda a: a[g], caches["shared"])
              if cache else None)
        x, snc = sbody(params["shared"], x, positions, sc, cache_index)
        if snc is not None:
            new_s.append(snc)

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params.get("lm_head")
    logits = L.unembed(x, head if head is not None else params["embed"].T)
    logits = sh.constrain(logits, "batch", None, "vocab")
    if cache is not None:
        new_cache = {
            "mamba": jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *new_m),
            "shared": jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *new_s),
        }
        return logits, new_cache
    return logits


def cache_shapes(cfg, batch: int, max_len: int):
    d = L.dtype_of(cfg)
    sd = jax.ShapeDtypeStruct
    napp = _n_apps(cfg)
    k = cfg.shared_attn_every
    mc = m2.cache_shapes(cfg, batch)
    # regroup mamba caches (L,...) -> (napp, k, ...)
    mc = {kk: sd((napp, k) + v.shape[1:], v.dtype) for kk, v in mc.items()}
    return {
        "mamba": mc,
        "shared": {
            "k": sd((napp, batch, max_len, cfg.n_kv_heads, cfg.head_dim), d),
            "v": sd((napp, batch, max_len, cfg.n_kv_heads, cfg.head_dim), d),
        },
    }


def cache_logical_axes(cfg):
    return {
        "mamba": {"conv": (None, None, "batch", None, "model"),
                  "ssm": (None, None, "batch", "model", None, None)},
        "shared": {"k": (None, "batch", "seq_cache", "kv_heads", None),
                   "v": (None, "batch", "seq_cache", "kv_heads", None)},
    }
