"""Mamba2 (SSD) decoder — the attention-free family.

Block: in_proj -> (z | x | B | C | dt); causal depthwise conv on (x|B|C);
dt = softplus(dt + bias); SSD scan (Pallas kernel on TPU, chunked jnp on
CPU); gated RMSNorm; out_proj.

Decode keeps O(1)-in-sequence state: a (K-1)-deep conv cache and the
(H, N, P) SSM state -- which is why this family (and the Zamba2 hybrid)
are the ones that run the ``long_500k`` cell.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import layers as L
from . import sharding as sh
from ..kernels.ssd_scan import ops as ssd_ops
from ..kernels.ssd_scan import ref as ssd_ref


def _dims(cfg):
    din = cfg.ssm_d_inner
    H = cfg.ssm_heads
    P = cfg.ssm_head_dim
    G = cfg.ssm_groups
    N = cfg.ssm_state
    conv_dim = din + 2 * G * N
    return din, H, P, G, N, conv_dim


def layer_shapes(cfg, nl):
    d = L.dtype_of(cfg)
    sd = jax.ShapeDtypeStruct
    D = cfg.d_model
    din, H, P, G, N, conv_dim = _dims(cfg)
    return {
        "ln": sd((nl, D), d),
        "in_proj": sd((nl, D, 2 * din + 2 * G * N + H), d),
        "conv_w": sd((nl, cfg.ssm_conv, conv_dim), d),
        "conv_b": sd((nl, conv_dim), d),
        "dt_bias": sd((nl, H), jnp.float32),
        "A_log": sd((nl, H), jnp.float32),
        "D_skip": sd((nl, H), jnp.float32),
        "norm_w": sd((nl, din), d),
        "out_proj": sd((nl, din, D), d),
    }


def param_shapes(cfg):
    d = L.dtype_of(cfg)
    sd = jax.ShapeDtypeStruct
    p = {"embed": sd((cfg.vocab, cfg.d_model), d),
         "final_norm": sd((cfg.d_model,), d),
         "layers": layer_shapes(cfg, cfg.n_layers)}
    if not cfg.tie_embeddings:
        p["lm_head"] = sd((cfg.d_model, cfg.vocab), d)
    return p


def logical_axes(cfg):
    def annot(tree):
        out = {}
        for k, v in tree.items():
            if isinstance(v, dict):
                out[k] = annot(v)
            elif k == "embed":
                out[k] = ("vocab", "fsdp")
            elif k == "lm_head":
                out[k] = ("fsdp", "vocab")
            elif k in ("in_proj",):
                out[k] = (None, "fsdp", "model")
            elif k in ("out_proj",):
                out[k] = (None, "model", "fsdp")
            elif k in ("conv_w", "conv_b", "norm_w"):
                out[k] = (None,) * (len(v.shape) - 1) + ("model",)
            else:
                out[k] = (None,) * len(v.shape)
        return out
    return annot(param_shapes(cfg))


def init_params(cfg, key):
    shapes = param_shapes(cfg)
    leaves, treedef = jax.tree_util.tree_flatten(shapes)
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, spec in zip(keys, leaves):
        path_hint = spec.shape
        if len(spec.shape) >= 2 and spec.shape[-1] > 8:
            w = (jax.random.normal(k, spec.shape, jnp.float32)
                 * spec.shape[-2] ** -0.5)
        else:
            w = jnp.ones(spec.shape, jnp.float32) * 0.1
        out.append(w.astype(spec.dtype))
    p = jax.tree_util.tree_unflatten(treedef, out)
    # A must be negative: A = -exp(A_log); dt_bias small positive
    p["layers"]["A_log"] = jnp.zeros_like(p["layers"]["A_log"])
    p["layers"]["dt_bias"] = jnp.full_like(p["layers"]["dt_bias"], -2.0)
    return p


def _causal_conv(x, w, b, conv_state=None):
    """x (B, S, C); w (K, C) depthwise; returns (y, new_state (B, K-1, C))."""
    K = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)             # (B, S+K-1, C)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
            for i in range(K))
    y = jax.nn.silu((y + b).astype(jnp.float32)).astype(x.dtype)
    new_state = xp[:, -(K - 1):, :]
    return y, new_state


def mamba_block(cfg, p, x, cache=None, mode="train"):
    """x (B, S, D) -> (y, new_cache).  cache: {"conv": (B,K-1,Cv),
    "ssm": (B,H,N,P)}."""
    B, S, D = x.shape
    din, H, P, G, N, conv_dim = _dims(cfg)
    proj = x @ p["in_proj"]
    z = proj[..., :din]
    xbc = proj[..., din:din + conv_dim]
    dt_raw = proj[..., din + conv_dim:]

    conv_state = cache.get("conv") if cache else None
    if mode == "decode" and S == 1:
        xbc_conv, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"],
                                          conv_state)
    else:
        xbc_conv, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"],
                                          conv_state if mode != "train"
                                          else None)
    xc = xbc_conv[..., :din].reshape(B, S, H, P)
    Bm = xbc_conv[..., din:din + G * N].reshape(B, S, G, N)
    Cm = xbc_conv[..., din + G * N:].reshape(B, S, G, N)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    new_ssm = None
    if mode == "decode" and S == 1:
        # single-step recurrence on the cached state
        h_prev = cache["ssm"].astype(jnp.float32)       # (B,H,N,P)
        rep = H // G
        b1 = jnp.repeat(Bm[:, 0], rep, axis=1)          # (B,H,N)
        c1 = jnp.repeat(Cm[:, 0], rep, axis=1)
        dt1 = dt[:, 0]                                   # (B,H)
        x1 = xc[:, 0].astype(jnp.float32)                # (B,H,P)
        decay = jnp.exp(A[None] * dt1)                   # (B,H)
        h = (decay[..., None, None] * h_prev
             + dt1[..., None, None] * b1[..., :, None] * x1[..., None, :])
        y = jnp.einsum("bhn,bhnp->bhp", c1, h)[:, None]  # (B,1,H,P)
        new_ssm = h.astype(cache["ssm"].dtype)
        y = y.astype(x.dtype)
    else:
        backend = "chunked" if jax.default_backend() != "tpu" else "auto"
        y = ssd_ops.ssd(xc, dt.astype(jnp.float32), A, Bm, Cm,
                        backend=backend)
        if cache is not None:  # prefill: also compute the final state
            new_ssm = ssd_ref.ssd_final_state(
                xc, dt.astype(jnp.float32), A, Bm, Cm).astype(
                cache["ssm"].dtype)
    y = y + xc.astype(jnp.float32).astype(x.dtype) * p["D_skip"].astype(
        x.dtype)[None, None, :, None]
    y = y.reshape(B, S, din)
    y = L.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                   p["norm_w"], cfg.norm_eps)
    out = y @ p["out_proj"]
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv.astype(cache["conv"].dtype),
                     "ssm": new_ssm}
    return out, new_cache


def _layer(cfg, p, x, cache, mode):
    h = L.rms_norm(x, p["ln"], cfg.norm_eps)
    y, nc = mamba_block(cfg, p, h, cache, mode)
    return x + y, nc


def forward(cfg, params, tokens, *, mode="train", cache=None,
            cache_index: int = 0, remat: Optional[bool] = None):
    remat = cfg.remat if remat is None else remat
    x = L.embed(tokens, params["embed"])
    x = sh.constrain(x, "batch", None, None)

    def body(lp, xx, lc):
        return _layer(cfg, lp, xx, lc, mode)
    if remat and mode == "train":
        body = jax.checkpoint(body, policy=L.remat_policy_of(cfg))
    if cache is None:
        def scan_fn(carry, lp):
            y, _ = body(lp, carry, None)
            return y, None
        x, _ = jax.lax.scan(scan_fn, x, params["layers"], unroll=cfg.scan_unroll)
        new_cache = None
    else:
        def scan_fn(carry, inp):
            lp, lc = inp
            y, nc = body(lp, carry, lc)
            return y, nc
        x, new_cache = jax.lax.scan(scan_fn, x, (params["layers"], cache), unroll=cfg.scan_unroll)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params.get("lm_head")
    logits = L.unembed(x, head if head is not None else params["embed"].T)
    logits = sh.constrain(logits, "batch", None, "vocab")
    return (logits, new_cache) if cache is not None else logits


def cache_shapes(cfg, batch: int, max_len: int = 0):
    """SSM caches are O(1) in sequence length (max_len unused)."""
    d = L.dtype_of(cfg)
    sd = jax.ShapeDtypeStruct
    din, H, P, G, N, conv_dim = _dims(cfg)
    nl = cfg.n_layers
    return {"conv": sd((nl, batch, cfg.ssm_conv - 1, conv_dim), d),
            "ssm": sd((nl, batch, H, N, P), jnp.float32)}


def cache_logical_axes(cfg):
    return {"conv": (None, "batch", None, "model"),
            "ssm": (None, "batch", "model", None, None)}
