"""Stateless counter-based randomness streams for the simulation engines.

Every random draw an engine makes in-pipeline is a pure function

    value = threefry2x32(key(seed, site, lane), counter(logical_id, slot))

of the replicate ``seed``, a :data:`draw-site <SITE_EDGE_RAND>` tag, the
*logical* identity of the drawing entity (host id, packet id -- dense
prefixes of any padded id space), the time slot (or arrival rank on the
fast engine), and an optional ``lane`` sub-index (the port column of a JSQ
noise grid).  Nothing else enters the computation: no carried generator
state, no array shapes, no batch position.  Consequences, in decreasing
order of why this module exists:

  * **padding invariance** -- a point padded onto a larger tree's (or a
    fused megabatch's) compiled pipeline draws *bitwise-identical* values
    for every real entity, because pad entities merely extend the id range
    the stream is evaluated over.  This is what lets rand/JSQ switch
    schemes cross-tree-size fuse on the slotted engine (they were the last
    holdouts keying fused dispatches on raw ``k``);
  * **order invariance** -- draws need no sequencing, so vmapped /
    shard_map-sharded rows and serial runs agree without replaying a split
    chain;
  * **replayability** -- any single draw can be recomputed in isolation
    (tests do exactly this).

The PRF is Threefry-2x32 with 20 rounds -- the same permutation JAX's
default PRNG uses (`Salmon et al., SC'11 <https://doi.org/10.1145/2063384
.2063405>`_) -- written against the operator set ``numpy`` and
``jax.numpy`` share, so host-side precomputation (fast-engine noise grids)
and in-``while_loop`` draws (slotted engine) evaluate the *same* function.

Key/counter packing (injective over the tuples the engines use)::

    k0 = seed_lo                      # low 32 bits of the replicate seed
    k1 = seed_hi ^ (site << 16 | lane)  # site < 2**16, lane < 2**16
    c0 = slot                         # time slot / arrival rank
    c1 = logical id                   # host / packet / switch id

Draws at distinct (seed, site, lane, id, slot) tuples are therefore
distinct PRF evaluations; uniformity and cross-site independence are
tested statistically in ``tests/test_entropy.py``.
"""
from __future__ import annotations

import numpy as np

# ---------------------------------------------------------------------------
# Draw-site tags.  One per randomness consumer; adding a site never perturbs
# the streams of existing sites (the tag is part of the PRF key).
# ---------------------------------------------------------------------------
SITE_EDGE_RAND = 1      # loopsim: per-host uniform (a, c) spray at the edge
SITE_AGG_RAND = 2       # loopsim: per-packet uniform core sub-link at the agg
SITE_EDGE_JSQ = 3       # loopsim: per-(host, port) JSQ tie-break noise
SITE_AGG_JSQ = 4        # loopsim: per-(packet, port) JSQ tie-break noise
SITE_FAST_EDGE_JSQ = 5  # fastsim: per-(edge switch, rank, port) JSQ noise
SITE_FAST_AGG_JSQ = 6   # fastsim: per-(agg switch, rank, port) JSQ noise
SITE_LINK_FAIL = 7      # topology: per-(tree, layer, link) random failures

_MASK32 = 0xFFFFFFFF
_PARITY = 0x1BD11BDA                       # Threefry key-schedule parity
_ROTATIONS = ((13, 15, 26, 6), (17, 29, 16, 24))
_INV_2_24 = np.float32(1.0 / (1 << 24))


def key_words(seed: int):
    """Host-side split of a (possibly 64-bit) replicate seed into the two
    uint32 PRF key words the engines carry as per-row operands."""
    s = int(seed) & 0xFFFFFFFFFFFFFFFF
    return np.uint32(s & _MASK32), np.uint32((s >> 32) & _MASK32)


def _rotl32(x, r: int):
    return (x << r) | (x >> (32 - r))


def threefry2x32(k0, k1, c0, c1):
    """Threefry-2x32, 20 rounds: PRF from (key, counter) to two uint32 words.

    Array-library agnostic: inputs may be ``numpy`` or ``jax.numpy`` uint32
    arrays (broadcast together); all arithmetic is mod 2**32.  Matches
    JAX's ``threefry_2x32`` bit-for-bit (known-answer tested).
    """
    with np.errstate(over="ignore"):     # wraparound mod 2**32 is the point
        ks0, ks1 = k0, k1
        ks2 = ks0 ^ ks1 ^ np.uint32(_PARITY)
        x0 = c0 + ks0
        x1 = c1 + ks1
        schedule = ((ks1, ks2), (ks2, ks0), (ks0, ks1), (ks1, ks2),
                    (ks2, ks0))
        for block, (inj0, inj1) in enumerate(schedule):
            for r in _ROTATIONS[block % 2]:
                x0 = x0 + x1
                x1 = _rotl32(x1, r) ^ x0
            x0 = x0 + inj0
            x1 = x1 + inj1 + np.uint32(block + 1)
    return x0, x1


def _as_u32(x):
    # Works for python ints, numpy and jnp arrays alike; values are taken
    # mod 2**32 (ids/slots are nonnegative and < 2**31 in practice).  Python
    # ints become 0-d *arrays*, not numpy scalars: scalar integer overflow
    # raises RuntimeWarnings, array overflow wraps silently.
    if isinstance(x, (int, np.integer)):
        return np.asarray(int(x) & _MASK32, np.uint32)
    return x.astype(np.uint32)


def draw_u32(seed_lo, seed_hi, site, ids, slot, lane=0):
    """One uint32 per element of ``broadcast(ids, slot, lane)``: the counter
    stream at (seed, site, lane, id, slot).  ``seed_lo``/``seed_hi`` are the
    :func:`key_words` operands (scalars, possibly traced); ``site`` is a
    python int tag; ``ids``/``slot``/``lane`` broadcast together."""
    k0 = _as_u32(seed_lo)
    k1 = _as_u32(seed_hi) ^ (np.uint32(site << 16) ^ _as_u32(lane))
    x0, _ = threefry2x32(k0, k1, _as_u32(slot), _as_u32(ids))
    return x0


def draw_int(seed_lo, seed_hi, site, ids, slot, bound, lane=0):
    """Integers in ``[0, bound)`` (int32).  ``bound`` may be a traced per-row
    operand (the logical port count); the modulo bias is < 2**-25 for the
    bounds the engines use (<= k**2/4)."""
    u = draw_u32(seed_lo, seed_hi, site, ids, slot, lane=lane)
    return (u % _as_u32(bound)).astype(np.int32)


def draw_uniform(seed_lo, seed_hi, site, ids, slot, lane=0):
    """float32 uniforms in ``[0, 1)`` (24-bit mantissa resolution)."""
    u = draw_u32(seed_lo, seed_hi, site, ids, slot, lane=lane)
    return (u >> np.uint32(8)).astype(np.float32) * _INV_2_24


def uniform_grid(seed: int, site: int, n_ids: int, n_slots: int,
                 n_lanes: int) -> np.ndarray:
    """Host-side (numpy) ``(n_ids, n_slots, n_lanes)`` float32 uniform grid:
    element ``[i, s, l]`` is the stream value at (seed, site, lane=l, id=i,
    slot=s).  The fast engine precomputes its JSQ tie-break noise with this;
    growing any axis (JSQ pad-retry, megabatch group-wide padding) extends
    the grid without perturbing existing entries."""
    lo, hi = key_words(seed)
    return np.asarray(draw_uniform(
        lo, hi, site,
        ids=np.arange(n_ids, dtype=np.uint32)[:, None, None],
        slot=np.arange(n_slots, dtype=np.uint32)[None, :, None],
        lane=np.arange(n_lanes, dtype=np.uint32)[None, None, :]))
