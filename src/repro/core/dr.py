"""Destination-based Rotation (DR) — the paper's optimal scheduling discipline.

DR generalizes DRB [Cao et al.]: traffic is load-balanced round-robin *per
destination group*, guaranteeing uniform load on both uplinks **and**
downlinks of a fat tree (the per-destination pointer is what SIMPLE RR lacks:
RR balances uplinks but lets a destination's traffic collide on the single
southbound path from core to destination).

This module holds the pointer machinery shared by HOST DR and OFAN:

  * a *pointer* is (start offset, traversal order) over a set of candidate
    ports/paths; packet ``r`` of the pointer's group uses
    ``order[(start + r) % len(order)]``;
  * pointers are initialized to a random start and a random traversal order to
    avoid cross-pointer synchronization (paper §7, Implementation);
  * under failures, the traversal order is rebuilt from W-ECMP weights as an
    Interleaved Weighted Round-Robin (IWRR) schedule (paper App. F.4).
"""
from __future__ import annotations

import numpy as np


def random_pointer_table(n_pointers: int, n_ports: int,
                         rng: np.random.Generator) -> tuple[np.ndarray, np.ndarray]:
    """(orders, starts): orders (n_pointers, n_ports) random permutations,
    starts (n_pointers,) random initial offsets."""
    orders = np.argsort(rng.random((n_pointers, n_ports)), axis=1).astype(np.int32)
    starts = rng.integers(0, n_ports, size=n_pointers).astype(np.int32)
    return orders, starts


def iwrr_schedule(weights: np.ndarray, rng: np.random.Generator) -> np.ndarray:
    """Interleaved Weighted Round-Robin schedule from raw W-ECMP weights.

    Divides by the gcd, randomly shuffles the port order, then interleaves so
    a port with weight w appears w times, spread as evenly as possible
    (paper App. F.4 example: weights {2,2,2,1} -> schedule length 7 with the
    weight-1 port appearing half as often).

    Returns an int32 array of port indices (the schedule); all-zero weights
    yield an empty schedule (destination unreachable).
    """
    w = np.asarray(weights, dtype=np.int64)
    if (w < 0).any():
        raise ValueError("negative W-ECMP weight")
    if w.sum() == 0:
        return np.zeros((0,), dtype=np.int32)
    nz = w > 0
    g = np.gcd.reduce(w[nz])
    w = w // g
    ports = np.flatnonzero(nz)
    ports = ports[rng.permutation(len(ports))]
    wp = w[ports]
    # Interleave: round r emits every port whose weight exceeds the number of
    # times it has been emitted, in shuffled port order -- the classic IWRR
    # expansion (each of max(w) rounds emits ports with w > round).
    sched = []
    for r in range(int(wp.max())):
        for p, wi in zip(ports.tolist(), wp.tolist()):
            if wi > r:
                sched.append(p)
    return np.asarray(sched, dtype=np.int32)


def rotate(order: np.ndarray, start: int, ranks: np.ndarray) -> np.ndarray:
    """Apply a pointer: port for the rank-th packet of this pointer's group."""
    L = order.shape[0]
    return order[(start + ranks) % L]
