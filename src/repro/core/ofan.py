"""OFAN — the paper's switch-based realization of Destination-based Rotation.

OFAN exploits the fat-tree's *mandatory waypoints* to consolidate DR pointers:

  * an **edge** switch keeps one pointer per (destination edge switch,
    packet-size class) rotating over its k/2 uplink ports;
  * an **aggregation** switch keeps one pointer per (destination pod,
    packet-size class) rotating over its k/2 core-facing ports.

At startup every pointer gets a random initial port and a random traversal
order (to avoid cross-pointer synchronization).  Under failures, the traversal
orders become IWRR schedules over W-ECMP weights (App. F.4); with no failures
the schedule degenerates to the shuffled permutation.

This module builds the static pointer tables consumed by both engines.  The
data-plane semantics (`rank within the pointer's group -> port`) live in the
engines; here we only build (order, start) tables.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from ..net.topology import FatTree, LinkState
from . import dr as dr_mod


@dataclasses.dataclass
class OfanTables:
    """Pointer tables.  Edge layer: pointer id = src_global_edge * n_edges +
    dst_global_edge.  Agg layer: pointer id = global_agg * n_pods + dst_pod.

    ``edge_orders``: (n_edge_ptrs, sched_len) int32 port schedule per pointer.
    ``edge_starts``: (n_edge_ptrs,) random initial offsets.
    ``edge_len``:    (n_edge_ptrs,) schedule length actually used (IWRR
                     schedules under failure may differ in length; rows are
                     padded with repeats of the schedule to a common width).
    Similarly for agg_*.
    """
    edge_orders: np.ndarray
    edge_starts: np.ndarray
    edge_len: np.ndarray
    agg_orders: np.ndarray
    agg_starts: np.ndarray
    agg_len: np.ndarray


def build_tables(tree: FatTree, rng: np.random.Generator,
                 links: Optional[LinkState] = None,
                 use_wecmp: bool = True) -> OfanTables:
    """Build OFAN pointer tables; with ``links`` given and failures present,
    schedules follow IWRR over W-ECMP weights (or plain FIB reachability when
    ``use_wecmp=False`` — the simpler variant of App. F.4)."""
    h = tree.half
    n_edges = tree.n_edge_switches
    n_pods = tree.n_pods
    n_aggs = tree.n_agg_switches

    failure_free = links is None or not links.any_failure()

    # ---- edge pointers: (src edge, dst edge) -------------------------------
    n_eptr = n_edges * n_edges
    if failure_free:
        e_orders, e_starts = dr_mod.random_pointer_table(n_eptr, h, rng)
        e_len = np.full(n_eptr, h, dtype=np.int32)
        a_orders, a_starts = dr_mod.random_pointer_table(n_aggs * n_pods, h, rng)
        a_len = np.full(n_aggs * n_pods, h, dtype=np.int32)
        return OfanTables(e_orders, e_starts, e_len, a_orders, a_starts, a_len)

    # Failure case: IWRR schedules; pad rows to a common width by tiling.
    def _pad(rows):
        width = max((len(r) for r in rows if len(r)), default=h)
        out = np.zeros((len(rows), width), dtype=np.int32)
        lens = np.zeros(len(rows), dtype=np.int32)
        for i, r in enumerate(rows):
            if len(r) == 0:          # unreachable: keep port 0, flagged len 0
                lens[i] = 0
                continue
            reps = int(np.ceil(width / len(r)))
            out[i] = np.tile(r, reps)[:width]
            lens[i] = len(r)
        return out, lens

    e_rows = []
    for se in range(n_edges):
        sp, sei = divmod(se, h)
        for de in range(n_edges):
            dp, dei = divmod(de, h)
            if se == de:
                e_rows.append(np.arange(h, dtype=np.int32))  # unused
                continue
            if use_wecmp:
                w = links.wecmp_edge_weights(sp, sei, dp, dei)
            else:
                w = (links.ea[sp, sei, :]).astype(np.int64)
                if dp != sp:
                    # FIB-only: reachable if some path exists through a
                    w = w * (links.ea[dp, dei, :] & (
                        (links.ac[sp, :, :] & links.ac[dp, :, :]).any(axis=1))
                    ).astype(np.int64)
                else:
                    w = w * links.ea[dp, dei, :].astype(np.int64)
            e_rows.append(dr_mod.iwrr_schedule(w, rng))
    e_orders, e_len = _pad(e_rows)
    e_starts = rng.integers(0, np.maximum(e_len, 1)).astype(np.int32)

    a_rows = []
    for ga in range(n_aggs):
        sp, ai = divmod(ga, h)
        for dp in range(n_pods):
            if dp == sp:
                a_rows.append(np.arange(h, dtype=np.int32))  # unused (southbound)
                continue
            if use_wecmp:
                w = links.wecmp_agg_weights(sp, ai, dp)
            else:
                w = (links.ac[sp, ai, :] & links.ac[dp, ai, :]).astype(np.int64)
            a_rows.append(dr_mod.iwrr_schedule(w, rng))
    a_orders, a_len = _pad(a_rows)
    a_starts = rng.integers(0, np.maximum(a_len, 1)).astype(np.int32)
    return OfanTables(e_orders, e_starts, e_len, a_orders, a_starts, a_len)


def pointer_counts(tree: FatTree) -> dict:
    """Pointer state a switch must hold (paper §7: 'very reasonable'):
    edge: one per destination edge switch x size class; agg: one per
    destination pod x size class.  Returned per size class."""
    return {
        "edge_pointers": tree.n_edge_switches - 1,
        "agg_pointers": tree.n_pods - 1,
        "host_dr_pointers_per_host": tree.n_hosts - 1,
    }
