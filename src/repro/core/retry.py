"""Bounded retry with exponential backoff.

One shared primitive for every "transient failure" loop in the repo: the
training driver's per-step retry (``repro.train.fault_tolerance
.ResilientLoop``) and the sweep runner's per-dispatch retry
(``repro.sweep.runner``).  Deliberately tiny and injectable -- callers pass
their own ``sleep`` so tests (and deterministic trace comparisons) never
wait on a wall clock, and ``on_retry`` so each caller keeps its own logging
/ health-callback / trace-span idiom.
"""
from __future__ import annotations

import time
from typing import Callable, Optional


def retry_call(fn: Callable, *, max_retries: int, backoff_s: float,
               sleep: Callable[[float], None] = time.sleep,
               on_retry: Optional[Callable] = None,
               on_exhausted: Optional[Callable] = None):
    """Call ``fn()`` up to ``1 + max_retries`` times.

    On attempt ``a`` failing with a retry budget left: ``on_retry(a, exc,
    delay)`` is invoked (if given), then ``sleep(delay)`` with ``delay =
    backoff_s * 2**a``.  When the budget is exhausted ``on_exhausted(exc)``
    runs (cleanup hook -- e.g. draining an async checkpointer) and the last
    exception propagates unchanged.  Returns ``fn()``'s value.
    """
    for attempt in range(max_retries + 1):
        try:
            return fn()
        except Exception as e:  # noqa: BLE001 -- caller-scoped transience
            if attempt >= max_retries:
                if on_exhausted is not None:
                    on_exhausted(e)
                raise
            delay = backoff_s * (2 ** attempt)
            if on_retry is not None:
                on_retry(attempt, e, delay)
            sleep(delay)
