"""Load-balancing scheme descriptors — the paper's leading contenders (§3.2),
the simplified theory models (§6.1), and the DR disciplines (§6–7).

A scheme tells the engines how the two free path choices of a 3-level
fat-tree are made:

  * ``edge_mode``: how the source edge switch uplink (aggregation index
    ``a`` in [0, k/2)) is picked;
  * ``agg_mode``: how the aggregation uplink (core sub-index ``c``) is picked.

Modes:
  ``pre``        choice precomputed at the host (per flow / subflow / packet /
                 DR pointer) — host-based schemes;
  ``rr``         switch round-robin over the uplink group, one pointer per
                 switch (the theory's SIMPLE RR);
  ``rr_reset``   htsim-style round-robin whose traversal order is re-permuted
                 every ``reset_wraps`` wraparounds (SWITCH PKT);
  ``rand``       uniform random at the switch (the theory's RSQ);
  ``jsq``        join-shortest-queue with random tie-break (theory JSQ);
  ``jsq_quant``  JSQ over quantized queue bins (SWITCH PKT AR / Spectrum-X);
  ``ofan``       OFAN consolidated DR pointers: per destination edge switch at
                 the edge layer, per destination pod at the aggregation layer.

Host-based adaptive schemes (REPS, PLB) need ACK/ECN feedback and therefore
only run on the slotted feedback engine (``net.loopsim``); their descriptors
carry the relevant thresholds.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from ..net.topology import FatTree
from . import dr as dr_mod


@dataclasses.dataclass(frozen=True)
class LBScheme:
    name: str
    edge_mode: str
    agg_mode: str
    # pre-mode host label granularity: 'flow' | 'subflow' | 'packet' | 'dr'
    host_granularity: Optional[str] = None
    n_subflows: int = 0
    reset_wraps: int = 5                     # SWITCH PKT order re-permute period
    quanta: Tuple[float, ...] = (0.05, 0.10, 0.20)   # SWITCH PKT AR bins
    buffer_pkts: int = 195                   # 800 KB / ~4.1 KB frames
    # loopsim-only host adaptation:
    ecn_frac: float = 0.0          # REPS: discard labels whose ACK was marked
    plb_alpha: int = 0             # PLB: may change label every alpha packets
    plb_beta: float = 0.0          # PLB: ...if > beta of recent acks ECN-marked
    adaptive_host: bool = False    # needs ACK feedback (loopsim only)

    @property
    def needs_feedback(self) -> bool:
        return self.adaptive_host

    def reaction_class(self) -> str:
        """How fast this scheme observes path-state changes under a dynamic
        fault schedule (``repro.faults``): ``'host'`` for schemes whose path
        choices live at the host (host-labelled ``pre`` schemes and
        ACK-adaptive REPS/PLB see failures end-to-end -- black-holed labels
        stop returning ACKs), ``'switch'`` for switch-local state (RR, JSQ,
        OFAN wait on local port status / W-ECMP convergence).  Selects
        between a schedule's ``host_react`` and ``switch_react`` delays."""
        if self.adaptive_host or self.edge_mode == "pre":
            return "host"
        return "switch"

    def table_keys(self) -> Tuple[str, ...]:
        """Names of the per-seed switch-table operands this scheme's
        fast-engine pipeline consumes, in pipeline argument order.  These are
        the vmappable pytree leaves a megabatch stacks onto the fused batch
        axis (rotation state for RR/SWITCH PKT, consolidated DR pointers for
        OFAN); host-labelled and JSQ schemes carry their per-layer state in
        the per-packet/noise operands instead and need no tables."""
        if self.edge_mode == "rr_reset":
            return ("rr_perms", "rr_starts")
        if self.edge_mode == "rr":
            return ("rr_starts",)
        if self.edge_mode == "ofan":
            return ("lens", "orders", "starts")
        return ()

    def shape_key(self) -> Tuple:
        """Hashable key of everything that determines the *compiled* fast-engine
        pipeline (mirrors ``fastsim._build_run``'s cache key, minus the
        topology/padding part).  Two schemes with equal shape keys -- e.g.
        flow_ecmp and host_pkt, which differ only in host-side label
        granularity -- share one compiled executable; the sweep planner orders
        campaign grid points by this key to maximize compile-cache reuse."""
        quanta = (tuple(self.quanta) if self.edge_mode == "jsq_quant"
                  else None)
        return (self.edge_mode, self.agg_mode, quanta, self.buffer_pkts,
                self.reset_wraps)

    def loop_kfusable(self) -> bool:
        """Whether the slotted engine can pad this scheme's points onto a
        larger fat tree while staying bitwise-identical (the planner's
        cross-tree-size fusion).  Always True: pointer and host-label
        schemes draw host-side or from shape-independent pools, and
        rand/JSQ switch modes draw in-loop from the counter streams of
        ``core.entropy`` -- pure functions of (seed, draw site, logical
        host/packet id, slot) that padding cannot perturb.  Retained (as a
        constant) for API stability; no planner branch keys on it anymore.
        """
        return True

    def loop_shape_key(self) -> Tuple:
        """Hashable key of everything that determines the compiled *loop*
        engine (``net.loopsim``): the port-choice branches and the host
        adaptation machinery.  Schemes with equal loop shape keys -- e.g.
        flow_ecmp, host_pkt and host_dr, which all lower to the 'pre/pre'
        slotted pipeline -- fuse into one megabatched loop dispatch (the
        LoopConfig static fields are the other half of that fused key)."""
        quanta = (tuple(self.quanta) if self.edge_mode == "jsq_quant"
                  else None)
        return (self.edge_mode, self.agg_mode, quanta, self.adaptive_host,
                self.name == "host_flowlet_ar")


# ---------------------------------------------------------------------------
# Factories — Table 2 of the paper.
# ---------------------------------------------------------------------------

def ecmp() -> LBScheme:
    return LBScheme("flow_ecmp", "pre", "pre", host_granularity="flow")


def subflow(n: int = 4) -> LBScheme:
    return LBScheme("subflow_mptcp", "pre", "pre",
                    host_granularity="subflow", n_subflows=n)


def plb(alpha: int = 64, beta: float = 0.4, ecn_thresh_frac: float = 0.5) -> LBScheme:
    """HOST FLOWLET AR, modeled after PLB: change label at most every alpha
    packets when > beta of recent ACKs carried ECN marks (paper fn. 2).
    ``ecn_thresh_frac`` is the marking threshold as a fraction of buffer."""
    return LBScheme("host_flowlet_ar", "pre", "pre", host_granularity="flow",
                    plb_alpha=alpha, plb_beta=beta, ecn_frac=ecn_thresh_frac,
                    adaptive_host=True)


def host_pkt() -> LBScheme:
    """Host per-packet spraying (OPS): fresh random label every packet."""
    return LBScheme("host_pkt", "pre", "pre", host_granularity="packet")


def switch_pkt(reset_wraps: int = 5) -> LBScheme:
    """Switch per-packet round-robin, order permuted every 5 wraparounds."""
    return LBScheme("switch_pkt", "rr_reset", "rr_reset", reset_wraps=reset_wraps)


def host_pkt_ar(ecn_frac: float = 0.10) -> LBScheme:
    """Adaptive host per-packet (REPS): recycle labels whose ACKs came back
    unmarked; discard marked ones.  Feedback => loopsim only; on the fast
    engine it degenerates to host_pkt (documented approximation)."""
    return LBScheme("host_pkt_ar", "pre", "pre", host_granularity="packet",
                    ecn_frac=ecn_frac, adaptive_host=True)


def switch_pkt_ar(quanta: Tuple[float, ...] = (0.05, 0.10, 0.20),
                  buffer_pkts: int = 195) -> LBScheme:
    """Adaptive switch per-packet (Spectrum-X style): quantized shortest-queue
    with random choice inside the smallest bin."""
    return LBScheme("switch_pkt_ar", "jsq_quant", "jsq_quant",
                    quanta=quanta, buffer_pkts=buffer_pkts)


# ---- simplified theory models (§6.1) --------------------------------------

def simple_rr() -> LBScheme:
    return LBScheme("simple_rr", "rr", "rr")


def jsq() -> LBScheme:
    return LBScheme("jsq", "jsq", "jsq")


def rsq() -> LBScheme:
    return LBScheme("rsq", "rand", "rand")


# ---- DR disciplines ---------------------------------------------------------

def host_dr() -> LBScheme:
    """HOST DR (DRB): per (src host, dst host) pointer rotating over the
    lowest common layer (cores for inter-pod, aggs for intra-pod)."""
    return LBScheme("host_dr", "pre", "pre", host_granularity="dr")


def ofan() -> LBScheme:
    return LBScheme("ofan", "ofan", "ofan")


ALL_CONTENDERS = ("flow_ecmp", "subflow_mptcp", "host_flowlet_ar", "host_pkt",
                  "switch_pkt", "host_pkt_ar", "switch_pkt_ar")
PACKET_SCHEMES = ("host_pkt", "switch_pkt", "host_pkt_ar", "switch_pkt_ar",
                  "simple_rr", "jsq", "rsq", "host_dr", "ofan")


def by_name(name: str, **kw) -> LBScheme:
    table = {
        "flow_ecmp": ecmp, "subflow_mptcp": subflow, "host_flowlet_ar": plb,
        "host_pkt": host_pkt, "switch_pkt": switch_pkt,
        "host_pkt_ar": host_pkt_ar, "switch_pkt_ar": switch_pkt_ar,
        "simple_rr": simple_rr, "jsq": jsq, "rsq": rsq,
        "host_dr": host_dr, "ofan": ofan,
    }
    return table[name](**kw)


# ---------------------------------------------------------------------------
# Host-side label precomputation for 'pre' schemes.
# ---------------------------------------------------------------------------

def precompute_host_choices(scheme: LBScheme, tree: FatTree,
                            flow: np.ndarray, seq: np.ndarray,
                            flow_src: np.ndarray, flow_dst: np.ndarray,
                            rng: np.random.Generator,
                            path_valid: Optional[np.ndarray] = None,
                            ) -> Tuple[np.ndarray, np.ndarray]:
    """Per-packet (agg_choice, sub_choice) for host-based schemes.

    ``path_valid``: optional (n_flows, k/2, k/2) bool of alive (a, c) paths
    (HOST DR restricts its rotation to reachable common-layer switches; hash
    schemes re-hash among valid labels — modeling converged W-ECMP state).
    """
    h = tree.half
    n_pkts = flow.shape[0]
    n_flows = flow_src.shape[0]
    gran = scheme.host_granularity

    if gran in ("flow", "subflow"):
        n_sub = max(1, scheme.n_subflows if gran == "subflow" else 1)
        # One random (a, c) label per (flow, subflow), drawn among valid paths.
        a_lab = np.empty((n_flows, n_sub), dtype=np.int32)
        c_lab = np.empty((n_flows, n_sub), dtype=np.int32)
        for f in range(n_flows):
            if path_valid is not None:
                cand = np.argwhere(path_valid[f])
                if len(cand) == 0:
                    cand = np.argwhere(np.ones((h, h), dtype=bool))
                pick = cand[rng.integers(0, len(cand), size=n_sub)]
            else:
                pick = np.stack([rng.integers(0, h, size=n_sub),
                                 rng.integers(0, h, size=n_sub)], axis=1)
            a_lab[f], c_lab[f] = pick[:, 0], pick[:, 1]
        sub_id = (seq % n_sub).astype(np.int64)
        return a_lab[flow, sub_id], c_lab[flow, sub_id]

    if gran == "packet":
        if path_valid is None:
            return (rng.integers(0, h, size=n_pkts).astype(np.int32),
                    rng.integers(0, h, size=n_pkts).astype(np.int32))
        # Random among valid paths of the packet's flow.
        a_out = np.empty(n_pkts, dtype=np.int32)
        c_out = np.empty(n_pkts, dtype=np.int32)
        for f in range(n_flows):
            idx = np.flatnonzero(flow == f)
            cand = np.argwhere(path_valid[f])
            if len(cand) == 0:
                cand = np.argwhere(np.ones((h, h), dtype=bool))
            pick = cand[rng.integers(0, len(cand), size=len(idx))]
            a_out[idx], c_out[idx] = pick[:, 0], pick[:, 1]
        return a_out, c_out

    if gran == "dr":
        # HOST DR: per-flow pointer over the lowest-common-layer switches.
        p1 = tree.host_pod(flow_src)
        p2 = tree.host_pod(flow_dst)
        a_out = np.empty(n_pkts, dtype=np.int32)
        c_out = np.zeros(n_pkts, dtype=np.int32)
        for f in range(n_flows):
            idx = np.flatnonzero(flow == f)
            if len(idx) == 0:
                continue
            s = seq[idx]
            if p1[f] != p2[f]:
                # rotate over cores == (a, c) pairs (k^2/4 of them)
                if path_valid is not None:
                    cand = np.argwhere(path_valid[f])
                    if len(cand) == 0:
                        cand = np.argwhere(np.ones((h, h), dtype=bool))
                else:
                    cand = np.argwhere(np.ones((h, h), dtype=bool))
                order = cand[rng.permutation(len(cand))]
                start = rng.integers(0, len(order))
                sel = order[(start + s) % len(order)]
                a_out[idx], c_out[idx] = sel[:, 0], sel[:, 1]
            else:
                if path_valid is not None:
                    cand = np.flatnonzero(path_valid[f][:, 0])
                    if len(cand) == 0:
                        cand = np.arange(h)
                else:
                    cand = np.arange(h)
                order = cand[rng.permutation(len(cand))]
                start = rng.integers(0, len(order))
                a_out[idx] = order[(start + s) % len(order)]
                c_out[idx] = rng.integers(0, h, size=len(idx))
        return a_out, c_out

    raise ValueError(f"scheme {scheme.name} has no host precompute "
                     f"(granularity={gran})")
