"""Closed-form models from the paper's §6 and appendices.

* queue-scaling laws q(m) (Table 3, Theorems 1–3, App. C–E);
* the ND/D/1 bounded-queue model behind HOST DR / OFAN optimality;
* collective completion time lower bounds (§5 metric; App. B for the
  permutation's three-mode data/ACK dynamics);
* optimal packet size (Theorem 5, App. G);
* synchronization (collision) probabilities of App. C.

All times are in seconds unless suffixed ``_slots``.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np


# ---------------------------------------------------------------------------
# Network constants (paper §5 defaults).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class NetParams:
    link_rate_bps: float = 800e9
    link_latency_s: float = 0.5e-6
    payload_B: int = 4096
    header_B: int = 62
    gap_B: int = 20          # 12 B IFG + 8 B preamble/SFD
    ack_B: int = 64
    buffer_B: int = 800_000
    hops_inter_pod: int = 6  # host->edge->agg->core->agg->edge->host links

    @property
    def frame_B(self) -> int:
        return self.payload_B + self.header_B

    @property
    def slot_B(self) -> int:
        """Bytes per data-packet slot including inter-frame gap."""
        return self.frame_B + self.gap_B

    @property
    def slot_s(self) -> float:
        return self.slot_B * 8 / self.link_rate_bps

    @property
    def ack_slot_s(self) -> float:
        return (self.ack_B + self.gap_B) * 8 / self.link_rate_bps

    @property
    def prop_slots(self) -> float:
        return self.link_latency_s / self.slot_s

    @property
    def buffer_pkts(self) -> int:
        return self.buffer_B // self.slot_B

    @property
    def min_rtt_s(self) -> float:
        """Zero-load RTT: data out (6 hops store-and-forward + prop) and ACK
        back (6 hops, ACK-sized serialization + prop)."""
        data = 6 * (self.slot_s + self.link_latency_s)
        ack = 6 * (self.ack_slot_s + self.link_latency_s)
        return data + ack


DEFAULT_NET = NetParams()


# ---------------------------------------------------------------------------
# Queue scaling laws (Table 3).
# ---------------------------------------------------------------------------

def q_linear(m: np.ndarray, slope: float = 1.0) -> np.ndarray:
    """SIMPLE RR / JSQ under collective synchronization: Theta(m).

    The synchronization argument (App. C): sticky flows from different source
    pods that picked the same aggregation index and the same destination edge
    switch collide on one agg->edge downlink; two colliding line-rate flows
    build queue at 1 packet per 2 sent, i.e. q ~ m/2 per collision pair."""
    return slope * np.asarray(m, dtype=float)


def q_sqrt(m: np.ndarray, k: int) -> np.ndarray:
    """Random spraying (HOST PKT / RSQ), Theorem 2 / App. D:
    q(m) ~ sqrt(1 - 1/(k/2)) * sqrt(2 m / pi) (reflected random walk at
    critical load)."""
    m = np.asarray(m, dtype=float)
    return np.sqrt(1.0 - 1.0 / (k / 2)) * np.sqrt(2.0 * m / math.pi)


def q_nd_d_1(n_flows: float, rho: float) -> float:
    """Mean queue of the ND/D/1 model (superposition of N periodic unit-rate
    flows with random phases, load rho<=1): Gaussian/Brownian-bridge
    approximation of the stationary mean (App. E, [55, 74]).

    Bounded for any rho<1 and even at rho==1 stays O(sqrt(N)) *independent of
    message size m* — the paper's Theta(1)-in-m optimality.  We use the
    standard heavy-traffic approximation E[Q] ≈ rho^2 * sqrt(N*pi/8)/ ...;
    for our purposes (a horizontal reference line in Fig. 6-style plots) we
    expose the simple bound below.
    """
    n_flows = float(n_flows)
    if rho >= 1.0:
        # Critically loaded ND/D/1: mean queue ~ sqrt(N pi / 8) (Brownian
        # bridge peak of the arrival-curve deviation).
        return math.sqrt(n_flows * math.pi / 8.0)
    # Sub-critical: geometric-tail approximation.
    sigma2 = n_flows * rho * (1 - rho)
    return rho * sigma2 / (2 * n_flows * (1 - rho)) + rho


def fit_power_law(m: np.ndarray, q: np.ndarray) -> tuple[float, float]:
    """Fit q = c * m^alpha; returns (alpha, c).  Used by tbl3 benchmarks to
    check the Theta(m) / sqrt(m) / Theta(1) clusters."""
    m = np.asarray(m, dtype=float)
    q = np.maximum(np.asarray(q, dtype=float), 1e-9)
    A = np.stack([np.log(m), np.ones_like(m)], axis=1)
    coef, *_ = np.linalg.lstsq(A, np.log(q), rcond=None)
    return float(coef[0]), float(math.exp(coef[1]))


# ---------------------------------------------------------------------------
# CCT lower bounds (§5 + App. B).
# ---------------------------------------------------------------------------

def ata_cct_lower_bound_s(n: int, msg_B_per_dst: int, net: NetParams = DEFAULT_NET,
                          hops: int = 6) -> float:
    """All-to-all lower bound: host transmission time of all data plus the
    pipeline latency of the last packet (§5: 'simple sum of propagation and
    host transmission delays')."""
    pkts_per_dst = math.ceil(msg_B_per_dst / net.payload_B)
    total_slots = pkts_per_dst * (n - 1)
    send_s = total_slots * net.slot_s
    pipe_s = hops * net.link_latency_s + (hops - 1) * net.slot_s
    return send_s + pipe_s


def permutation_cct_lower_bound_s(m: int, net: NetParams = DEFAULT_NET,
                                  hops: int = 6) -> float:
    """Permutation lower bound with symmetric data/ACK dynamics (App. B).

    Each host simultaneously sends m data packets and returns ACKs for the m
    packets it receives; the host uplink must carry both.  Three modes:
      (1) data only until the first data packet arrives (i1 packets sent),
      (2) interleaved data/ACK round-robin,
      (3) ACK drain.
    Completion = time the last ACK is *received* by the sender... the paper
    measures CCT at full-message delivery + ACK; we follow App. B and return
    the time the last ACK arrives back.
    """
    H = hops
    T_d = net.frame_B * 8 / net.link_rate_bps          # data serialization
    T_a = net.ack_B * 8 / net.link_rate_bps
    T_g = net.gap_B * 8 / net.link_rate_bps
    T_dp = T_d + T_g
    T_ap = T_a + T_g
    T_p = H * net.link_latency_s                        # one-way propagation

    # Mode 1: first data packet arrives at t1 after T_p + H serializations.
    t1 = T_p + H * T_d
    i1 = math.ceil((T_p + (H - 1) * T_d) / T_dp) + 1
    if m <= i1:
        # Pure pipeline: last data at t1 + (m-1) T_dp; its ACK returns after
        # the reverse path.
        t_last_data = t1 + (m - 1) * T_dp
        return t_last_data + T_ap + T_p + (H - 1) * T_a
    # Packet i1 arrives at:
    t_i1 = t1 + (i1 - 1) * T_dp
    # First ACK right after:
    t_ack1 = t_i1 + T_ap
    # Mode 2: interleaved; ACK for packet i arrives at
    #   t_ack(i) = t_ack1 + (i-1)(T_dp + T_ap)   while data remains.
    i2 = m - i1 + 1
    t_ack_i2 = t_ack1 + (i2 - 1) * (T_dp + T_ap)
    # Mode 3: ACK-only drain, two constraints (App. B).
    best = t_ack_i2
    for i in range(i2 + 1, m + 1):
        c1 = t_ack_i2 + (i - i2) * T_ap
        # ACK i follows data packet i + (i1 - 1):
        j = i - (i1 - 1)
        t_ack_j = t_ack1 + (j - 1) * (T_dp + T_ap) if j >= 1 else t_ack1
        c2 = t_ack_j + (H - 1) * T_ap + T_p
        best = max(best, c1, c2)
    return best


def cct_increase(cct_s: float, bound_s: float) -> float:
    """The paper's metric: percentage increase over the lower bound."""
    return 100.0 * (cct_s / bound_s - 1.0)


# ---------------------------------------------------------------------------
# Theorem 5: optimal packet size.
# ---------------------------------------------------------------------------

def optimal_payload_B(msg_B: float, header_B: float = 82.0,
                      alpha_pkts: float = 10.0) -> float:
    """P - H = sqrt(H * D / alpha): payload minimizing CCT for a DR scheme
    whose queueing is a constant alpha packets (Thm 5 / App. G).  ``header_B``
    includes the inter-frame gap (the paper uses 82 B)."""
    return math.sqrt(header_B * msg_B / alpha_pkts)


def modeled_cct_slots(msg_B: float, payload_B: float, header_B: float = 82.0,
                      alpha_pkts: float = 10.0) -> float:
    """CCT model (App. G, eq. 29) in units of (P/C): transmission + queueing.
    Returns the P-dependent part  P*(D/(P-H) + alpha)  in *byte-time* units
    (divide by line rate for seconds)."""
    P = payload_B + header_B
    return P * (msg_B / payload_B + alpha_pkts)


def optimal_payload_sqrt_queue_B(msg_B: float, header_B: float = 82.0,
                                 beta: float = 1.0) -> float:
    """For sqrt-queue spraying schemes (q = beta*sqrt(n_pkts)), CCT ∝
    P*(D/(P-H)) + beta*sqrt(D/(P-H))*P; the optimum grows as Theta(D^{1/3})
    (paper §8.1).  Solved numerically."""
    from scipy.optimize import minimize_scalar  # pragma: no cover
    raise NotImplementedError("numeric helper lives in benchmarks")


def cube_root_payload_scaling(msg_B: np.ndarray, header_B: float = 82.0,
                              beta: float = 1.0) -> np.ndarray:
    """Numeric optimum payload for sqrt-queue schemes (no scipy): grid search
    over payloads; used to verify the Theta(D^{1/3}) claim."""
    outs = []
    for D in np.atleast_1d(msg_B):
        best, bestv = None, np.inf
        for payload in np.geomspace(64, 65536, 512):
            P = payload + header_B
            n_pkts = D / payload
            v = P * (n_pkts + beta * math.sqrt(max(n_pkts, 1.0)))
            if v < bestv:
                best, bestv = payload, v
        outs.append(best)
    return np.asarray(outs)


# ---------------------------------------------------------------------------
# App. C synchronization probabilities (SIMPLE RR / JSQ collisions).
# ---------------------------------------------------------------------------

def p_northbound(k: int) -> float:
    """All k/2 flows of an edge switch leave the switch (eq. 8)."""
    n = k ** 3 / 4
    h = k // 2
    p = 1.0
    for i in range(h):
        p *= (n - h - i) / (n - 1 - i)
    return p


def p_hotspot(k: int) -> float:
    """All flows of an edge switch target the same outside edge switch (eq. 9)."""
    n = k ** 3 / 4
    h = k // 2
    p = (n - h) / (n - 1)
    for i in range(1, h):
        p *= (h - i) / (n - 1 - i)
    return p


def p_red(k: int) -> float:
    return p_northbound(k) - p_hotspot(k)


def expected_collisions_rr(k: int) -> float:
    """Expected synchronized (linear-queue) flow pairs for SIMPLE RR (eq. 18/19)."""
    n = k ** 3 / 4
    h = k // 2
    p_same_agg = 1.0 / h
    p_same_dst_edge = (h - 1) / (n - 1 - h)
    p_coll = p_red(k) ** 2 * p_same_agg * p_same_dst_edge
    return n * (n - 1) / 2 * p_coll


def expected_collisions_jsq(k: int, t_ipg_frac: float = 0.0) -> float:
    """Same for JSQ with the App. C 'safe flow' factor (eq. 13, 17)."""
    h = k // 2
    p_safe = (1.0 - 2.0 * t_ipg_frac) ** (h - 1)
    return expected_collisions_rr(k) * p_safe ** 2
