"""Jit'd public wrapper for the Lindley segmented-scan kernel.

``backend``:
  * ``xla``     -- associative_scan oracle (default on CPU: interpret-mode
                   Pallas is orders of magnitude slower than XLA);
  * ``pallas``  -- the TPU kernel (interpret=True on CPU for validation);
  * ``auto``    -- pallas on TPU, xla elsewhere.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import kernel as _kernel
from . import ref as _ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def segmented_cummax(v, flags, backend: str = "auto", block: int = 1024):
    if backend == "auto":
        backend = "pallas" if _on_tpu() else "xla"
    if backend == "xla":
        return _ref.segmented_cummax(v, flags)
    if backend == "pallas":
        return _kernel.segmented_cummax(v, flags, block=block,
                                        interpret=not _on_tpu())
    raise ValueError(backend)


def lindley_departures(arrival_sorted, seg_start, service: float = 1.0,
                       backend: str = "auto"):
    n = arrival_sorted.shape[0]
    idx = jnp.arange(n, dtype=jnp.float32) * service
    m = segmented_cummax(arrival_sorted - idx, seg_start, backend=backend)
    return m + idx + service
