"""Jit'd public wrapper for the Lindley segmented-scan kernel.

``backend``:
  * ``xla``     -- associative_scan oracle (default on CPU: interpret-mode
                   Pallas is orders of magnitude slower than XLA);
  * ``pallas``  -- the TPU kernel (interpret=True on CPU for validation);
  * ``auto``    -- pallas on TPU, xla elsewhere.
"""
from __future__ import annotations

import jax.numpy as jnp

from . import kernel as _kernel
from . import ref as _ref
from .._common import resolve_backend, use_interpret


def segmented_cummax(v, flags, backend: str = "auto", block: int = 1024):
    backend = resolve_backend(backend)
    if backend == "xla":
        return _ref.segmented_cummax(v, flags)
    return _kernel.segmented_cummax(v, flags, block=block,
                                    interpret=use_interpret())


def lindley_departures(arrival_sorted, seg_start, service: float = 1.0,
                       backend: str = "auto"):
    n = arrival_sorted.shape[0]
    idx = jnp.arange(n, dtype=jnp.float32) * service
    m = segmented_cummax(arrival_sorted - idx, seg_start, backend=backend)
    return m + idx + service
