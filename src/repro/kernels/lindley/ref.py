"""Pure-jnp oracle for the segmented max-plus (Lindley) scan.

``segmented_cummax(v, flags)`` returns the running maximum of ``v`` that
resets at every True in ``flags`` (segment starts).  This is the inner loop of
the fast fabric engine: with packets sorted by (queue, arrival), departure
times are ``d_i = i + 1 + segmented_cummax(a - i)`` (Lindley recursion in
max-plus form).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def segmented_cummax(v: jnp.ndarray, flags: jnp.ndarray) -> jnp.ndarray:
    """Oracle via ``jax.lax.associative_scan`` on (value, flag) pairs."""
    v = jnp.asarray(v, jnp.float32)
    flags = jnp.asarray(flags, bool)

    def combine(a, b):
        va, fa = a
        vb, fb = b
        return jnp.where(fb, vb, jnp.maximum(va, vb)), fa | fb

    out, _ = jax.lax.associative_scan(combine, (v, flags))
    return out


def segmented_cummax_serial(v, flags):
    """Sequential reference (used by hypothesis tests as a second oracle)."""
    import numpy as np
    v = np.asarray(v, np.float32)
    flags = np.asarray(flags, bool)
    out = np.empty_like(v)
    cur = -np.inf
    for i in range(len(v)):
        cur = v[i] if flags[i] else max(cur, v[i])
        out[i] = cur
    return out


def lindley_departures(arrival_sorted: jnp.ndarray, seg_start: jnp.ndarray,
                       service: float = 1.0) -> jnp.ndarray:
    """Departure times for FIFO unit-rate queues: packets sorted by
    (queue, arrival); ``seg_start`` marks the first packet of each queue."""
    n = arrival_sorted.shape[0]
    idx = jnp.arange(n, dtype=jnp.float32) * service
    m = segmented_cummax(arrival_sorted - idx, seg_start)
    return m + idx + service
