"""Pallas TPU kernel: segmented max-plus (Lindley) scan.

The fast fabric engine's hot spot: a segmented running maximum over packets
sorted by (queue, arrival).  TPU mapping:

  * the packet stream is tiled into VMEM blocks of ``block`` elements
    (a multiple of 128 for lane alignment);
  * the TPU grid executes sequentially, so a single SMEM scalar carries the
    running maximum of the open segment across blocks;
  * within a block the segmented scan is a Hillis–Steele doubling scan
    (log2(block) vector steps on the VPU) over (value, flag) pairs --
    identical algebra to the associative_scan oracle in ``ref.py``.

Flags are passed as int32 (bool VMEM blocks are awkward on TPU); any nonzero
means "segment start".
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .._common import NEG


def _scan_block(v, f):
    """In-block segmented cummax via doubling; v (B,), f (B,) bool."""
    B = v.shape[0]
    shift = 1
    while shift < B:
        vp = jnp.concatenate([jnp.full((shift,), NEG), v[:-shift]])
        fp = jnp.concatenate([jnp.zeros((shift,), bool), f[:-shift]])
        v = jnp.where(f, v, jnp.maximum(v, vp))
        f = f | fp
        shift *= 2
    return v, f


def _kernel(v_ref, f_ref, o_ref, carry_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        carry_ref[0] = NEG

    v = v_ref[...]
    f = f_ref[...] != 0
    sv, sf = _scan_block(v, f)
    # positions with no flag anywhere before them in this block continue the
    # previous block's open segment:
    carry = carry_ref[0]
    out = jnp.where(sf, sv, jnp.maximum(sv, carry))
    o_ref[...] = out
    carry_ref[0] = out[-1]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def segmented_cummax(v: jnp.ndarray, flags: jnp.ndarray, *,
                     block: int = 1024, interpret: bool = True) -> jnp.ndarray:
    """Segmented running max of ``v`` resetting where ``flags`` is set.

    Pads to a block multiple (padding opens a fresh segment so it never
    contaminates real data).  ``interpret=True`` runs the kernel body in
    Python on CPU (this container); on TPU pass interpret=False.
    """
    n = v.shape[0]
    v = jnp.asarray(v, jnp.float32)
    f = jnp.asarray(flags).astype(jnp.int32)
    npad = (-n) % block
    if npad:
        v = jnp.concatenate([v, jnp.full((npad,), NEG)])
        f = jnp.concatenate([f, jnp.ones((npad,), jnp.int32)])
    total = v.shape[0]

    out = pl.pallas_call(
        _kernel,
        grid=(total // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((total,), jnp.float32),
        scratch_shapes=[pltpu.SMEM((1,), jnp.float32)],
        interpret=interpret,
    )(v, f)
    return out[:n]
