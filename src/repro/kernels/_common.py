"""Shared helpers for the Pallas kernel packages.

Every kernel package (``lindley``, ``ssd_scan``, ``slot_step``, ...) ships
the same three-file idiom: ``kernel.py`` (the Pallas TPU kernel),
``ref.py`` (a pure-jnp oracle) and ``ops.py`` (a public wrapper with a
``backend`` switch).  The backend-detection logic and the captured-const
conventions they all need live here instead of being copy-pasted.

``REPRO_PALLAS=interpret`` (environment) forces ``auto`` to resolve to the
Pallas kernels in interpret mode even off-TPU -- CI uses this to smoke the
kernel paths on the CPU runners, where ``auto`` would otherwise pick the
XLA oracle (interpret-mode Pallas is orders of magnitude slower than XLA,
so it is never the default on CPU).
"""
from __future__ import annotations

import os

import jax

# Large-negative sentinel for max-scans inside kernel bodies.  A python
# float on purpose: jnp scalars would become captured consts in pallas.
NEG = -3.0e38


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def interpret_forced() -> bool:
    """True when ``REPRO_PALLAS=interpret`` asks for interpret-mode Pallas
    off-TPU (CI kernel smoke; never set in production runs)."""
    return os.environ.get("REPRO_PALLAS", "") == "interpret"


def resolve_backend(backend: str, *, fallback: str = "xla",
                    choices: tuple = ("auto", "xla", "pallas")) -> str:
    """Resolve an ``{auto, xla, pallas}``-style backend switch.

    ``auto`` picks ``"pallas"`` on TPU (or under ``REPRO_PALLAS=interpret``)
    and ``fallback`` elsewhere; explicit values pass through after
    validation.
    """
    if backend not in choices:
        raise ValueError(f"backend {backend!r}: expected one of {choices}")
    if backend == "auto":
        return "pallas" if (_on_tpu() or interpret_forced()) else fallback
    return backend


def use_interpret() -> bool:
    """Interpret flag for a resolved ``"pallas"`` backend: compile for real
    on TPU, interpret everywhere else (the bitwise CPU validation path)."""
    return not _on_tpu()
