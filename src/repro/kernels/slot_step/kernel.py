"""Pallas kernels for the slotted engine's per-slot body.

Four fused ops (see ``ref.py`` for the oracle semantics):

  * :func:`jsq_pick` -- queue-occupancy gather + in-kernel Threefry
    tie-break noise (:mod:`repro.core.entropy` is written against the
    numpy/jnp-shared operator set, so the PRF evaluates inside the kernel
    body) + quantization + pad/dead penalties + masked argmin.  Tiled over
    choosers (``block``); the occupancy vector rides whole in VMEM.
  * :func:`enqueue` / :func:`agg_jsq_enqueue` -- the arrival enqueue
    update (same-queue ranking, capacity drops, ring-buffer scatter,
    occupancy add, ECN marks), optionally fused with the agg-layer JSQ
    pick so the pick and the occupancy it feeds stay in one VMEM-resident
    pass.  Single-program kernels: the ranking couples all lanes.
  * :func:`sack_update_scan` / :func:`sack_advance` -- receiver-bitmap
    scatter + per-flow first-missing window argmin, and the unrolled
    cumulative-ack advance rounds.

Under ``vmap`` (the engine's seed/mega batch axes) the fused campaign axis
becomes the leading kernel grid dimension via the ``pallas_call`` batching
rule -- one launch covers the megabatch.

TPU-safe formulations throughout: 2D ``broadcasted_iota`` (1D iota does
not lower), argmin as min-of-iota-where-min (bitwise-equal to
``jnp.argmin`` first-occurrence semantics), same-slot arrival ranking as
an O(M^2) masked count (``rank_by``'s stable sort has no Mosaic lowering),
window ``cumprod`` unrolled to running products.  Booleans cross the
kernel boundary as int32 (bool VMEM blocks are awkward on TPU).  The
ring-buffer scatter uses ``.at[].set(mode="drop")``, which interpret mode
executes exactly; on a real TPU backend it relies on Mosaic's (limited)
scatter support -- the CPU-validated interpret path is the one tests pin.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ...core import entropy as ent


def _iota2(shape, dim):
    return jax.lax.broadcasted_iota(jnp.int32, shape, dim)


def _first_min_index(x, width):
    """Index of the first minimum along axis 1: bitwise-equal to
    ``jnp.argmin(x, axis=1)`` (min-reduction formulation lowers on TPU)."""
    m = jnp.min(x, axis=1, keepdims=True)
    return jnp.min(jnp.where(x == m, _iota2(x.shape, 1), width), axis=1)


def _pick_body(qcnt, qbase, ids, dead, pen, s_lo, s_hi, t, *,
               site, quanta, cap):
    """Score grid + masked argmin for one block of choosers (mirrors
    ``ref.jsq_score``/``ref.jsq_pick`` op for op)."""
    h = pen.shape[0]
    lane = _iota2((1, h), 1)
    lens = qcnt[qbase[:, None] + lane]
    nz = ent.draw_uniform(s_lo, s_hi, site, ids[:, None], t, lane=lane)
    if quanta is None:
        score = lens.astype(jnp.float32) + nz * 1e-3
    else:
        # Host-side f32 thresholds: identical rounding to the engine's
        # ``jnp.asarray(quanta, f32) * CAP``.
        thr = np.asarray(quanta, np.float32) * np.float32(cap)
        lf = lens.astype(jnp.float32)
        bins = jnp.zeros(lens.shape, jnp.int32)
        for v in thr:
            bins = bins + (lf > jnp.float32(v)).astype(jnp.int32)
        score = bins.astype(jnp.float32) + nz * 0.5
    score = score + pen[None, :]
    score = score + jnp.where(dead, jnp.float32(1e9), jnp.float32(0.0))
    return _first_min_index(score, h).astype(jnp.int32)


def _enqueue_body(qbuf, qhead, qcnt, alive, apk, aq, avalid, *,
                  cap, ecn_thresh):
    """Mirrors ``ref.enqueue`` with the rank as an O(M^2) masked count:
    ``rkq[i] = #{j < i : valid[j] and aq[j] == aq[i]}`` -- the stable-sort
    rank of ``rank_by`` without the sort."""
    nq = qcnt.shape[0]
    M = aq.shape[0]
    aqc = jnp.clip(aq, 0, nq - 1)
    dead = alive[aqc] == 0
    enq_try = avalid & ~dead
    earlier = ((aq[:, None] == aq[None, :]) & enq_try[None, :]
               & (_iota2((M, M), 1) < _iota2((M, M), 0)))
    rkq = jnp.where(enq_try,
                    jnp.sum(earlier.astype(jnp.int32), axis=1), 0)
    room = qcnt[aqc] + rkq < cap
    do_enq = enq_try & room
    pos = (qhead[aqc] + qcnt[aqc] + rkq) % cap
    qbuf2 = qbuf.at[jnp.where(do_enq, aq, nq),
                    jnp.where(do_enq, pos, 0)].set(
        jnp.where(do_enq, apk, -1), mode="drop")
    occ_after = qcnt[aqc] + rkq + 1
    marked = do_enq & (occ_after > ecn_thresh)
    qcnt2 = qcnt.at[jnp.where(do_enq, aq, nq)].add(1, mode="drop")
    return qbuf2, qcnt2, enq_try, do_enq, occ_after, marked


def _s1(x, dtype):
    """Scalar operand as a (1,)-shaped array (0-d operands don't batch
    cleanly through the pallas_call vmap rule)."""
    return jnp.asarray(x, dtype).reshape(1)


# ---------------------------------------------------------------------------
# jsq_pick: tiled over choosers
# ---------------------------------------------------------------------------

def _jsq_pick_kernel(qcnt_ref, qbase_ref, ids_ref, dead_ref, pen_ref,
                     slo_ref, shi_ref, t_ref, o_ref, *, site, quanta, cap):
    o_ref[...] = _pick_body(
        qcnt_ref[...], qbase_ref[...], ids_ref[...], dead_ref[...] != 0,
        pen_ref[...], slo_ref[0], shi_ref[0], t_ref[0],
        site=site, quanta=quanta, cap=cap)


@functools.partial(jax.jit, static_argnames=("site", "quanta", "cap",
                                             "block", "interpret"))
def jsq_pick(qcnt, qbase, ids, dead, pad_pen, seed_lo, seed_hi, t, *,
             site, quanta, cap, block=None, interpret=False):
    """Fused JSQ port pick; see ``ref.jsq_pick``.  ``block`` tiles the
    chooser axis (default: one program for the whole row); non-divisible
    tails are padded with inert choosers and sliced off."""
    M = qbase.shape[0]
    NQ = qcnt.shape[0]
    h = pad_pen.shape[0]
    block = M if block is None else min(int(block), M)
    npad = (-M) % block
    if npad:
        qbase = jnp.concatenate([qbase, jnp.zeros((npad,), qbase.dtype)])
        ids = jnp.concatenate([ids, jnp.zeros((npad,), ids.dtype)])
        dead = jnp.concatenate([dead, jnp.zeros((npad, h), bool)])
    out = pl.pallas_call(
        functools.partial(_jsq_pick_kernel, site=site, quanta=quanta,
                          cap=cap),
        grid=((M + npad) // block,),
        in_specs=[
            pl.BlockSpec((NQ,), lambda i: (0,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block, h), lambda i: (i, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((M + npad,), jnp.int32),
        interpret=interpret,
    )(qcnt, qbase, ids, dead.astype(jnp.int32), pad_pen,
      _s1(seed_lo, jnp.uint32), _s1(seed_hi, jnp.uint32), _s1(t, jnp.int32))
    return out[:M]


# ---------------------------------------------------------------------------
# enqueue / agg_jsq_enqueue: single-program (ranking couples all lanes)
# ---------------------------------------------------------------------------

def _store_enqueue_outs(outs, o_qbuf, o_qcnt, o_enq_try, o_do_enq, o_occ,
                        o_marked):
    qbuf2, qcnt2, enq_try, do_enq, occ_after, marked = outs
    o_qbuf[...] = qbuf2
    o_qcnt[...] = qcnt2
    o_enq_try[...] = enq_try.astype(jnp.int32)
    o_do_enq[...] = do_enq.astype(jnp.int32)
    o_occ[...] = occ_after
    o_marked[...] = marked.astype(jnp.int32)


def _enqueue_kernel(qbuf_ref, qhead_ref, qcnt_ref, alive_ref, apk_ref,
                    aq_ref, avalid_ref, o_qbuf, o_qcnt, o_enq_try, o_do_enq,
                    o_occ, o_marked, *, cap, ecn_thresh):
    _store_enqueue_outs(
        _enqueue_body(qbuf_ref[...], qhead_ref[...], qcnt_ref[...],
                      alive_ref[...], apk_ref[...], aq_ref[...],
                      avalid_ref[...] != 0, cap=cap, ecn_thresh=ecn_thresh),
        o_qbuf, o_qcnt, o_enq_try, o_do_enq, o_occ, o_marked)


def _enqueue_out_shapes(nq, cap, m):
    return (jax.ShapeDtypeStruct((nq, cap), jnp.int32),
            jax.ShapeDtypeStruct((nq,), jnp.int32),
            jax.ShapeDtypeStruct((m,), jnp.int32),
            jax.ShapeDtypeStruct((m,), jnp.int32),
            jax.ShapeDtypeStruct((m,), jnp.int32),
            jax.ShapeDtypeStruct((m,), jnp.int32))


def _unpack_enqueue_outs(outs):
    qbuf2, qcnt2, enq_try, do_enq, occ_after, marked = outs
    return (qbuf2, qcnt2, enq_try != 0, do_enq != 0, occ_after, marked != 0)


@functools.partial(jax.jit, static_argnames=("cap", "ecn_thresh",
                                             "interpret"))
def enqueue(qbuf, qhead, qcnt, alive_row, apk, aq, avalid, *,
            cap, ecn_thresh, interpret=False):
    """Fused arrival enqueue; see ``ref.enqueue``."""
    outs = pl.pallas_call(
        functools.partial(_enqueue_kernel, cap=cap, ecn_thresh=ecn_thresh),
        out_shape=_enqueue_out_shapes(qcnt.shape[0], cap, aq.shape[0]),
        interpret=interpret,
    )(qbuf, qhead, qcnt, alive_row.astype(jnp.int32), apk, aq,
      avalid.astype(jnp.int32))
    return _unpack_enqueue_outs(outs)


def _agg_jsq_enqueue_kernel(qbuf_ref, qhead_ref, qcnt_ref, alive_ref,
                            apk_ref, aq_ref, to_agg_ref, asw_ref, dead_ref,
                            pen_ref, slo_ref, shi_ref, t_ref,
                            o_qbuf, o_qcnt, o_cfin, o_enq_try, o_do_enq,
                            o_occ, o_marked, *,
                            site, quanta, cap, ecn_thresh, off1, h):
    qcnt = qcnt_ref[...]
    apk = apk_ref[...]
    asw = asw_ref[...]
    c_fin = _pick_body(qcnt, off1 + asw * h, jnp.maximum(apk, 0),
                       dead_ref[...] != 0, pen_ref[...],
                       slo_ref[0], shi_ref[0], t_ref[0],
                       site=site, quanta=quanta, cap=cap)
    aq2 = jnp.where(to_agg_ref[...] != 0, off1 + asw * h + c_fin,
                    aq_ref[...])
    o_cfin[...] = c_fin
    _store_enqueue_outs(
        _enqueue_body(qbuf_ref[...], qhead_ref[...], qcnt, alive_ref[...],
                      apk, aq2, apk >= 0, cap=cap, ecn_thresh=ecn_thresh),
        o_qbuf, o_qcnt, o_enq_try, o_do_enq, o_occ, o_marked)


@functools.partial(jax.jit, static_argnames=("site", "quanta", "cap",
                                             "ecn_thresh", "off1", "h",
                                             "interpret"))
def agg_jsq_enqueue(qbuf, qhead, qcnt, alive_row, apk, aq, to_agg, asw,
                    dead, pad_pen, seed_lo, seed_hi, t, *,
                    site, quanta, cap, ecn_thresh, off1, h,
                    interpret=False):
    """Fused agg-layer JSQ pick + enqueue; see ``ref.agg_jsq_enqueue``."""
    nq, m = qcnt.shape[0], aq.shape[0]
    shapes = _enqueue_out_shapes(nq, cap, m)
    outs = pl.pallas_call(
        functools.partial(_agg_jsq_enqueue_kernel, site=site, quanta=quanta,
                          cap=cap, ecn_thresh=ecn_thresh, off1=off1, h=h),
        out_shape=shapes[:2] + (jax.ShapeDtypeStruct((m,), jnp.int32),)
        + shapes[2:],
        interpret=interpret,
    )(qbuf, qhead, qcnt, alive_row.astype(jnp.int32), apk, aq,
      to_agg.astype(jnp.int32), asw, dead.astype(jnp.int32), pad_pen,
      _s1(seed_lo, jnp.uint32), _s1(seed_hi, jnp.uint32), _s1(t, jnp.int32))
    up = _unpack_enqueue_outs(outs[:2] + outs[3:])
    return up[:2] + (outs[2],) + up[2:]


# ---------------------------------------------------------------------------
# SACK scoreboard
# ---------------------------------------------------------------------------

def _sack_update_scan_kernel(prec_ref, pk_ref, deliv_ref, cum_ref, fsz_ref,
                             pbase_ref, o_prec, o_fm, *, window):
    prec = prec_ref[...]
    P = prec.shape[0]
    deliv = deliv_ref[...] != 0
    prec2 = prec.at[jnp.where(deliv, pk_ref[...], P)].set(1, mode="drop")
    cum = cum_ref[...]
    fsz = fsz_ref[...]
    offs = _iota2((1, window), 1)
    cand = jnp.minimum(cum[:, None] + offs, fsz[:, None] - 1)
    got = prec2[pbase_ref[...][:, None] + cand]
    idx = _first_min_index(got, window)
    o_prec[...] = prec2
    o_fm[...] = jnp.take_along_axis(cand, idx[:, None], axis=1)[:, 0]


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def sack_update_scan(p_recv, pk, deliv, f_cum, fsize, pbase, *,
                     window=64, interpret=False):
    """Fused bitmap update + per-flow first-missing scan; see
    ``ref.sack_update_scan``."""
    F = f_cum.shape[0]
    prec2, fm = pl.pallas_call(
        functools.partial(_sack_update_scan_kernel, window=window),
        out_shape=(jax.ShapeDtypeStruct(p_recv.shape, jnp.int32),
                   jax.ShapeDtypeStruct((F,), jnp.int32)),
        interpret=interpret,
    )(p_recv.astype(jnp.int32), pk, deliv.astype(jnp.int32),
      f_cum, fsize, pbase)
    return prec2 != 0, fm


def _sack_advance_kernel(prec_ref, cum_ref, fsz_ref, pbase_ref, o_cum, *,
                         rounds, window):
    prec = prec_ref[...]
    cum = cum_ref[...]
    fsz = fsz_ref[...]
    pbase = pbase_ref[...]
    offs = _iota2((1, window), 1)
    for _ in range(rounds):
        cand = jnp.minimum(cum[:, None] + offs, fsz[:, None] - 1)
        got = ((prec[pbase[:, None] + cand] != 0)
               & (cum[:, None] + offs < fsz[:, None])).astype(jnp.int32)
        # sum(cumprod(got)) with the window product unrolled (integer
        # arithmetic: identical to the oracle's cumprod formulation).
        run = jnp.ones(cum.shape, jnp.int32)
        adv = jnp.zeros(cum.shape, jnp.int32)
        for w in range(window):
            run = run * got[:, w]
            adv = adv + run
        cum = jnp.minimum(cum + adv, fsz)
    o_cum[...] = cum


@functools.partial(jax.jit, static_argnames=("rounds", "window",
                                             "interpret"))
def sack_advance(p_recv, f_cum, fsize, pbase, *, rounds=2, window=4,
                 interpret=False):
    """Fused cumulative-ack advance rounds; see ``ref.sack_advance``."""
    return pl.pallas_call(
        functools.partial(_sack_advance_kernel, rounds=rounds,
                          window=window),
        out_shape=jax.ShapeDtypeStruct(f_cum.shape, jnp.int32),
        interpret=interpret,
    )(p_recv.astype(jnp.int32), f_cum, fsize, pbase)
