"""Pure-jnp oracles for the slot-step kernels.

Each function mirrors the corresponding inline lax block of
``repro.net.loopsim._engine`` *operation for operation* (same ops, same
order -- f32 additions included), so `ref == inline lax` holds bitwise and
the interpret-mode Pallas kernels in ``kernel.py`` are tested against these
as ground truth.  All oracles are single-row; callers ``vmap`` the fused
campaign axis over them.
"""
from __future__ import annotations

import jax.numpy as jnp

from ...core import entropy as ent
from ...net._batching import rank_by


def jsq_score(qcnt, qbase, ids, dead, pad_pen, seed_lo, seed_hi, t, *,
              site, quanta, cap):
    """The (M, h) JSQ score grid: occupancy gather + counter-stream
    tie-break noise + quantization + pad/dead penalties.

    ``qcnt`` (NQ,) int32 queue occupancy; ``qbase`` (M,) int32 first-port
    queue id per chooser; ``ids`` (M,) int32 entropy lane ids (host ids at
    the edge, packet ids at the agg); ``dead`` (M, h) bool pre-gathered
    failed-port mask (already gated on convergence); ``pad_pen`` (h,) f32
    ``port_pad_penalty``.  ``quanta`` is the static quantization tuple (or
    None for plain JSQ); ``cap`` the buffer capacity scaling it.
    """
    h = pad_pen.shape[0]
    lens = qcnt[qbase[:, None] + jnp.arange(h)[None, :]]
    nz = ent.draw_uniform(seed_lo, seed_hi, site, ids[:, None], t,
                          lane=jnp.arange(h)[None, :])
    if quanta is None:
        score = lens.astype(jnp.float32) + nz * 1e-3
    else:
        thr = jnp.asarray(quanta, jnp.float32) * cap
        bins = jnp.sum(lens[:, :, None] > thr[None, None, :], axis=2)
        score = bins.astype(jnp.float32) + nz * 0.5
    score = score + pad_pen[None, :]
    score = score + jnp.where(dead, 1e9, 0.0)
    return score


def jsq_pick(qcnt, qbase, ids, dead, pad_pen, seed_lo, seed_hi, t, *,
             site, quanta, cap):
    """Masked-argmin port pick per chooser: (M,) int32."""
    score = jsq_score(qcnt, qbase, ids, dead, pad_pen, seed_lo, seed_hi, t,
                      site=site, quanta=quanta, cap=cap)
    return jnp.argmin(score, axis=1).astype(jnp.int32)


def enqueue(qbuf, qhead, qcnt, alive_row, apk, aq, avalid, *,
            cap, ecn_thresh):
    """Fused same-slot arrival enqueue: failure black-holing, same-queue
    arrival ranking, capacity drop, ring-buffer scatter, occupancy add and
    ECN marking -- the engine's step-8 block.

    ``qbuf`` (NQ, cap) int32 ring buffers; ``qhead``/``qcnt`` (NQ,) int32;
    ``alive_row`` (NQ,) bool (current physical epoch); ``apk``/``aq``
    (M,) int32 arriving packet / target queue per lane; ``avalid`` (M,)
    bool.  Returns ``(qbuf', qcnt', enq_try, do_enq, occ_after, marked)``;
    drop counts derive outside as ``avalid & ~enq_try`` (black-holed) and
    ``enq_try & ~do_enq`` (buffer full).
    """
    nq = qcnt.shape[0]
    aqc = jnp.clip(aq, 0, nq - 1)
    dead = ~alive_row[aqc]
    enq_try = avalid & ~dead
    rkq = rank_by(aq, enq_try)
    room = qcnt[aqc] + rkq < cap
    do_enq = enq_try & room
    pos = (qhead[aqc] + qcnt[aqc] + rkq) % cap
    qbuf2 = qbuf.at[jnp.where(do_enq, aq, nq),
                    jnp.where(do_enq, pos, 0)].set(
        jnp.where(do_enq, apk, -1), mode="drop")
    occ_after = qcnt[aqc] + rkq + 1
    marked = do_enq & (occ_after > ecn_thresh)
    qcnt2 = qcnt.at[jnp.where(do_enq, aq, nq)].add(1, mode="drop")
    return qbuf2, qcnt2, enq_try, do_enq, occ_after, marked


def agg_jsq_enqueue(qbuf, qhead, qcnt, alive_row, apk, aq, to_agg, asw,
                    dead, pad_pen, seed_lo, seed_hi, t, *,
                    site, quanta, cap, ecn_thresh, off1, h):
    """Fused agg-layer JSQ pick + enqueue (engine steps 7(jsq) + 8): score
    the agg uplink queues per arriving packet, argmin, rewrite the target
    queue of agg-bound lanes, then run the full enqueue update -- one pass
    over the occupancy state.  Returns ``(qbuf', qcnt', c_fin, enq_try,
    do_enq, occ_after, marked)``.
    """
    apkc = jnp.maximum(apk, 0)
    c_fin = jsq_pick(qcnt, off1 + asw * h, apkc, dead, pad_pen,
                     seed_lo, seed_hi, t, site=site, quanta=quanta, cap=cap)
    aq2 = jnp.where(to_agg, off1 + asw * h + c_fin, aq)
    out = enqueue(qbuf, qhead, qcnt, alive_row, apk, aq2, avalid=apk >= 0,
                  cap=cap, ecn_thresh=ecn_thresh)
    return out[:2] + (c_fin,) + out[2:]


def sack_update_scan(p_recv, pk, deliv, f_cum, fsize, pbase, *, window=64):
    """Fused receiver-bitmap update + per-flow first-missing-sequence scan
    (the SACK retransmit candidate): engine step 3's ``p_recv`` scatter and
    step 5's 64-wide window argmin, evaluated per *flow* (the inline code
    evaluates it per send lane; gathering ``fm[flow]`` afterwards is
    bitwise-identical since every lane's window is its flow's window).

    ``p_recv`` (P,) bool; ``pk``/``deliv`` (M,) this slot's popped packets
    and delivery mask; ``f_cum``/``fsize``/``pbase`` (F,) int32.  Returns
    ``(p_recv', first_missing (F,) int32)``.
    """
    P = p_recv.shape[0]
    F = f_cum.shape[0]
    p_recv2 = p_recv.at[jnp.where(deliv, pk, P)].set(True, mode="drop")
    offs = jnp.arange(window)[None, :]
    cand = jnp.minimum(f_cum[:, None] + offs, fsize[:, None] - 1)
    got = p_recv2[pbase[:, None] + cand]
    fm = cand[jnp.arange(F), jnp.argmin(got, axis=1)]
    return p_recv2, fm


def sack_advance(p_recv, f_cum, fsize, pbase, *, rounds=2, window=4):
    """Cumulative-ack advance: ``rounds`` unrolled passes of the engine's
    step-9 window scan (each advances ``f_cum`` past up to ``window``
    contiguously received sequences) fused into one call."""
    for _ in range(rounds):
        offs = jnp.arange(window)[None, :]
        cand = jnp.minimum(f_cum[:, None] + offs, fsize[:, None] - 1)
        got = p_recv[pbase[:, None] + cand] & (
            f_cum[:, None] + offs < fsize[:, None])
        adv = jnp.sum(jnp.cumprod(got, axis=1), axis=1).astype(jnp.int32)
        f_cum = jnp.minimum(f_cum + adv, fsize)
    return f_cum
