"""Public wrappers for the slot-step kernels.

``backend``:
  * ``xla``     -- the pure-jnp oracle (``ref.py``), bitwise-identical to
                   the inline lax engine code (default off-TPU: interpret-
                   mode Pallas is orders of magnitude slower than XLA);
  * ``pallas``  -- the TPU kernels (interpret=True off-TPU for validation);
  * ``auto``    -- pallas on TPU (or under ``REPRO_PALLAS=interpret``),
                   xla elsewhere.

:func:`resolve_impl` maps the engine-level ``LoopConfig.impl`` switch
(``lax``/``pallas``/``auto``) onto this: ``auto`` runs the kernels only
where they win (TPU) or where CI forces them (``REPRO_PALLAS=interpret``),
falling back to the inline lax code path otherwise.
"""
from __future__ import annotations

from . import kernel as _kernel
from . import ref as _ref
from .._common import resolve_backend, use_interpret, interpret_forced, \
    _on_tpu

LOOP_IMPLS = ("lax", "pallas", "auto")


def resolve_impl(impl: str) -> str:
    """Resolve ``LoopConfig.impl`` to the concrete engine path
    (``"lax"`` or ``"pallas"``)."""
    if impl not in LOOP_IMPLS:
        raise ValueError(f"LoopConfig.impl {impl!r}: expected one of "
                         f"{LOOP_IMPLS}")
    if impl == "auto":
        return "pallas" if (_on_tpu() or interpret_forced()) else "lax"
    return impl


def jsq_pick(qcnt, qbase, ids, dead, pad_pen, seed_lo, seed_hi, t, *,
             site, quanta, cap, backend="auto", block=None):
    backend = resolve_backend(backend)
    if backend == "xla":
        return _ref.jsq_pick(qcnt, qbase, ids, dead, pad_pen,
                             seed_lo, seed_hi, t,
                             site=site, quanta=quanta, cap=cap)
    return _kernel.jsq_pick(qcnt, qbase, ids, dead, pad_pen,
                            seed_lo, seed_hi, t,
                            site=site, quanta=quanta, cap=cap, block=block,
                            interpret=use_interpret())


def enqueue(qbuf, qhead, qcnt, alive_row, apk, aq, avalid, *,
            cap, ecn_thresh, backend="auto"):
    backend = resolve_backend(backend)
    if backend == "xla":
        return _ref.enqueue(qbuf, qhead, qcnt, alive_row, apk, aq, avalid,
                            cap=cap, ecn_thresh=ecn_thresh)
    return _kernel.enqueue(qbuf, qhead, qcnt, alive_row, apk, aq, avalid,
                           cap=cap, ecn_thresh=ecn_thresh,
                           interpret=use_interpret())


def agg_jsq_enqueue(qbuf, qhead, qcnt, alive_row, apk, aq, to_agg, asw,
                    dead, pad_pen, seed_lo, seed_hi, t, *,
                    site, quanta, cap, ecn_thresh, off1, h, backend="auto"):
    backend = resolve_backend(backend)
    if backend == "xla":
        return _ref.agg_jsq_enqueue(
            qbuf, qhead, qcnt, alive_row, apk, aq, to_agg, asw, dead,
            pad_pen, seed_lo, seed_hi, t, site=site, quanta=quanta,
            cap=cap, ecn_thresh=ecn_thresh, off1=off1, h=h)
    return _kernel.agg_jsq_enqueue(
        qbuf, qhead, qcnt, alive_row, apk, aq, to_agg, asw, dead,
        pad_pen, seed_lo, seed_hi, t, site=site, quanta=quanta,
        cap=cap, ecn_thresh=ecn_thresh, off1=off1, h=h,
        interpret=use_interpret())


def sack_update_scan(p_recv, pk, deliv, f_cum, fsize, pbase, *,
                     window=64, backend="auto"):
    backend = resolve_backend(backend)
    if backend == "xla":
        return _ref.sack_update_scan(p_recv, pk, deliv, f_cum, fsize,
                                     pbase, window=window)
    return _kernel.sack_update_scan(p_recv, pk, deliv, f_cum, fsize, pbase,
                                    window=window, interpret=use_interpret())


def sack_advance(p_recv, f_cum, fsize, pbase, *, rounds=2, window=4,
                 backend="auto"):
    backend = resolve_backend(backend)
    if backend == "xla":
        return _ref.sack_advance(p_recv, f_cum, fsize, pbase,
                                 rounds=rounds, window=window)
    return _kernel.sack_advance(p_recv, f_cum, fsize, pbase, rounds=rounds,
                                window=window, interpret=use_interpret())
