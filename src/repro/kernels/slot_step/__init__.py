"""Pallas kernels for the slotted feedback engine's per-slot body.

Four fused ops replacing the scatter/gather-heavy sections of
``repro.net.loopsim._engine`` (JSQ port-rank + queue-occupancy update, SACK
scoreboard scans), each with a pure-jnp oracle (``ref.py``) that is
bitwise-identical to the inline lax engine code and a Pallas kernel
(``kernel.py``) validated against it in interpret mode.  Use via
``ops`` (backend switch) or through ``LoopConfig(impl="pallas")``.
"""
