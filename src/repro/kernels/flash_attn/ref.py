"""Pure-jnp oracle for causal GQA flash attention (+ a memory-bounded
chunked variant -- 'flash in XLA' -- used for long sequences on the CPU/XLA
backend; peak memory O(S * block) instead of O(S^2))."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mha(q, k, v, *, causal: bool = True, scale: float | None = None,
        logit_soft_cap: float | None = None):
    """Reference attention.

    q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D) with Hq % Hkv == 0 (GQA).
    Returns (B, Hq, Sq, D) in q's dtype; math in float32.
    """
    B, Hq, Sq, D = q.shape
    Hkv = k.shape[1]
    assert Hq % Hkv == 0, (Hq, Hkv)
    group = Hq // Hkv
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    qf = q.astype(jnp.float32)
    kf = jnp.repeat(k.astype(jnp.float32), group, axis=1)
    vf = jnp.repeat(v.astype(jnp.float32), group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", qf, kf) * scale
    if logit_soft_cap is not None:
        logits = logit_soft_cap * jnp.tanh(logits / logit_soft_cap)
    if causal:
        Sk = k.shape[2]
        # queries are the last Sq positions of the Sk context
        qpos = jnp.arange(Sq)[:, None] + (Sk - Sq)
        kpos = jnp.arange(Sk)[None, :]
        mask = kpos <= qpos
        logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vf)
    return out.astype(q.dtype)


def mha_chunked(q, k, v, *, causal: bool = True, scale: float | None = None,
                block_k: int = 512):
    """Online-softmax attention scanning kv blocks: O(Sq*block) memory.

    Same semantics as ``mha``; supports Dv != Dk.  This is the XLA-level
    equivalent of the Pallas kernel, used on non-TPU backends for long
    sequences and by MLA (d_k=192, d_v=128)."""
    B, Hq, Sq, Dk = q.shape
    _, Hkv, Sk, _ = k.shape
    Dv = v.shape[-1]
    group = Hq // Hkv
    if scale is None:
        scale = 1.0 / (Dk ** 0.5)
    if Sk % block_k:
        pad = (-Sk) % block_k
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        # padded keys masked below via positions
    Skp = k.shape[2]
    nb = Skp // block_k
    qf = q.astype(jnp.float32) * scale
    qg = qf.reshape(B, Hkv, group, Sq, Dk)
    kb = k.astype(jnp.float32).reshape(B, Hkv, nb, block_k, Dk)
    vb = v.astype(jnp.float32).reshape(B, Hkv, nb, block_k, Dv)
    qpos = jnp.arange(Sq) + (Sk - Sq)

    def body(carry, inp):
        m, l, acc = carry
        kj, vj, j = inp
        s = jnp.einsum("bkgqd,bktd->bkgqt", qg, kj)
        kpos = j * block_k + jnp.arange(block_k)
        ok = kpos[None, :] < Sk
        if causal:
            ok = ok & (kpos[None, :] <= qpos[:, None])
        s = jnp.where(ok[None, None, None], s, -1e30)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bkgqt,bktd->bkgqd", p, vj)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Hkv, group, Sq), -1e30)
    l0 = jnp.zeros((B, Hkv, group, Sq))
    a0 = jnp.zeros((B, Hkv, group, Sq, Dv))
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (jnp.moveaxis(kb, 2, 0), jnp.moveaxis(vb, 2, 0),
         jnp.arange(nb)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, Hq, Sq, Dv).astype(q.dtype)
