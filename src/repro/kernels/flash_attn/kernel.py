"""Pallas TPU kernel: causal GQA flash attention (forward).

Design (TPU-native, not a CUDA port):
  * grid = (batch, q_heads, Sq // block_q): one program per query tile;
  * the query tile (block_q, D) lives in VMEM; K/V for the *kv head* of this
    query head (GQA mapping done in the BlockSpec index_map) are staged in
    VMEM as (Sk, D) blocks -- sized for Sk*D*4B <= a few MB, i.e. contexts up
    to ~8k at D=128.  Longer contexts tile over an extra kv grid dimension at
    the ops layer (chunked attention with softmax recombination);
  * inner fori_loop walks kv tiles of size block_k with the online-softmax
    (m, l, acc) recurrence; the causal tile skip bounds the loop count so the
    average program does half the work (the scheduler-visible win of
    causality);
  * matmul tiles are (block_q x D) @ (D x block_k) -> MXU-aligned when
    block_q, block_k, D are multiples of 128 (D=64 also lowers fine).

Validated on CPU with interpret=True against ``ref.mha``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1.0e30  # python float (jnp scalars become captured consts)


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, scale, block_k, causal,
                 sk_total, q_offset):
    qi = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, D)
    bq = q.shape[0]
    n_kv = sk_total // block_k
    # causal limit: last kv tile that any query in this tile can see
    if causal:
        q_last = q_offset + qi * bq + bq - 1
        kv_hi = jnp.minimum((q_last // block_k) + 1, n_kv)
    else:
        kv_hi = n_kv

    def body(j, carry):
        m, l, acc = carry
        k = jax.lax.dynamic_slice_in_dim(
            k_ref[0, 0], j * block_k, block_k).astype(jnp.float32)
        v = jax.lax.dynamic_slice_in_dim(
            v_ref[0, 0], j * block_k, block_k).astype(jnp.float32)
        s = q @ k.T                                        # (bq, bk)
        if causal:
            qpos = q_offset + qi * bq + jax.lax.broadcasted_iota(
                jnp.int32, (bq, block_k), 0)
            kpos = j * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (bq, block_k), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=1)
        acc_new = acc * alpha[:, None] + p @ v
        return m_new, l_new, acc_new

    m0 = jnp.full((bq,), NEG_INF)
    l0 = jnp.zeros((bq,))
    acc0 = jnp.zeros((bq, q.shape[1]))
    m, l, acc = jax.lax.fori_loop(0, kv_hi, body, (m0, l0, acc0))
    o_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret", "scale"))
def flash_attention(q, k, v, *, causal: bool = True, scale=None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True):
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D). Returns (B, Hq, Sq, D).

    For decode (Sq < block_q) the q tile shrinks to Sq.  Queries are assumed
    to occupy the last Sq positions of the Sk-long context (KV-cache layout).
    """
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    assert Hq % Hkv == 0
    group = Hq // Hkv
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    block_q = min(block_q, Sq)
    # pad Sq to a block multiple
    pq = (-Sq) % block_q
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    pk = (-Sk) % block_k
    if pk:
        # pad keys with zeros; mask via causal bound won't see them for
        # causal=True; for non-causal we mask explicitly below by padding
        # k with NEG-scoring values: simplest is to require Sk % block_k == 0
        raise ValueError(f"Sk={Sk} must be a multiple of block_k={block_k}")
    Sq_p = q.shape[2]
    q_offset = Sk - Sq          # causal alignment for KV-cache decode

    kernel = functools.partial(
        _attn_kernel, scale=scale, block_k=block_k, causal=causal,
        sk_total=Sk, q_offset=q_offset)
    out = pl.pallas_call(
        kernel,
        grid=(B, Hq, Sq_p // block_q),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, Sk, D), lambda b, h, i: (b, h // group, 0, 0)),
            pl.BlockSpec((1, 1, Sk, D), lambda b, h, i: (b, h // group, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, i: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq_p, D), q.dtype),
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :Sq]
