"""Public attention entry point used by the model zoo.

``backend``:
  * 'auto'   -- Pallas kernel on TPU, jnp reference elsewhere (interpret-mode
                Pallas is far too slow for real training steps on CPU);
  * 'pallas' -- force the kernel (interpret=True off-TPU: used by tests);
  * 'xla'    -- the pure-jnp reference.
"""
from __future__ import annotations

from . import kernel as _kernel
from . import ref as _ref
from .._common import resolve_backend, use_interpret


def attention(q, k, v, *, causal: bool = True, scale=None,
              backend: str = "auto", block_q: int = 128, block_k: int = 128):
    """q (B,Hq,Sq,Dk); k (B,Hkv,Sk,Dk); v (B,Hkv,Sk,Dv) -> (B,Hq,Sq,Dv).

    Dv != Dk and long sequences route through the chunked XLA path."""
    backend = resolve_backend(backend)
    mixed_dims = v.shape[-1] != k.shape[-1]
    long_seq = k.shape[2] > 1024
    if backend == "xla":
        if mixed_dims or long_seq:
            return _ref.mha_chunked(q, k, v, causal=causal, scale=scale,
                                    block_k=min(512, k.shape[2]))
        return _ref.mha(q, k, v, causal=causal, scale=scale)
    if mixed_dims:
        return _ref.mha_chunked(q, k, v, causal=causal, scale=scale)
    return _kernel.flash_attention(
        q, k, v, causal=causal, scale=scale, block_q=block_q,
        block_k=block_k, interpret=use_interpret())
