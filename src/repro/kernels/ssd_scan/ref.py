"""Pure-jnp oracle for the Mamba2 SSD (state-space duality) scan.

Semantics (scalar-per-head A, the Mamba2 parameterization):

    h_t = exp(A_h * dt_t) * h_{t-1} + dt_t * (B_t  outer  x_t)
    y_t = C_t . h_t                       (contract the state dim N)

shapes: x (B, L, H, P); dt (B, L, H); A (H,) (negative);
B_mat, C (B, L, G, N) with H % G == 0 (grouped B/C a la GQA).
Returns y (B, L, H, P).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan(x, dt, A, B_mat, C):
    Bsz, L, H, P = x.shape
    G = B_mat.shape[2]
    N = B_mat.shape[3]
    assert H % G == 0
    rep = H // G
    Bh = jnp.repeat(B_mat, rep, axis=2)       # (B, L, H, N)
    Ch = jnp.repeat(C, rep, axis=2)

    def per_bh(xbh, dtbh, a, Bbh, Cbh):
        # xbh (L, P), dtbh (L,), Bbh/Cbh (L, N)
        def step(h, inp):
            xt, dtt, bt, ct = inp
            h = jnp.exp(a * dtt) * h + dtt * (bt[:, None] * xt[None, :])
            y = ct @ h                         # (P,)
            return h, y
        h0 = jnp.zeros((Bbh.shape[1], xbh.shape[1]), jnp.float32)
        _, y = jax.lax.scan(step, h0, (xbh.astype(jnp.float32),
                                       dtbh.astype(jnp.float32),
                                       Bbh.astype(jnp.float32),
                                       Cbh.astype(jnp.float32)))
        return y

    f = jax.vmap(jax.vmap(per_bh, in_axes=(1, 1, 0, 1, 1), out_axes=1),
                 in_axes=(0, 0, None, 0, 0), out_axes=0)
    y = f(x, dt, A.astype(jnp.float32), Bh, Ch)
    return y.astype(x.dtype)


def ssd_chunked(x, dt, A, B_mat, C, chunk: int = 64):
    """Chunked closed form (the algorithm the Pallas kernel implements);
    mathematically identical to ``ssd_scan`` -- used as the model's
    CPU-efficient path and as a second oracle."""
    Bsz, L, H, P = x.shape
    G, N = B_mat.shape[2], B_mat.shape[3]
    rep = H // G
    assert L % chunk == 0
    Q = chunk
    nc = L // Q
    xf = x.astype(jnp.float32).reshape(Bsz, nc, Q, H, P)
    dtf = dt.astype(jnp.float32).reshape(Bsz, nc, Q, H)
    Bf = jnp.repeat(B_mat, rep, axis=2).astype(jnp.float32).reshape(
        Bsz, nc, Q, H, N)
    Cf = jnp.repeat(C, rep, axis=2).astype(jnp.float32).reshape(
        Bsz, nc, Q, H, N)
    Af = A.astype(jnp.float32)

    lam = jnp.cumsum(Af[None, None, None, :] * dtf, axis=2)   # (B,nc,Q,H)

    # intra-chunk: S[i,j] = (C_i.B_j) exp(lam_i - lam_j) dt_j for j<=i
    Sdot = jnp.einsum("bcqhn,bckhn->bchqk", Cf, Bf)
    dec = jnp.exp(lam[:, :, :, None, :] - lam[:, :, None, :, :])  # (B,nc,Q,K,H)
    dec = jnp.moveaxis(dec, -1, 2)                                # (B,nc,H,Q,K)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    S = jnp.where(mask[None, None, None], Sdot * dec
                  * jnp.moveaxis(dtf, 2, 3)[:, :, :, None, :], 0.0)
    y_intra = jnp.einsum("bchqk,bckhp->bcqhp", S, xf)

    # inter-chunk: carry states sequentially
    lam_end = lam[:, :, -1, :]                                    # (B,nc,H)
    # chunk state contribution: sum_j exp(lam_end - lam_j) dt_j B_j x_j^T
    w = jnp.exp(lam_end[:, :, None, :] - lam) * dtf               # (B,nc,Q,H)
    chunk_state = jnp.einsum("bcqh,bcqhn,bcqhp->bchnp", w, Bf, xf)

    def carry_fn(h, inp):
        cs, le = inp                       # (B,H,N,P), (B,H)
        h_new = jnp.exp(le)[:, :, None, None] * h + cs
        return h_new, h                    # emit state at chunk *start*
    h0 = jnp.zeros((Bsz, H, N, P), jnp.float32)
    _, h_starts = jax.lax.scan(
        carry_fn, h0, (jnp.moveaxis(chunk_state, 1, 0),
                       jnp.moveaxis(lam_end, 1, 0)))
    h_starts = jnp.moveaxis(h_starts, 0, 1)                       # (B,nc,H,N,P)

    y_inter = jnp.einsum("bcqhn,bchnp,bcqh->bcqhp", Cf, h_starts,
                         jnp.exp(lam))
    y = (y_intra + y_inter).reshape(Bsz, L, H, P)
    return y.astype(x.dtype)


def ssd_final_state(x, dt, A, B_mat, C, chunk: int = 64):
    """Final SSM state h_L (B, H, N, P) -- used by prefill to seed decode."""
    Bsz, L, H, P = x.shape
    G, N = B_mat.shape[2], B_mat.shape[3]
    rep = H // G
    pad = (-L) % chunk
    if pad:
        zp = lambda a: jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
        x, dt, B_mat, C = zp(x), zp(dt), zp(B_mat), zp(C)
    L2 = x.shape[1]
    Q = chunk
    nc = L2 // Q
    xf = x.astype(jnp.float32).reshape(Bsz, nc, Q, H, P)
    dtf = dt.astype(jnp.float32).reshape(Bsz, nc, Q, H)
    Bf = jnp.repeat(B_mat, rep, axis=2).astype(jnp.float32).reshape(
        Bsz, nc, Q, H, N)
    Af = A.astype(jnp.float32)
    lam = jnp.cumsum(Af[None, None, None, :] * dtf, axis=2)
    lam_end = lam[:, :, -1, :]
    w = jnp.exp(lam_end[:, :, None, :] - lam) * dtf
    chunk_state = jnp.einsum("bcqh,bcqhn,bcqhp->bchnp", w, Bf, xf)

    def carry_fn(h, inp):
        cs, le = inp
        return jnp.exp(le)[:, :, None, None] * h + cs, None
    h0 = jnp.zeros((Bsz, H, N, P), jnp.float32)
    h_final, _ = jax.lax.scan(carry_fn, h0,
                              (jnp.moveaxis(chunk_state, 1, 0),
                               jnp.moveaxis(lam_end, 1, 0)))
    return h_final
