"""Pallas TPU kernel: Mamba2 SSD chunked scan.

TPU mapping of the state-space-duality algorithm:

  * grid = (batch, heads): one program owns a full (L, P) sequence for one
    head -- the sequential chunk recurrence stays inside the program, so the
    state (N, P) never leaves VMEM/registers;
  * per chunk of Q steps, the three terms are dense matmuls on the MXU:
      intra:  (Q,N)@(N,Q) decay-masked, then (Q,Q)@(Q,P)
      inter:  (Q,N)@(N,P)
      state:  (N,Q)@(Q,P)
  * Q and N default to 64/128: MXU-aligned; P (head dim) 64.

Grouped B/C (the Mamba2 analogue of GQA) is resolved in the BlockSpec
index_map, exactly like kv heads in flash attention.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, o_ref, *, chunk, n_state):
    L = x_ref.shape[2]
    P = x_ref.shape[3]
    Q = chunk
    a = a_ref[0, 0]

    x = x_ref[0, 0].astype(jnp.float32)       # (L, P)
    dtv = dt_ref[0, 0].astype(jnp.float32)    # (L,)
    Bm = b_ref[0, 0].astype(jnp.float32)      # (L, N)
    Cm = c_ref[0, 0].astype(jnp.float32)      # (L, N)

    mask = jnp.tril(jnp.ones((Q, Q), jnp.float32))

    def body(ci, carry):
        h = carry                              # (N, P)
        sl = ci * Q
        xq = jax.lax.dynamic_slice_in_dim(x, sl, Q)
        dq = jax.lax.dynamic_slice_in_dim(dtv, sl, Q)
        Bq = jax.lax.dynamic_slice_in_dim(Bm, sl, Q)
        Cq = jax.lax.dynamic_slice_in_dim(Cm, sl, Q)
        lam = jnp.cumsum(a * dq)               # (Q,)
        dec = jnp.exp(lam[:, None] - lam[None, :]) * mask
        S = (Cq @ Bq.T) * dec * dq[None, :]
        y_intra = S @ xq                        # (Q, P)
        y_inter = jnp.exp(lam)[:, None] * (Cq @ h)
        o_slice = (y_intra + y_inter).astype(o_ref.dtype)
        # scalar leading indices must be traced values: python ints break the
        # interpret-mode state-discharge rule on jax 0.4.x
        zero = jnp.int32(0)
        pl.store(o_ref, (zero, zero, pl.dslice(sl, Q), pl.dslice(0, P)),
                 o_slice)
        w = jnp.exp(lam[-1] - lam) * dq         # (Q,)
        h_new = jnp.exp(lam[-1]) * h + (Bq * w[:, None]).T @ xq
        return h_new

    h0 = jnp.zeros((n_state, P), jnp.float32)
    jax.lax.fori_loop(0, L // Q, body, h0)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, B_mat, C, *, chunk: int = 64, interpret: bool = True):
    """x (B,L,H,P); dt (B,L,H); A (H,); B_mat/C (B,L,G,N). Returns (B,L,H,P).

    L must be a multiple of ``chunk`` (the ops wrapper pads).
    """
    Bsz, L, H, P = x.shape
    G, N = B_mat.shape[2], B_mat.shape[3]
    assert H % G == 0 and L % chunk == 0
    group = H // G
    # layout: (B, H, L, P) etc. so each program gets contiguous blocks
    xt = jnp.moveaxis(x, 2, 1)                   # (B,H,L,P)
    dtt = jnp.moveaxis(dt, 2, 1)                 # (B,H,L)
    Bt = jnp.moveaxis(B_mat, 2, 1)               # (B,G,L,N)
    Ct = jnp.moveaxis(C, 2, 1)
    A2 = jnp.broadcast_to(A.astype(jnp.float32), (Bsz, H))

    kernel = functools.partial(_ssd_kernel, chunk=chunk, n_state=N)
    out = pl.pallas_call(
        kernel,
        grid=(Bsz, H),
        in_specs=[
            pl.BlockSpec((1, 1, L, P), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, L), lambda b, h: (b, h, 0)),
            pl.BlockSpec((1, 1), lambda b, h: (b, h)),
            pl.BlockSpec((1, 1, L, N), lambda b, h: (b, h // group, 0, 0)),
            pl.BlockSpec((1, 1, L, N), lambda b, h: (b, h // group, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, L, P), lambda b, h: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((Bsz, H, L, P), x.dtype),
        interpret=interpret,
    )(xt, dtt, A2, Bt, Ct)
    return jnp.moveaxis(out, 1, 2)
