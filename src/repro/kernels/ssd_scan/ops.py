"""Public wrapper for the Mamba2 SSD scan.

``backend``: 'auto' (pallas on TPU, chunked-jnp elsewhere), 'pallas',
'chunked' (jnp closed form), 'scan' (sequential oracle).
"""
from __future__ import annotations

import jax.numpy as jnp

from . import kernel as _kernel
from . import ref as _ref
from .._common import resolve_backend, use_interpret


def ssd(x, dt, A, B_mat, C, *, chunk: int = 64, backend: str = "auto"):
    backend = resolve_backend(
        backend, fallback="chunked",
        choices=("auto", "pallas", "chunked", "scan"))
    L = x.shape[1]
    pad = (-L) % chunk
    if pad and backend in ("pallas", "chunked"):
        zp = lambda a: jnp.pad(a, [(0, 0), (0, pad)] +
                               [(0, 0)] * (a.ndim - 2))
        x, dt, B_mat, C = zp(x), zp(dt), zp(B_mat), zp(C)
    if backend == "pallas":
        y = _kernel.ssd_scan(x, dt, A, B_mat, C, chunk=chunk,
                             interpret=use_interpret())
    elif backend == "chunked":
        y = _ref.ssd_chunked(x, dt, A, B_mat, C, chunk=chunk)
    else:
        y = _ref.ssd_scan(x, dt, A, B_mat, C)
    return y[:, :L]
