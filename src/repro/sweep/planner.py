"""Campaign execution planning.

Two-level grouping of the expanded grid:

1. **Seed batches** -- grid points identical up to the replicate seed merge
   into one :class:`SeedBatch`, which the runner executes as a *single*
   ``fastsim.simulate_batch`` call (one jitted, seed-vmapped dispatch).
2. **Compile groups** -- batches are ordered by *pipeline shape key*
   (tree/workload/failure identity + ``LBScheme.shape_key()``), the same
   information that keys ``fastsim._build_run``'s compile cache.  Batches
   with equal shape keys run back-to-back and share one compiled executable:
   e.g. flow_ecmp, subflow_mptcp, host_pkt and host_dr all lower to the same
   'pre/pre' pipeline and compile exactly once per (tree, workload) pair.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from ..core import lb_schemes as lbs
from .spec import Campaign, FailureSpec, GridPoint, WorkloadSpec


@dataclasses.dataclass(frozen=True)
class SeedBatch:
    """All replicate seeds of one simulation point: one vmapped execution."""
    campaign: str
    k: int
    load: WorkloadSpec
    failure: Optional[FailureSpec]
    scheme: str
    seeds: Tuple[int, ...]

    def points(self) -> List[GridPoint]:
        return [GridPoint(self.campaign, self.k, self.load, self.failure,
                          self.scheme, s) for s in self.seeds]

    def shape_key(self, backend: str, prop_slots: float) -> Tuple:
        """Compiled-pipeline identity (modulo JSQ padding, which the engine
        derives from the workload and is therefore equal within a group)."""
        return (self.k, self.load, self.failure,
                lbs.by_name(self.scheme).shape_key(), backend,
                float(prop_slots))


@dataclasses.dataclass
class Plan:
    campaign: Campaign
    batches: List[SeedBatch]

    @property
    def n_points(self) -> int:
        return sum(len(b.seeds) for b in self.batches)

    @property
    def n_dispatches(self) -> int:
        return len(self.batches)

    def describe(self) -> str:
        n_shapes = len({b.shape_key(self.campaign.backend,
                                    self.campaign.prop_slots)
                        for b in self.batches})
        return (f"campaign {self.campaign.name!r}: {self.n_points} grid "
                f"points -> {self.n_dispatches} batched dispatches "
                f"({n_shapes} compiled pipeline shapes)")


def plan(campaign: Campaign) -> Plan:
    """Group the campaign grid into seed batches ordered for compile reuse."""
    batches: dict = {}
    order: list = []
    for p in campaign.points():
        key = (p.k, p.load, p.failure, p.scheme)
        if key not in batches:
            batches[key] = []
            order.append(key)
        batches[key].append(p.seed)

    out = [SeedBatch(campaign=campaign.name, k=k, load=load, failure=failure,
                     scheme=scheme, seeds=tuple(batches[(k, load, failure,
                                                         scheme)]))
           for (k, load, failure, scheme) in order]
    # Stable sort by shape key: batches sharing a compiled pipeline become
    # adjacent while the within-shape grid order is preserved.
    shape_rank: dict = {}
    for b in out:
        shape_rank.setdefault(
            b.shape_key(campaign.backend, campaign.prop_slots),
            len(shape_rank))
    out.sort(key=lambda b: shape_rank[b.shape_key(campaign.backend,
                                                  campaign.prop_slots)])
    return Plan(campaign=campaign, batches=out)
