"""Campaign execution planning.

Three-level grouping of the expanded grid:

1. **Seed batches** -- grid points identical up to the replicate seed merge
   into one :class:`SeedBatch` (the record-keeping granularity: one
   workload/failure/scheme/G cell with all its seeds).
2. **Megabatches** -- fast-engine seed batches whose points lower to the
   same compiled pipeline fuse into one :class:`MegaBatch`, which the runner
   executes as a *single* jitted ``fastsim.simulate_megabatch`` dispatch:
   the scheme axis (flow_ecmp, subflow_mptcp, host_pkt and host_dr all lower
   to the same 'pre/pre' pipeline), the failure axis, and -- via
   shape-bucketed packet padding -- nearby message sizes all stack onto one
   fused ``(scheme x load x failure x seed)`` batch axis.
3. **Compiled shapes** -- one per distinct megabatch key, so
   ``n_dispatches == n_compiled_shapes``: every compile is amortized over
   the whole grid slice that shares it.

Both engines fuse.  Fast-engine batches group by ``LBScheme.shape_key()``;
loop-engine batches (ACK/ECN schemes) group by ``LBScheme.loop_shape_key()``
plus the static ``LoopConfig`` fields (``loss``, ``cca``, ``buffer_pkts``,
timing constants) and the power-of-two-bucketed slot budget -- the failure,
``g_converge``, rho and seed axes all ride the fused batch axis as operands.

The *tree-size* axis buckets too (``_batching.k_buckets``): every tree of a
campaign pads its topology operands to the largest ``k`` of its bucket, so
fused keys carry the k-bucket head instead of the raw ``k`` and a grid
sweeping tree size costs ONE dispatch per compiled shape, not one per tree.
Packet buckets are taken at the bucket-head tree (``n_packets(k_pad)``) so
the packet axis can't silently re-split what the k axis fused.  This holds
for EVERY scheme on BOTH engines: loop-engine rand/JSQ in-loop randomness
comes from counter streams keyed on logical ids (``core.entropy``), so
tree padding cannot perturb the draws and no fused key carries a raw ``k``
anywhere.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Tuple

from ..core import lb_schemes as lbs
from ..net._batching import k_buckets, pow2_bucket
from ..net import loopsim
from ..obs.probes import probe_shape
from .spec import Campaign, FailureSpec, GridPoint, WorkloadSpec


def bucket_packets(n: int) -> int:
    """Shape bucket for packet-array padding: next power of two.  Workloads
    whose packet counts land in one bucket share a compiled pipeline."""
    return pow2_bucket(n)


@functools.lru_cache(maxsize=256)
def _kmap_cached(trees: Tuple[int, ...]) -> Dict[int, int]:
    return k_buckets(trees)


def _kmap(trees: Tuple[int, ...]) -> Dict[int, int]:
    """Campaign-scoped tree-size buckets (``{k: k_pad}``).  The cache key is
    the canonicalized axis -- ``tuple(sorted(set(...)))`` -- so permuted or
    duplicated ``trees`` tuples (equal grids, equal buckets) hit one entry
    instead of multiplying equivalent ones."""
    return _kmap_cached(tuple(sorted({int(k) for k in trees})))


@dataclasses.dataclass(frozen=True)
class SeedBatch:
    """All replicate seeds of one simulation point."""
    campaign: str
    k: int
    load: WorkloadSpec
    failure: Optional[FailureSpec]
    scheme: str
    seeds: Tuple[int, ...]
    g_converge: Optional[int] = None
    timing: Optional[Tuple[int, int]] = None
    phase: Optional[object] = None     # repro.phases.PhaseSchedule

    def points(self) -> List[GridPoint]:
        return [GridPoint(self.campaign, self.k, self.load, self.failure,
                          self.scheme, s, self.g_converge, self.timing,
                          self.phase)
                for s in self.seeds]

    def n_packets(self, k: int) -> int:
        """Packet count of this batch's (possibly phased) traffic on a
        fat-tree of size ``k`` -- the planner's bucketing input and the
        cost model / fill accounting's "real rows" term."""
        if self.phase is not None:
            return self.phase.n_packets(k, self.load.msg_packets)
        return self.load.n_packets(k)

    def fused_key(self, campaign: Campaign, policy=None) -> Tuple:
        """Megabatch identity: everything the fused dispatch compiles over.
        Loads/failures/g_converge are *not* part of it (their per-packet
        arrays and convergence/rho scalars ride the batch axis, padded to
        the bucketed packet count), and neither is the raw tree size: the
        key carries the campaign's k-bucket head, to which every member's
        topology operands pad (packet buckets are taken at the bucket-head
        tree for the same reason).  Loop-engine points additionally key on
        the static LoopConfig fields (timing constants pow2-bucketed --
        the ring shapes, not the per-row moduli) and the bucketed slot
        budget; in-loop randomness is counter-stream based
        (``core.entropy``), so rand/JSQ loop schemes bucket like every
        other scheme -- no fused key carries a raw k.

        ``policy`` (a ``sweep.costmodel.BucketPolicy``) overrides the
        default greedy-2x k-buckets / pow2 packet buckets; ``None`` keeps
        the heuristic."""
        scheme = lbs.by_name(self.scheme)
        kmap = policy.kmap_dict() if policy is not None else \
            _kmap(campaign.trees)
        kb = kmap[self.k]
        npk = (policy.pkt_bucket(kb, self.n_packets(kb))
               if policy is not None
               else bucket_packets(self.n_packets(kb)))
        if campaign.engine == "loop" or scheme.needs_feedback:
            return ("loop", kb, npk,
                    scheme.loop_shape_key(),
                    loopsim.static_config(
                        campaign.loop_config(timing=self.timing)),
                    pow2_bucket(int(campaign.max_slots)),
                    probe_shape(campaign.probes))
        return ("fast", kb, npk,
                scheme.shape_key(), campaign.backend,
                float(campaign.prop_slots), probe_shape(campaign.probes))


@dataclasses.dataclass
class MegaBatch:
    """One runner dispatch: all member batches execute as a single jitted
    ``simulate_megabatch`` call on their engine (``fastsim`` or
    ``loopsim``)."""
    key: Tuple
    members: List[SeedBatch]

    @property
    def engine(self) -> str:
        return "loop" if self.key[0] == "loop" else "fast"

    @property
    def k_pad(self) -> int:
        """Tree size every member's topology operands pad to (the k-bucket
        head; equals the raw k for unbucketed members)."""
        return self.key[1]

    @property
    def npk_pad(self) -> int:
        """Bucketed packet-array padding of the fused dispatch."""
        return self.key[2]

    @property
    def n_points(self) -> int:
        return sum(len(b.seeds) for b in self.members)


@dataclasses.dataclass
class Plan:
    campaign: Campaign
    batches: List[SeedBatch]
    megabatches: List[MegaBatch]
    # Cost-modeled planning (``Campaign.planner == 'cost'`` or an explicit
    # ``policy=`` argument): the chosen ``costmodel.BucketPolicy``, its
    # predicted ``costmodel.PlanCost``, and the rejected alternatives as
    # (label, total cost, predicted pkt fill) rows.  All ``None``/empty
    # under the default heuristic policy.
    policy: Optional[object] = None
    cost: Optional[object] = None
    alternatives: Tuple = ()

    @property
    def n_points(self) -> int:
        return sum(len(b.seeds) for b in self.batches)

    @property
    def n_dispatches(self) -> int:
        return len(self.megabatches)

    @property
    def n_shapes(self) -> int:
        return len({m.key for m in self.megabatches})

    def describe(self) -> str:
        pol = (f" [policy {self.policy.label}]"
               if self.policy is not None else "")
        return (f"campaign {self.campaign.name!r}: {self.n_points} grid "
                f"points -> {self.n_dispatches} fused dispatches "
                f"({self.n_shapes} compiled pipeline shapes){pol}")


def plan(campaign: Campaign, policy=None, cost_params=None) -> Plan:
    """Group the campaign grid into seed batches, then fuse batches sharing
    a compiled pipeline into megabatches (one dispatch per compiled shape).

    With ``campaign.planner == 'cost'`` (and no explicit ``policy``) the
    ``sweep.costmodel`` cost model picks the bucketing: candidate tree/
    packet bucketings are scored as padded packet rows + slot-budget waste
    + a per-new-shape compile charge (``cost_params``, optionally
    calibrated from a measured trace), the minimizer wins, and dispatches
    are ordered largest-first so sharded device lanes fill before the
    small tails run.  An explicit ``policy`` (a
    ``costmodel.BucketPolicy``) bypasses selection and plans under that
    policy directly -- that is also how the cost model itself evaluates
    each candidate."""
    cost = None
    alternatives: Tuple = ()
    if policy is None and campaign.planner == "cost":
        from .costmodel import choose_policy
        policy, cost, alternatives = choose_policy(campaign, cost_params)

    batches: dict = {}
    for p in campaign.points():
        key = (p.k, p.load, p.failure, p.scheme, p.g_converge, p.timing,
               p.phase)
        batches.setdefault(key, []).append(p.seed)

    out = [SeedBatch(campaign=campaign.name, k=k, load=load, failure=failure,
                     scheme=scheme, seeds=tuple(seeds), g_converge=g,
                     timing=tm, phase=ph)
           for (k, load, failure, scheme, g, tm, ph), seeds
           in batches.items()]
    # Stable sort by fused key: batches sharing a compiled pipeline become
    # adjacent (and fuse into one dispatch) while the within-group grid
    # order is preserved.
    fused_rank: dict = {}
    for b in out:
        fused_rank.setdefault(b.fused_key(campaign, policy), len(fused_rank))
    out.sort(key=lambda b: fused_rank[b.fused_key(campaign, policy)])

    megas: List[MegaBatch] = []
    for b in out:
        key = b.fused_key(campaign, policy)
        if megas and megas[-1].key == key:
            megas[-1].members.append(b)
        else:
            megas.append(MegaBatch(key=key, members=[b]))

    if policy is not None:
        # Largest-first dispatch order: sharded fused axes fill their
        # device lanes on the big dispatches before the small tails run
        # (first-seen rank breaks ties, keeping the order deterministic).
        first_seen = {id(m): i for i, m in enumerate(megas)}
        megas.sort(key=lambda m: (-m.n_points * m.npk_pad,
                                  first_seen[id(m)]))
        out = [b for m in megas for b in m.members]
    return Plan(campaign=campaign, batches=out, megabatches=megas,
                policy=policy, cost=cost, alternatives=alternatives)
