"""Campaign execution planning.

Three-level grouping of the expanded grid:

1. **Seed batches** -- grid points identical up to the replicate seed merge
   into one :class:`SeedBatch` (the record-keeping granularity: one
   workload/failure/scheme/G cell with all its seeds).
2. **Megabatches** -- fast-engine seed batches whose points lower to the
   same compiled pipeline fuse into one :class:`MegaBatch`, which the runner
   executes as a *single* jitted ``fastsim.simulate_megabatch`` dispatch:
   the scheme axis (flow_ecmp, subflow_mptcp, host_pkt and host_dr all lower
   to the same 'pre/pre' pipeline), the failure axis, and -- via
   shape-bucketed packet padding -- nearby message sizes all stack onto one
   fused ``(scheme x load x failure x seed)`` batch axis.
3. **Compiled shapes** -- one per distinct megabatch key, so
   ``n_dispatches == n_compiled_shapes``: every compile is amortized over
   the whole grid slice that shares it.

Both engines fuse.  Fast-engine batches group by ``LBScheme.shape_key()``;
loop-engine batches (ACK/ECN schemes) group by ``LBScheme.loop_shape_key()``
plus the static ``LoopConfig`` fields (``loss``, ``cca``, ``buffer_pkts``,
timing constants) and the power-of-two-bucketed slot budget -- the failure,
``g_converge``, rho and seed axes all ride the fused batch axis as operands.

The *tree-size* axis buckets too (``_batching.k_buckets``): every tree of a
campaign pads its topology operands to the largest ``k`` of its bucket, so
fused keys carry the k-bucket head instead of the raw ``k`` and a grid
sweeping tree size costs ONE dispatch per compiled shape, not one per tree.
Packet buckets are taken at the bucket-head tree (``n_packets(k_pad)``) so
the packet axis can't silently re-split what the k axis fused.  This holds
for EVERY scheme on BOTH engines: loop-engine rand/JSQ in-loop randomness
comes from counter streams keyed on logical ids (``core.entropy``), so
tree padding cannot perturb the draws and no fused key carries a raw ``k``
anywhere.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Tuple

from ..core import lb_schemes as lbs
from ..net._batching import k_buckets, pow2_bucket
from ..net import loopsim
from ..obs.probes import probe_shape
from .spec import Campaign, FailureSpec, GridPoint, WorkloadSpec


def bucket_packets(n: int) -> int:
    """Shape bucket for packet-array padding: next power of two.  Workloads
    whose packet counts land in one bucket share a compiled pipeline."""
    return pow2_bucket(n)


@functools.lru_cache(maxsize=256)
def _kmap(trees: Tuple[int, ...]) -> Dict[int, int]:
    """Campaign-scoped tree-size buckets (``{k: k_pad}``)."""
    return k_buckets(trees)


@dataclasses.dataclass(frozen=True)
class SeedBatch:
    """All replicate seeds of one simulation point."""
    campaign: str
    k: int
    load: WorkloadSpec
    failure: Optional[FailureSpec]
    scheme: str
    seeds: Tuple[int, ...]
    g_converge: Optional[int] = None

    def points(self) -> List[GridPoint]:
        return [GridPoint(self.campaign, self.k, self.load, self.failure,
                          self.scheme, s, self.g_converge)
                for s in self.seeds]

    def fused_key(self, campaign: Campaign) -> Tuple:
        """Megabatch identity: everything the fused dispatch compiles over.
        Loads/failures/g_converge are *not* part of it (their per-packet
        arrays and convergence/rho scalars ride the batch axis, padded to
        the bucketed packet count), and neither is the raw tree size: the
        key carries the campaign's k-bucket head, to which every member's
        topology operands pad (packet buckets are taken at the bucket-head
        tree for the same reason).  Loop-engine points additionally key on
        the static LoopConfig fields and the bucketed slot budget; in-loop
        randomness is counter-stream based (``core.entropy``), so rand/JSQ
        loop schemes bucket like every other scheme -- no fused key carries
        a raw k."""
        scheme = lbs.by_name(self.scheme)
        if campaign.engine == "loop" or scheme.needs_feedback:
            kb = _kmap(campaign.trees)[self.k]
            return ("loop", kb, bucket_packets(self.load.n_packets(kb)),
                    scheme.loop_shape_key(),
                    loopsim.static_config(campaign.loop_config()),
                    pow2_bucket(max(int(campaign.max_slots), 1)),
                    probe_shape(campaign.probes))
        kb = _kmap(campaign.trees)[self.k]
        return ("fast", kb, bucket_packets(self.load.n_packets(kb)),
                scheme.shape_key(), campaign.backend,
                float(campaign.prop_slots), probe_shape(campaign.probes))


@dataclasses.dataclass
class MegaBatch:
    """One runner dispatch: all member batches execute as a single jitted
    ``simulate_megabatch`` call on their engine (``fastsim`` or
    ``loopsim``)."""
    key: Tuple
    members: List[SeedBatch]

    @property
    def engine(self) -> str:
        return "loop" if self.key[0] == "loop" else "fast"

    @property
    def k_pad(self) -> int:
        """Tree size every member's topology operands pad to (the k-bucket
        head; equals the raw k for unbucketed members)."""
        return self.key[1]

    @property
    def npk_pad(self) -> int:
        """Bucketed packet-array padding of the fused dispatch."""
        return self.key[2]

    @property
    def n_points(self) -> int:
        return sum(len(b.seeds) for b in self.members)


@dataclasses.dataclass
class Plan:
    campaign: Campaign
    batches: List[SeedBatch]
    megabatches: List[MegaBatch]

    @property
    def n_points(self) -> int:
        return sum(len(b.seeds) for b in self.batches)

    @property
    def n_dispatches(self) -> int:
        return len(self.megabatches)

    @property
    def n_shapes(self) -> int:
        return len({m.key for m in self.megabatches})

    def describe(self) -> str:
        return (f"campaign {self.campaign.name!r}: {self.n_points} grid "
                f"points -> {self.n_dispatches} fused dispatches "
                f"({self.n_shapes} compiled pipeline shapes)")


def plan(campaign: Campaign) -> Plan:
    """Group the campaign grid into seed batches, then fuse batches sharing
    a compiled pipeline into megabatches (one dispatch per compiled shape)."""
    batches: dict = {}
    for p in campaign.points():
        key = (p.k, p.load, p.failure, p.scheme, p.g_converge)
        batches.setdefault(key, []).append(p.seed)

    out = [SeedBatch(campaign=campaign.name, k=k, load=load, failure=failure,
                     scheme=scheme, seeds=tuple(seeds), g_converge=g)
           for (k, load, failure, scheme, g), seeds in batches.items()]
    # Stable sort by fused key: batches sharing a compiled pipeline become
    # adjacent (and fuse into one dispatch) while the within-group grid
    # order is preserved.
    fused_rank: dict = {}
    for b in out:
        fused_rank.setdefault(b.fused_key(campaign), len(fused_rank))
    out.sort(key=lambda b: fused_rank[b.fused_key(campaign)])

    megas: List[MegaBatch] = []
    for b in out:
        key = b.fused_key(campaign)
        if megas and megas[-1].key == key:
            megas[-1].members.append(b)
        else:
            megas.append(MegaBatch(key=key, members=[b]))
    return Plan(campaign=campaign, batches=out, megabatches=megas)
