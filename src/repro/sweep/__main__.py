"""Campaign CLI.

    python -m repro.sweep run --preset theory --out runs/theory
    python -m repro.sweep run --spec campaign.json --seeds 0:8
    python -m repro.sweep run --preset layer_balance --probes 64 --out runs/lb
    python -m repro.sweep presets
    python -m repro.sweep summarize --results runs/theory/results.jsonl
    python -m repro.sweep report --trace runs/lb/trace.jsonl \
        --results runs/lb/results.jsonl

``run`` writes ``<out>/results.jsonl`` (one record per grid point),
``<out>/summary.jsonl`` (seed-aggregated rows) and ``<out>/trace.jsonl``
(one span per fused dispatch; see ``repro.obs``) -- all byte-deterministic
for a given spec, the trace modulo its wall-clock/cache fields.  ``report``
renders a trace (plus, optionally, probe-carrying results) into a
human-readable cost summary.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import pathlib
import sys

from ..obs import ProbeSpec, SweepLogger, TraceWriter, load_trace, render_report
from . import compile_cache
from .spec import Campaign, PRESETS, preset
from .planner import plan
from .results import ResultStore, summarize, write_summary
from .runner import run_campaign


def _parse_seeds(text: str):
    """'0:8' -> range(0, 8); '1,5,9' -> (1, 5, 9)."""
    if ":" in text:
        lo, hi = text.split(":")
        return tuple(range(int(lo), int(hi)))
    return tuple(int(s) for s in text.split(","))


def _parse_probes(text: str) -> ProbeSpec:
    """'64' -> ProbeSpec(stride=64); '64,128' -> ProbeSpec(64, 128)."""
    parts = [int(p) for p in text.split(",")]
    if len(parts) == 1:
        return ProbeSpec(stride=parts[0])
    if len(parts) == 2:
        return ProbeSpec(stride=parts[0], samples=parts[1])
    raise argparse.ArgumentTypeError(
        f"--probes expects STRIDE or STRIDE,SAMPLES, got {text!r}")


def _load_campaign(args) -> Campaign:
    if args.preset:
        c = preset(args.preset)
    else:
        with open(args.spec) as f:
            c = Campaign.from_dict(json.load(f))
    override = {}
    if args.seeds:
        override["seeds"] = _parse_seeds(args.seeds)
    if args.k:
        override["trees"] = tuple(int(k) for k in args.k.split(","))
    if args.backend:
        override["backend"] = args.backend
    if getattr(args, "shard", None):
        override["shard"] = args.shard
    if getattr(args, "probes", None):
        override["probes"] = _parse_probes(args.probes)
    # --plan-from-trace implies cost-modeled planning.
    if getattr(args, "plan", None):
        override["planner"] = args.plan
    elif getattr(args, "plan_from_trace", None):
        override["planner"] = "cost"
    return dataclasses.replace(c, **override) if override else c


def _cost_params(args):
    """The CostParams for a run/plan invocation: trace-calibrated with
    --plan-from-trace, else None (model defaults)."""
    if getattr(args, "plan_from_trace", None):
        from .costmodel import CostParams
        return CostParams.from_trace(args.plan_from_trace)
    return None


def cmd_run(args) -> int:
    c = _load_campaign(args)
    out = pathlib.Path(args.out) if args.out else None
    resume = args.resume
    if resume and not out:
        print("--resume requires --out (the checkpoint is the results "
              "JSONL)", file=sys.stderr)
        return 2
    store = ResultStore(out / "results.jsonl" if out else None,
                        overwrite=not resume)
    quiet = args.quiet
    level = "quiet" if quiet else ("debug" if args.verbose else "info")
    trace = TraceWriter(out / "trace.jsonl" if out else None,
                        overwrite=not resume)
    # Precedence: --no-compile-cache > --compile-cache > $REPRO_COMPILE_CACHE
    # (resolved inside compile_cache.enable) > <out>/jax-cache.
    if args.no_compile_cache:
        cache_dir = False
    elif args.compile_cache:
        cache_dir = args.compile_cache
    elif os.environ.get(compile_cache.ENV_VAR):
        cache_dir = None
    else:
        cache_dir = str(out / "jax-cache") if out else None
    run_campaign(
        c, store=store, compile_cache_dir=cache_dir,
        trace=trace, log=SweepLogger(level),
        timing_split=args.timing_split, profile_dir=args.profile,
        retry=args.retry, backoff_s=args.backoff, resume=resume,
        cost_params=_cost_params(args))
    store.close()
    trace.close()
    # Summarize the *store*, not just this invocation's new records: on
    # --resume the checkpointed prefix is part of the campaign too.
    rows = (write_summary(out / "summary.jsonl", store.records) if out
            else summarize(store.records))
    if not quiet:
        for row in rows:
            print(f"{row['scheme']:>16s} k={row['k']} {row['workload']:<22s} "
                  f"cct {row['cct_mean']:10.1f} +- {row['cct_std']:7.1f} "
                  f"(n={row['n_seeds']})  max_q {row['max_queue_max']:8.1f}")
        if out:
            print(f"wrote {out / 'results.jsonl'}, {out / 'summary.jsonl'} "
                  f"and {out / 'trace.jsonl'}")
    return 0


def cmd_plan(args) -> int:
    c = _load_campaign(args)
    p = plan(c, cost_params=_cost_params(args))
    print(p.describe())
    if p.policy is not None and p.cost is not None:
        pred = p.cost
        print(f"cost model: policy {p.policy.label!r} -- "
              f"{pred.pkt_rows_padded} padded pkt rows "
              f"(fill {pred.pkt_fill:.1%}), {pred.n_shapes} shapes, "
              f"total {pred.total:.0f} rows")
        for lbl, cost, fill in p.alternatives[:4]:
            print(f"  rejected: {lbl:<24s} cost {cost:.0f} rows "
                  f"(fill {fill:.1%})")
    for i, mega in enumerate(p.megabatches):
        print(f"dispatch {i}: engine={mega.engine} "
              f"{mega.n_points} points pad={mega.npk_pad}")
        for b in mega.members:
            fail = b.failure.label() if b.failure else "nofail"
            g = "" if b.g_converge is None else f" G={b.g_converge}"
            print(f"  {b.scheme:>16s} k={b.k} {b.load.label():<22s} "
                  f"{fail:<14s}{g} seeds={list(b.seeds)}")
    return 0


def cmd_presets(_args) -> int:
    for name in sorted(PRESETS):
        c = PRESETS[name]()
        print(f"{name:>14s}: {c.n_points:4d} points  engine={c.engine:<5s} "
              f"schemes={','.join(c.schemes)}")
    return 0


def cmd_summarize(args) -> int:
    store = ResultStore.load(args.results)
    for row in summarize(store.records):
        print(json.dumps(row, sort_keys=True))
    return 0


def cmd_report(args) -> int:
    spans = load_trace(args.trace)
    records = (ResultStore.load(args.results).records
               if args.results else None)
    bench = None
    if args.bench:
        with open(args.bench) as f:
            bench = json.load(f)
    text = render_report(spans, records, top=args.top, bench=bench)
    print(text)
    if args.out:
        p = pathlib.Path(args.out)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(text + "\n")
        print(f"wrote {p}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.sweep")
    sub = ap.add_subparsers(dest="cmd", required=True)

    def _spec_args(p):
        g = p.add_mutually_exclusive_group(required=True)
        g.add_argument("--preset", choices=sorted(PRESETS))
        g.add_argument("--spec", help="path to a Campaign JSON file")
        p.add_argument("--seeds", help="override seeds: '0:8' or '1,5,9'")
        p.add_argument("--k", help="override tree sizes: '4,8'")
        p.add_argument("--backend", choices=["auto", "xla", "pallas"])
        p.add_argument("--shard", choices=["auto", "off"],
                       help="shard fused dispatches across devices")
        p.add_argument("--plan", choices=["heuristic", "cost"],
                       help="bucket-policy planner: the fixed greedy-2x/"
                            "pow2 heuristic, or the per-campaign cost "
                            "model (repro.sweep.costmodel)")
        p.add_argument("--plan-from-trace", metavar="TRACE",
                       help="calibrate the cost model's compile charge "
                            "from a measured trace.jsonl (spans written "
                            "under --timing-split); implies --plan cost")

    p_run = sub.add_parser("run", help="execute a campaign")
    _spec_args(p_run)
    p_run.add_argument("--out", help="output dir for results/summary/trace "
                                     "JSONL")
    p_run.add_argument("--compile-cache", metavar="DIR",
                       help="persistent JAX compile cache directory "
                            "(default: <out>/jax-cache, or "
                            "$REPRO_COMPILE_CACHE)")
    p_run.add_argument("--no-compile-cache", action="store_true")
    p_run.add_argument("--quiet", action="store_true",
                       help="no progress output")
    p_run.add_argument("--verbose", "-v", action="store_true",
                       help="per-member timings and cache diagnostics "
                            "(default: one line per fused dispatch)")
    p_run.add_argument("--probes", metavar="STRIDE[,SAMPLES]",
                       help="record per-layer queue-occupancy time series "
                            "(repro.obs.probes; default 256 samples)")
    p_run.add_argument("--timing-split", action="store_true",
                       help="dispatch twice to split compile vs execute "
                            "wall time in the trace")
    p_run.add_argument("--profile", metavar="DIR",
                       help="write a jax.profiler trace to DIR")
    p_run.add_argument("--retry", type=int, default=0, metavar="N",
                       help="extra attempts per dispatch before the "
                            "degradation ladder (megabatch -> per-member "
                            "-> serial) kicks in")
    p_run.add_argument("--backoff", type=float, default=0.5, metavar="S",
                       help="base retry backoff seconds, doubled per "
                            "attempt (default 0.5)")
    p_run.add_argument("--resume", action="store_true",
                       help="treat an existing <out>/results.jsonl as a "
                            "checkpoint: skip complete dispatches, re-run "
                            "the partial tail; the finished file is byte-"
                            "identical to an uninterrupted run")
    p_run.set_defaults(fn=cmd_run)

    p_plan = sub.add_parser("plan", help="show the batched execution plan")
    _spec_args(p_plan)
    p_plan.set_defaults(fn=cmd_plan)

    p_pre = sub.add_parser("presets", help="list named campaign presets")
    p_pre.set_defaults(fn=cmd_presets)

    p_sum = sub.add_parser("summarize", help="aggregate a results.jsonl")
    p_sum.add_argument("--results", required=True)
    p_sum.set_defaults(fn=cmd_summarize)

    p_rep = sub.add_parser("report", help="render a dispatch trace into a "
                                          "cost summary")
    p_rep.add_argument("--trace", required=True, help="path to trace.jsonl")
    p_rep.add_argument("--results", help="results.jsonl (enables queue-"
                                         "trajectory sparklines when the "
                                         "campaign ran with probes)")
    p_rep.add_argument("--top", type=int, default=3,
                       help="queue trajectories to show (default 3)")
    p_rep.add_argument("--bench", help="BENCH_sweep.json: render its "
                                       "speedup_vs_* samples (ratios below "
                                       "1.0 are labeled as slowdowns)")
    p_rep.add_argument("--out", help="also write the report to this file")
    p_rep.set_defaults(fn=cmd_report)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
