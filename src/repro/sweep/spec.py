"""Declarative campaign specifications.

A :class:`Campaign` is a grid over the paper's evaluation axes -- scheme x
load x tree size x seeds x failure pattern -- plus fixed engine options.  It
is data, not code: specs round-trip through JSON (``to_dict``/``from_dict``)
so campaigns can live in files and be launched from the CLI
(``python -m repro.sweep run --spec ...``), and the named presets below cover
the paper's standing experiments (Table 2 contenders, the §6.1 theory
schemes, the Fig. 7 layer-balance study).

The grid expands to :class:`GridPoint` records; the planner
(``sweep.planner``) then groups points that share a compiled-pipeline shape
and batches replicate seeds into single vmapped executions.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, Optional, Tuple, Union

from ..core import lb_schemes as lbs
from ..faults import FaultSchedule
from ..obs.probes import ProbeSpec
from ..phases import PhaseSchedule, phases_from_dict


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """One traffic-matrix axis value (see ``net.workloads``)."""
    kind: str = "permutation"        # 'permutation' | 'all_to_all' | 'fsdp_rings'
    msg_packets: int = 256           # packets per flow (per dest for all_to_all)
    inter_pod_only: bool = False     # permutation only
    gpus_per_server: int = 8         # fsdp_rings only
    rng_seed: int = 1                # traffic-matrix randomness (not replicate seed)

    def label(self) -> str:
        """Unique within a campaign: every field that changes the traffic
        matrix appears here, since result aggregation groups by this label."""
        bits = [self.kind, f"m{self.msg_packets}"]
        if self.inter_pod_only:
            bits.append("xpod")
        if self.kind == "fsdp_rings":
            bits.append(f"g{self.gpus_per_server}")
        bits.append(f"r{self.rng_seed}")
        return "-".join(bits)

    def n_packets(self, k: int) -> int:
        """Packet count of this workload on a fat-tree of size ``k``, without
        materializing it (the planner buckets megabatch shapes by this)."""
        n_hosts = k ** 3 // 4
        if self.kind == "permutation":
            return n_hosts * self.msg_packets
        if self.kind == "all_to_all":
            return n_hosts * (n_hosts - 1) * self.msg_packets
        if self.kind == "fsdp_rings":
            return n_hosts * self.msg_packets
        raise ValueError(f"unknown workload kind {self.kind!r}")


@dataclasses.dataclass(frozen=True)
class FailureSpec:
    """Random bidirectional link failures (paper §5.2 model).

    Patterns are drawn from the counter-keyed entropy streams
    (``core.entropy``, site ``SITE_LINK_FAIL``) by default -- pure functions
    of (rng_seed, link id), so the same spec yields the same pattern
    regardless of tree-construction order.  ``legacy_rng=True`` keeps the
    old sequential ``np.random`` draws for comparing against result files
    produced before the rekey.
    """
    p_fail: float
    rng_seed: int = 42
    legacy_rng: bool = False

    def label(self) -> str:
        legacy = "-np" if self.legacy_rng else ""
        return f"fail{self.p_fail:g}-r{self.rng_seed}{legacy}"


# The failure axis accepts both models: a static FailureSpec or a dynamic
# repro.faults.FaultSchedule (mid-run link flaps; rides the fused campaign
# axis exactly like the static patterns -- the planner keys seed batches on
# the frozen value and fused dispatches never split on it).
FailureLike = Union[FailureSpec, FaultSchedule]


@dataclasses.dataclass(frozen=True)
class GridPoint:
    """One fully-specified simulation: a single cell of the campaign grid."""
    campaign: str
    k: int
    load: WorkloadSpec
    failure: Optional[FailureLike]
    scheme: str
    seed: int
    g_converge: Optional[int] = None   # loop engine routing-convergence slot
    timing: Optional[Tuple[int, int]] = None  # (prop_slots, ack_delay) sweep
    # Collective-phase schedule (repro.phases): when set, the point's
    # traffic is the schedule compiled on its tree (the load contributes
    # msg_packets scaling + traffic rng seed; its kind is ignored).
    phase: Optional[PhaseSchedule] = None

    def point_id(self) -> str:
        fail = self.failure.label() if self.failure else "nofail"
        g = "" if self.g_converge is None else f"G{self.g_converge}/"
        tm = ("" if self.timing is None
              else f"p{self.timing[0]}a{self.timing[1]}/")
        ph = "" if self.phase is None else f"{self.phase.label()}/"
        return (f"{self.campaign}/k{self.k}/{self.load.label()}/{ph}{fail}/"
                f"{g}{tm}{self.scheme}/s{self.seed}")

    def n_packets(self, k: Optional[int] = None) -> int:
        """Packet count of this point's (possibly phased) traffic on a
        fat-tree of size ``k`` (default: the point's own tree) without
        materializing it -- shared by the planner's shape bucketing, the
        cost model and the runner's fill accounting."""
        k = self.k if k is None else int(k)
        if self.phase is not None:
            return self.phase.n_packets(k, self.load.msg_packets)
        return self.load.n_packets(k)


@dataclasses.dataclass(frozen=True)
class Campaign:
    """A declarative sweep: the cartesian product of the axis tuples.

    ``engine`` selects the execution backend: ``'fast'`` (the max-plus
    engine, megabatched via one fused vmap dispatch per compiled pipeline
    shape) or ``'loop'`` (the slotted feedback engine, serial -- required for
    ACK/ECN schemes like REPS and PLB).  ``g_converge`` is a grid axis of
    routing-convergence slots for loop-engine points (None = never converge;
    fast-engine campaigns leave it at the default ``(None,)``).  Rows whose
    ``failures`` entry is a dynamic ``FaultSchedule`` ignore ``g_converge``
    entirely -- the schedule's own ``host_react``/``switch_react`` delays
    play its role, per epoch.
    ``max_slots`` is the loop-engine slot budget -- a first-class field: the
    compiled engine takes it as a per-row *operand* (so differing budgets
    share one executable; the planner's fused keys carry only its
    power-of-two bucket), and legacy specs that carried it inside
    ``loop_opts`` auto-migrate.  ``loop_opts``
    carries the remaining ``net.loopsim.LoopConfig`` overrides plus the
    special key ``rho`` (sending rate; the string ``'auto'`` means rho_max
    under the point's failure pattern, Appendix A).
    ``shard`` controls device sharding of fused megabatch dispatches:
    ``'auto'`` splits the fused axis over all visible devices via
    ``shard_map``, ``'off'`` keeps single-device vmap.
    ``probes`` opts points into carrying a downsampled per-layer
    queue-occupancy time series out of the engines (``repro.obs.probes``);
    ``None`` (the default) leaves every output bitwise-identical to a
    probe-free build.
    ``timings`` is a loop-engine grid axis of ``(prop_slots, ack_delay)``
    pairs (``None`` = the campaign's ``prop_slots`` field plus the
    ``loop_opts`` ``ack_delay``).  The engine buckets both constants to
    powers of two for its delay-ring *shapes* and indexes the rings modulo
    the real per-row values, so a timing sweep shares one compiled
    pipeline per bucket instead of compiling per point.
    ``planner`` selects the bucket policy: ``'heuristic'`` (greedy 2x
    k-buckets + pow2 packet buckets) or ``'cost'`` (the
    ``sweep.costmodel`` per-campaign cost model: candidate bucketings
    scored by padded packet rows + slot-budget waste + a per-new-shape
    compile charge, dispatches ordered largest-first).
    """
    name: str
    schemes: Tuple[str, ...]
    loads: Tuple[WorkloadSpec, ...]
    trees: Tuple[int, ...] = (8,)
    seeds: Tuple[int, ...] = (0,)
    failures: Tuple[Optional[FailureLike], ...] = (None,)
    g_converge: Tuple[Optional[int], ...] = (None,)
    prop_slots: float = 12.0
    backend: str = "auto"
    engine: str = "fast"
    shard: str = "auto"
    max_slots: int = 200_000           # loop-engine slot budget
    loop_opts: Tuple[Tuple[str, object], ...] = ()
    probes: Optional[ProbeSpec] = None  # opt-in queue time-series capture
    timings: Tuple[Optional[Tuple[int, int]], ...] = (None,)
    planner: str = "heuristic"         # 'heuristic' | 'cost'
    # Collective-phase axis (repro.phases.PhaseSchedule): ``None`` rows are
    # the static workloads; schedule rows compile phased traffic from the
    # row's load (msg_packets scaling + rng seed) and ride the fused
    # campaign axis like any other grid dimension.
    phases: Tuple[Optional[PhaseSchedule], ...] = (None,)

    def __post_init__(self):
        for s in self.schemes:
            try:
                lbs.by_name(s)
            except KeyError:
                raise KeyError(
                    f"unknown scheme {s!r} in campaign {self.name!r}; "
                    f"see repro.core.lb_schemes.by_name") from None
        if self.engine not in ("fast", "loop"):
            raise ValueError(f"unknown engine {self.engine!r}")
        if self.shard not in ("auto", "off"):
            raise ValueError(f"unknown shard policy {self.shard!r}")
        if self.planner not in ("heuristic", "cost"):
            raise ValueError(f"unknown planner {self.planner!r}")
        for ph in self.phases:
            if ph is not None and not isinstance(ph, PhaseSchedule):
                raise ValueError(f"phases entries must be PhaseSchedule or "
                                 f"None, got {type(ph).__name__}")
        for tm in self.timings:
            if tm is None:
                continue
            if self.engine != "loop":
                raise ValueError("timings is a loop-engine axis; fast-"
                                 "engine campaigns must leave it at (None,)")
            if len(tm) != 2 or int(tm[0]) < 0 or int(tm[1]) < 0:
                raise ValueError(f"bad timings entry {tm!r}: expected "
                                 f"(prop_slots, ack_delay) with both >= 0")
        # Legacy spec migration: g_converge and max_slots used to live in
        # loop_opts; the spec layer is now their single source of truth.
        opts = dict(self.loop_opts)
        if "g_converge" in opts:
            g = opts.pop("g_converge")
            if self.g_converge == (None,):
                object.__setattr__(self, "g_converge", (g,))
        if "max_slots" in opts:
            m = opts.pop("max_slots")
            if self.max_slots == 200_000:
                object.__setattr__(self, "max_slots", int(m))
        if len(opts) != len(self.loop_opts):
            object.__setattr__(self, "loop_opts", tuple(sorted(opts.items())))

    @property
    def _uniq_trees(self) -> Tuple[int, ...]:
        """The tree axis with duplicates dropped (first occurrence wins):
        a repeated ``k`` would emit the exact same grid points twice."""
        return tuple(dict.fromkeys(int(k) for k in self.trees))

    @property
    def n_points(self) -> int:
        n_sched = sum(isinstance(f, FaultSchedule) for f in self.failures)
        fail_rows = ((len(self.failures) - n_sched) * len(self.g_converge)
                     + n_sched)
        return (len(self._uniq_trees) * len(self.loads) * len(self.phases)
                * fail_rows * len(self.timings) * len(self.schemes)
                * len(self.seeds))

    def loop_options(self) -> Dict:
        return dict(self.loop_opts)

    def loop_config(self, rho: float = 1.0,
                    timing: Optional[Tuple[int, int]] = None):
        """The ``net.loopsim.LoopConfig`` this campaign's loop-engine points
        run under (``rho`` is the one per-point field; 'auto' is resolved by
        the runner; ``timing`` is a grid point's ``timings`` axis value and
        overrides the ``prop_slots``/``ack_delay`` defaults).  The planner
        keys fused loop dispatches by its static part
        (``loopsim.static_config``), so this is the single place the
        spec-to-engine translation happens."""
        from ..net import loopsim
        opts = self.loop_options()
        opts.pop("rho", None)
        prop = int(round(self.prop_slots))
        if timing is not None:
            prop = int(timing[0])
            opts["ack_delay"] = int(timing[1])
        return loopsim.LoopConfig(prop_slots=prop,
                                  rho=float(rho), max_slots=self.max_slots,
                                  **opts)

    def points(self):
        """Expand the grid in a deterministic order (seeds innermost, so
        replicate runs of one point are adjacent for the planner)."""
        for k, load, phase, failure, g, tm, scheme, seed in itertools.product(
                self._uniq_trees, self.loads, self.phases, self.failures,
                self.g_converge, self.timings, self.schemes, self.seeds):
            if isinstance(failure, FaultSchedule):
                # Schedule rows ignore the g_converge axis (their reaction
                # delays live in the schedule): emit once, at g=None,
                # instead of duplicating the point per axis value.
                if g != self.g_converge[0]:
                    continue
                g = None
            yield GridPoint(campaign=self.name, k=k, load=load,
                            failure=failure, scheme=scheme, seed=seed,
                            g_converge=g, timing=tm, phase=phase)

    # ---- JSON round-trip ---------------------------------------------------
    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["loads"] = [dataclasses.asdict(l) for l in self.loads]
        # FaultSchedule dicts carry a "kind": "schedule" discriminator so
        # from_dict can tell the two failure models apart.
        d["failures"] = [f.to_dict() if isinstance(f, FaultSchedule)
                         else (dataclasses.asdict(f) if f else None)
                         for f in self.failures]
        d["loop_opts"] = dict(self.loop_opts)
        if self.probes is not None:
            d["probes"] = dataclasses.asdict(self.probes)
        # Only-when-set (the timings/records pattern): pre-phase specs
        # round-trip byte-identically.
        if all(p is None for p in self.phases):
            d.pop("phases")
        else:
            d["phases"] = [p.to_dict() if p is not None else None
                           for p in self.phases]
        return d

    @classmethod
    def from_dict(cls, d: Dict) -> "Campaign":
        d = dict(d)
        d["schemes"] = tuple(d["schemes"])
        d["loads"] = tuple(WorkloadSpec(**l) for l in d["loads"])
        d["trees"] = tuple(d.get("trees", (8,)))
        d["seeds"] = tuple(d.get("seeds", (0,)))
        d["failures"] = tuple(
            (FaultSchedule.from_dict(f) if f.get("kind") == "schedule"
             else FailureSpec(**f)) if f else None
            for f in d.get("failures", [None]))
        d["g_converge"] = tuple(d.get("g_converge", [None]))
        d["timings"] = tuple(
            tuple(int(x) for x in tm) if tm is not None else None
            for tm in d.get("timings", [None]))
        d["shard"] = d.get("shard", "auto")
        d["phases"] = tuple(phases_from_dict(p)
                            for p in d.get("phases", [None]))
        d["loop_opts"] = tuple(sorted(d.get("loop_opts", {}).items()))
        pr = d.get("probes")
        d["probes"] = ProbeSpec(**pr) if isinstance(pr, dict) else pr
        return cls(**d)


# ---------------------------------------------------------------------------
# Named presets: the paper's standing experiments.
# ---------------------------------------------------------------------------

def _table2(trees: Tuple[int, ...] = (8,),
            seeds: Tuple[int, ...] = (0, 1, 2, 3)) -> Campaign:
    """Fast-engine Table 2 contenders + DR schemes, permutation and
    all-to-all (the Fig. 1 comparison grid)."""
    return Campaign(
        name="table2",
        schemes=("flow_ecmp", "subflow_mptcp", "host_pkt", "switch_pkt",
                 "switch_pkt_ar", "host_dr", "ofan"),
        loads=(WorkloadSpec("permutation", 256),
               WorkloadSpec("all_to_all", 8)),
        trees=trees, seeds=seeds)


def _fig1(trees: Tuple[int, ...] = (4, 6, 8),
          seeds: Tuple[int, ...] = (0, 1)) -> Campaign:
    """The Fig. 1 contender comparison swept over fat-tree size: all three
    trees pad to one k-bucket, so the whole grid runs as ONE fused dispatch
    per compiled pipeline shape (4 shapes: pre/pre, rr_reset, jsq_quant,
    ofan) -- dispatch count does not scale with the number of tree sizes."""
    return Campaign(
        name="fig1",
        schemes=("flow_ecmp", "subflow_mptcp", "host_pkt", "switch_pkt",
                 "switch_pkt_ar", "host_dr", "ofan"),
        loads=(WorkloadSpec("permutation", 64),),
        trees=trees, seeds=seeds)


def _theory(trees: Tuple[int, ...] = (8,),
            seeds: Tuple[int, ...] = (0, 1, 2, 3)) -> Campaign:
    """§6.1 simplified theory schemes over the Table-3 message-size ladder
    (inter-pod permutations; the queue-scaling-law clusters)."""
    return Campaign(
        name="theory",
        schemes=("simple_rr", "jsq", "rsq", "host_pkt", "host_dr", "ofan"),
        loads=tuple(WorkloadSpec("permutation", m, inter_pod_only=True,
                                 rng_seed=2) for m in (64, 256, 1024)),
        trees=trees, seeds=seeds)


def _layer_balance(trees: Tuple[int, ...] = (8,),
                   seeds: Tuple[int, ...] = (5,)) -> Campaign:
    """Fig. 7 worst-case per-layer overload study."""
    return Campaign(
        name="layer_balance",
        schemes=("simple_rr", "jsq", "host_pkt", "host_dr", "ofan"),
        loads=(WorkloadSpec("permutation", 256, inter_pod_only=True,
                            rng_seed=4),),
        trees=trees, seeds=seeds)


def _failures(trees: Tuple[int, ...] = (4,),
              seeds: Tuple[int, ...] = (0,)) -> Campaign:
    """Loop-engine failure study skeleton (examples/simulate_fabric.py runs
    its G-convergence sweep by widening the g_converge axis)."""
    return Campaign(
        name="failures",
        schemes=("host_pkt_ar", "switch_pkt_ar", "ofan"),
        loads=(WorkloadSpec("permutation", 64, inter_pod_only=True),),
        trees=trees, seeds=seeds,
        failures=(FailureSpec(p_fail=0.08, rng_seed=42),),
        g_converge=(0,),
        engine="loop", max_slots=20000,
        loop_opts=(("rho", "auto"), ("rto_slots", 250)))


def _flap(trees: Tuple[int, ...] = (4,),
          seeds: Tuple[int, ...] = (0, 1)) -> Campaign:
    """Robustness study: clean rows, a static random-failure pattern and a
    3-epoch mid-run link flap (down at slot 256, back up at 768) share the
    failure axis -- all three fuse onto one dispatch per compiled shape, so
    ``n_dispatches == n_shapes`` exactly as for purely static campaigns.
    Schedule rows take their convergence semantics from the reaction
    delays (host schemes re-draw labels at +64, switch-local state
    converges at +192); the ``g_converge`` axis applies to the static
    FailureSpec row only."""
    return Campaign(
        name="flap",
        schemes=("host_pkt_ar", "switch_pkt_ar", "ofan"),
        loads=(WorkloadSpec("permutation", 48, inter_pod_only=True),),
        trees=trees, seeds=seeds,
        failures=(None,
                  FailureSpec(p_fail=0.08, rng_seed=42),
                  FaultSchedule.flap(layer="ea", pod=0, i=0, j=1, t0=256,
                                     period=512, cycles=1, host_react=64,
                                     switch_react=192)),
        g_converge=(64,),
        engine="loop", max_slots=20000,
        loop_opts=(("rho", "auto"), ("rto_slots", 250)))


def _fig12(trees: Tuple[int, ...] = (8,),
           seeds: Tuple[int, ...] = (0, 1)) -> Campaign:
    """Fig. 12 SACK loss-recovery grid on the loop engine: the scheme x
    load x seed axes run as fused megabatch dispatches (host_pkt and
    host_dr share the 'pre/pre' slotted pipeline and fuse; adaptive and
    switch schemes each compile their own shape).  Sweeping ``trees``
    keeps one dispatch per shape for EVERY scheme -- switch_pkt_ar's
    in-loop JSQ randomness rides counter streams keyed on logical ids
    (``core.entropy``), so it k-buckets like the rest."""
    return Campaign(
        name="fig12",
        schemes=("host_pkt", "host_dr", "switch_pkt_ar", "host_pkt_ar",
                 "ofan"),
        loads=(WorkloadSpec("permutation", 256, rng_seed=1),),
        trees=trees, seeds=seeds,
        engine="loop", max_slots=60000,
        loop_opts=(("loss", "sack"), ("sack_thresh", 32)))


def _train_iter(trees: Tuple[int, ...] = (4,),
                seeds: Tuple[int, ...] = (0, 1),
                iterations: int = 2) -> Campaign:
    """Collective-phase training campaign: the Table-2 contender schemes
    under a DeepSeek-V3-671B-derived phase schedule (MoE dispatch/combine
    all-to-alls, the gradient all-reduce, the over-pod FSDP ring) repeated
    for ``iterations`` training steps, crossed with two message-size loads.
    The iteration-time section of ``sweep report`` reads this campaign's
    per-iteration makespans; phased points fuse exactly like static ones
    (``n_dispatches == n_shapes``)."""
    sched = PhaseSchedule.from_model("deepseek-v3-671b", ep=8, dp=8,
                                     iterations=iterations)
    return Campaign(
        name="train_iter",
        schemes=("flow_ecmp", "host_pkt", "host_dr", "ofan"),
        loads=(WorkloadSpec("permutation", 8),
               WorkloadSpec("permutation", 16)),
        trees=trees, seeds=seeds,
        phases=(sched,))


PRESETS = {
    "table2": _table2,
    "fig1": _fig1,
    "theory": _theory,
    "layer_balance": _layer_balance,
    "failures": _failures,
    "flap": _flap,
    "fig12": _fig12,
    "train_iter": _train_iter,
}


def preset(name: str, **kw) -> Campaign:
    try:
        factory = PRESETS[name]
    except KeyError:
        raise KeyError(f"unknown preset {name!r}; available: "
                       f"{', '.join(sorted(PRESETS))}") from None
    return factory(**kw)
