"""Campaign result records, JSONL persistence, and seed aggregation.

One record per grid point (scheme x load x tree x failure x seed), holding
the scalar metrics the paper's figures are built from: collective completion
time, queue maxima, per-layer waits, delivery-time percentiles, and -- for
the layer-balance study -- counts-based per-layer overload ratios.

Records are written as JSONL with sorted keys and canonical float repr, so a
re-run of the same campaign produces a byte-identical file (tested in
``tests/test_sweep.py``); summaries aggregate over the seed axis (mean/p99
CCT plus seed spread).
"""
from __future__ import annotations

import json
import pathlib
from typing import Dict, List, Optional

import numpy as np

from ..net.topology import LAYER_NAMES
from .spec import GridPoint

# Grid-point identity fields, in summary group-by order (everything but seed).
# Fast-engine records carry no g_converge, only timing-axis loop records
# carry prop_slots/ack_delay, and only collective-phase records carry
# "phases"; .get(None) keeps the others grouped.
_KEY_FIELDS = ("campaign", "k", "workload", "failure", "g_converge",
               "prop_slots", "ack_delay", "phases", "scheme")


def _phase_fields(rec: Dict, point: GridPoint, phases, done) -> None:
    """Attach the collective-phase / iteration-time fields to a record.

    Only-when-set (the timings pattern): points without a phase schedule
    add no keys, keeping pre-phase campaign files byte-identical.  ``done``
    is the per-packet completion-slot vector of the point's engine
    (fast: ``delivery``; loop: ``delivered_slot``); ``phases`` is the
    runner-cached ``repro.phases.CompiledPhases`` (None under degraded
    paths that lack it -- the identity fields still land).
    """
    if point.phase is None:
        return
    rec["phases"] = point.phase.label()
    rec["n_phases"] = int(point.phase.n_phases)
    rec["iterations"] = int(point.phase.iterations)
    if phases is None:
        return
    done = np.asarray(done, dtype=np.float64)
    comp = []
    for lo, hi, st in zip(phases.pkt_lo.tolist(), phases.pkt_hi.tolist(),
                          phases.phase_start.tolist()):
        # An empty phase (degenerate collective) completes at its start.
        comp.append(float(done[lo:hi].max()) if hi > lo else float(st))
    rec["phase_completion"] = comp
    mks = []
    for it in range(int(point.phase.iterations)):
        m = phases.iter_of == it
        if not m.any():
            continue
        end = max(c for c, sel in zip(comp, m.tolist()) if sel)
        mks.append(end - float(phases.phase_start[m].min()))
    rec["iter_makespan"] = mks
    rec["iter_time_mean"] = float(np.mean(mks)) if mks else 0.0


def point_record(point: GridPoint, res, phases=None) -> Dict:
    """Flatten one ``fastsim.FastSimResult`` into a JSON-safe record."""
    delivery = np.asarray(res.delivery)
    fcomp = np.asarray(res.flow_completion)
    rec = {
        "campaign": point.campaign,
        "k": point.k,
        "workload": point.load.label(),
        "failure": point.failure.label() if point.failure else None,
        "scheme": point.scheme,
        "seed": point.seed,
        "engine": "fast",
        "n_packets": int(delivery.shape[0]),
        "cct": float(res.cct),
        "max_queue": float(res.max_queue),
        # Zero-packet workloads (msg_packets=0, all-degenerate phases)
        # have no percentiles to take.
        "delivery_p50": float(np.percentile(delivery, 50))
        if delivery.size else 0.0,
        "delivery_p99": float(np.percentile(delivery, 99))
        if delivery.size else 0.0,
        "flow_completion_p99": float(np.percentile(fcomp, 99))
        if fcomp.size else 0.0,
    }
    for name in LAYER_NAMES:
        st = res.layers[name]
        tag = name.replace("->", "_")
        rec[f"max_queue_{tag}"] = float(st.max_queue)
        rec[f"avg_wait_{tag}"] = float(st.avg_wait)
        counts = np.asarray(st.counts)
        used = counts[counts > 0]
        if used.size and counts.sum() > 0:
            ideal = counts.sum() / counts.shape[0]
            rec[f"overload_{tag}"] = float(used.max() / ideal - 1.0)
        else:
            rec[f"overload_{tag}"] = 0.0
    _phase_fields(rec, point, phases, res.delivery)
    _attach_probe(rec, res)
    return rec


def _attach_probe(rec: Dict, res) -> None:
    """Add the opt-in queue time series to a point record.  Probes off (the
    default) adds no keys, keeping the record -- and the JSONL bytes --
    identical to a probe-free build."""
    probe = getattr(res, "probe", None)
    if probe is not None:
        rec["probe_stride"] = int(probe.stride)
        rec["probe_queue"] = np.asarray(probe.series).tolist()


def loop_point_record(point: GridPoint, res, phases=None) -> Dict:
    """Flatten one ``loopsim.LoopSimResult`` into a JSON-safe record."""
    rec = {
        "campaign": point.campaign,
        "k": point.k,
        "workload": point.load.label(),
        "failure": point.failure.label() if point.failure else None,
        "scheme": point.scheme,
        "seed": point.seed,
        "g_converge": point.g_converge,
        "engine": "loop",
        "cct": float(res.cct_slots),
        "cct_acked": float(res.cct_acked_slots),
        "max_queue": float(res.max_queue),
        "avg_queue": float(res.avg_queue),
        "drops": int(res.drops),
        "retransmissions": int(res.retransmissions),
        "finished": bool(res.finished),
        "mean_cwnd": float(res.mean_cwnd),
    }
    if point.timing is not None:
        # Timing-axis points record their (prop_slots, ack_delay) pair;
        # points off the axis add no keys, keeping pre-axis campaign files
        # byte-identical.
        rec["prop_slots"] = int(point.timing[0])
        rec["ack_delay"] = int(point.timing[1])
    _phase_fields(rec, point, phases, res.delivered_slot)
    _attach_probe(rec, res)
    return rec


def _canon(x):
    """JSON-canonical values: floats through repr-stable float(), numpy
    scalars unboxed, arrays/containers recursed (probe series are nested
    lists)."""
    if isinstance(x, (np.floating,)):
        return float(x)
    if isinstance(x, (np.integer,)):
        return int(x)
    if isinstance(x, np.ndarray):
        return [_canon(v) for v in x.tolist()]
    if isinstance(x, (list, tuple)):
        return [_canon(v) for v in x]
    if isinstance(x, dict):
        return {k: _canon(v) for k, v in x.items()}
    return x


def encode_record(rec: Dict) -> str:
    return json.dumps({k: _canon(v) for k, v in rec.items()}, sort_keys=True)


class ResultStore:
    """Append-only JSONL store for point records, with deterministic bytes.

    ``path=None`` keeps records in memory only (used by benchmarks/tests
    that aggregate without persisting).

    ``overwrite=False`` turns an existing file into a resume checkpoint:
    complete lines load back into ``records`` (a torn final line -- the
    half-written tail of a SIGKILLed run; append flushes per record, so at
    most one line can be torn -- is discarded and truncated off the file)
    and subsequent appends extend the file.  Because encoding is
    canonical, a campaign finished via resume produces a byte-identical
    file to one that never crashed (``tests/test_faults.py``).
    """

    def __init__(self, path: Optional[str] = None, overwrite: bool = True):
        self.path = pathlib.Path(path) if path else None
        self.records: List[Dict] = []
        # per-dispatch wall times, filled by the runner: list of
        # (SeedBatch, seconds).  Kept off the JSONL so result files stay
        # byte-deterministic; benchmarks read it for per-scheme timings.
        self.timings: List = []
        self._fh = None
        if self.path:
            self.path.parent.mkdir(parents=True, exist_ok=True)
            if self.path.exists():
                if overwrite:
                    self.path.unlink()
                else:
                    self._load_checkpoint()

    def _load_checkpoint(self) -> None:
        """Read back every complete line; drop (and truncate off disk) a
        torn tail line lacking its newline or failing to decode."""
        raw = self.path.read_text(errors="replace")
        lines = raw.split("\n")
        kept: List[Dict] = []
        for i, line in enumerate(lines):
            if not line.strip():
                continue
            if i == len(lines) - 1:      # no trailing newline: torn write
                break
            try:
                kept.append(json.loads(line))
            except json.JSONDecodeError:
                break
        self.records = kept
        self._rewrite()

    def _rewrite(self) -> None:
        self.close()
        with self.path.open("w") as f:
            for rec in self.records:
                f.write(encode_record(rec) + "\n")

    def truncate(self, n: int) -> None:
        """Keep only the first ``n`` records (resume: drop the records of a
        partially-recorded dispatch so it re-runs whole)."""
        if n >= len(self.records):
            return
        del self.records[n:]
        if self.path:
            self._rewrite()

    def append(self, rec: Dict) -> None:
        self.records.append(rec)
        if self.path:
            if self._fh is None:
                self._fh = self.path.open("a")
            self._fh.write(encode_record(rec) + "\n")
            self._fh.flush()    # every appended record is durable on return

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    @classmethod
    def load(cls, path: str) -> "ResultStore":
        store = cls(None)
        with pathlib.Path(path).open() as f:
            store.records = [json.loads(line) for line in f if line.strip()]
        return store


def summarize(records: List[Dict]) -> List[Dict]:
    """Aggregate over the seed axis: one summary row per grid point identity.

    Reports mean and p99 CCT, the max-over-seeds queue maximum, and the seed
    spread (std / min / max of CCT) that the paper's error bars show.

    Tolerant of schema growth: records missing the core metrics (e.g. rows
    from a future producer, or non-point rows mixed into a shared file) are
    skipped rather than KeyError'd, and extra keys -- probe series, trace
    cross-references -- are ignored.
    """
    groups: Dict[tuple, List[Dict]] = {}
    order: List[tuple] = []
    for r in records:
        if "cct" not in r:
            continue
        key = tuple(r.get(k) for k in _KEY_FIELDS)
        if key not in groups:
            groups[key] = []
            order.append(key)
        groups[key].append(r)

    out = []
    for key in order:
        rs = groups[key]
        cct = np.array([r["cct"] for r in rs], dtype=np.float64)
        mq = np.array([r.get("max_queue", 0.0) for r in rs],
                      dtype=np.float64)
        row = dict(zip(_KEY_FIELDS, key))
        row.update({
            "n_seeds": len(rs),
            "cct_mean": float(cct.mean()),
            "cct_p99": float(np.percentile(cct, 99)),
            "cct_std": float(cct.std()),
            "cct_min": float(cct.min()),
            "cct_max": float(cct.max()),
            "max_queue_mean": float(mq.mean()),
            "max_queue_max": float(mq.max()),
        })
        # Iteration time (collective-phase points; only-when-set).
        its = [r["iter_time_mean"] for r in rs if "iter_time_mean" in r]
        if its:
            row["iter_time_mean"] = float(np.mean(its))
            row["iter_time_max"] = float(np.max(its))
        out.append(row)
    return out


def write_summary(path: str, records: List[Dict]) -> List[Dict]:
    rows = summarize(records)
    p = pathlib.Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    with p.open("w") as f:
        for row in rows:
            f.write(encode_record(row) + "\n")
    return rows
