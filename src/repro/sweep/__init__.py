"""Megabatched scenario-campaign engine.

Declarative sweeps over the paper's evaluation axes (LB scheme x load x
fat-tree size x replicate seeds x failure patterns x routing convergence)
executed with ONE fused, jitted dispatch per compiled pipeline shape: every
scheme/load/failure/seed cell that lowers to the same pipeline stacks onto a
single vmapped batch axis (``shard_map``-sharded across devices when more
than one is visible), instead of a Python loop of per-point
``fastsim.simulate`` calls.

    from repro import sweep

    c = sweep.preset("theory", seeds=tuple(range(8)))
    records, _ = sweep.run_campaign(c, store=sweep.ResultStore("out.jsonl"))
    for row in sweep.summarize(records):
        print(row["scheme"], row["cct_mean"], row["cct_std"])

CLI: ``python -m repro.sweep run --preset theory --out runs/theory``.

Observability (``repro.obs``, re-exported here): ``run_campaign`` can emit a
versioned JSONL dispatch trace (``trace=TraceWriter(...)``), log one line
per fused dispatch (``log=SweepLogger(...)``), and -- with
``Campaign.probes=ProbeSpec(...)`` -- carry per-layer queue-occupancy time
series out of the engines.  ``python -m repro.sweep report`` renders a trace
into a cost summary.
"""
from ..obs import (ProbeSpec, QueueProbe, SweepLogger, TraceWriter,
                   load_trace, render_report, strip_timing)
from .spec import (Campaign, FailureSpec, GridPoint, PRESETS, WorkloadSpec,
                   preset)
from .planner import MegaBatch, Plan, SeedBatch, bucket_packets, plan
from .costmodel import (BucketPolicy, CostParams, PlanCost,
                        candidate_policies, choose_policy, evaluate_policy)
from .results import (ResultStore, encode_record, loop_point_record,
                      point_record, summarize, write_summary)
from .runner import build_links, build_workload, run_campaign
from . import compile_cache

__all__ = [
    "Campaign", "FailureSpec", "GridPoint", "PRESETS", "WorkloadSpec",
    "preset", "MegaBatch", "Plan", "SeedBatch", "bucket_packets", "plan",
    "BucketPolicy", "CostParams", "PlanCost", "candidate_policies",
    "choose_policy", "evaluate_policy",
    "ResultStore", "encode_record", "loop_point_record", "point_record",
    "summarize", "write_summary", "build_links", "build_workload",
    "run_campaign", "compile_cache",
    "ProbeSpec", "QueueProbe", "SweepLogger", "TraceWriter",
    "load_trace", "render_report", "strip_timing",
]
