"""Persistent JAX compilation cache for repeat campaign invocations.

The megabatch runner already amortizes jit compiles *within* a process (one
compile per pipeline shape); this module makes them survive *across*
processes: compiled executables are written to an on-disk cache keyed by the
XLA computation fingerprint -- which for this engine is exactly the pipeline
shape (tree size, scheme modes, bucketed packet count, JSQ padding, backend,
device mesh) -- so re-running a campaign, or running a different campaign
whose grid lands in the same shape buckets, skips compilation entirely.

The cache location, in precedence order:

1. an explicit path (``run_campaign(compile_cache=...)`` or the CLI's
   ``--compile-cache``);
2. the ``REPRO_COMPILE_CACHE`` environment variable;
3. for the CLI ``run`` command with ``--out``, ``<out>/jax-cache``.

Enabling is best-effort: on JAX builds without persistent-cache support the
engine silently runs with in-process caching only.
"""
from __future__ import annotations

import os
from typing import Optional

ENV_VAR = "REPRO_COMPILE_CACHE"
_enabled_dir: Optional[str] = None


def enable(path: Optional[str] = None) -> Optional[str]:
    """Point JAX's persistent compilation cache at ``path`` (or the
    ``REPRO_COMPILE_CACHE`` env var).  Returns the active cache directory,
    or None when no path was given or the JAX build lacks support.

    Thresholds are dropped to zero so even the small CPU-CI pipelines cache;
    entries are content-addressed, so sharing one directory across campaigns
    and topologies is safe.
    """
    global _enabled_dir
    path = path or os.environ.get(ENV_VAR)
    if not path:
        return None
    if _enabled_dir == str(path):
        return _enabled_dir
    try:
        import jax
        os.makedirs(path, exist_ok=True)
        jax.config.update("jax_compilation_cache_dir", str(path))
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
        # JAX initializes its cache singleton lazily on the first compile; if
        # anything compiled before enable(), that singleton was pinned to
        # "no cache" and config updates alone would be ignored.
        from jax.experimental.compilation_cache import (
            compilation_cache as _cc)
        _cc.reset_cache()
    except Exception:
        return None
    _enabled_dir = str(path)
    return _enabled_dir


def active_dir() -> Optional[str]:
    return _enabled_dir
