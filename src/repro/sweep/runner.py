"""Campaign execution: one fused megabatch dispatch per compiled shape.

The runner walks the planner's megabatch list, memoizing topologies,
workloads and failure states across batches, and executes

  * fast-engine megabatches as a single ``fastsim.simulate_megabatch`` call:
    every member (scheme, load, failure, seed) cell stacks onto one fused,
    jitted batch axis -- padded to the megabatch's bucketed packet shape and,
    when several devices are visible (``Campaign.shard='auto'``),
    ``shard_map``-sharded across them;
  * loop-engine megabatches (ACK/ECN schemes) as a single
    ``loopsim.simulate_megabatch`` call: the scheme/load/failure/seed cells
    of one compiled slotted engine -- plus the ``g_converge`` and rho axes,
    which ride as per-row operands -- fuse the same way.

Each grid point yields one record in the :class:`~repro.sweep.results
.ResultStore`; per-point results are bitwise-identical to standalone
``fastsim.simulate`` calls with the same seeds (tested in
``tests/test_sweep.py``).  Pass ``compile_cache=<dir>`` (or set
``REPRO_COMPILE_CACHE``) to persist compiled pipelines across invocations.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from ..net.topology import FatTree, LinkState, rho_max
from ..net import workloads, fastsim, loopsim
from ..core import lb_schemes as lbs
from . import compile_cache
from .planner import MegaBatch, SeedBatch, plan
from .results import ResultStore, loop_point_record, point_record
from .spec import Campaign, FailureSpec, WorkloadSpec


def build_workload(tree: FatTree, load: WorkloadSpec):
    if load.kind == "permutation":
        return workloads.permutation(tree, load.msg_packets,
                                     np.random.default_rng(load.rng_seed),
                                     inter_pod_only=load.inter_pod_only)
    if load.kind == "all_to_all":
        return workloads.all_to_all(tree, load.msg_packets)
    if load.kind == "fsdp_rings":
        return workloads.fsdp_rings(tree, load.gpus_per_server,
                                    load.msg_packets,
                                    np.random.default_rng(load.rng_seed))
    raise ValueError(f"unknown workload kind {load.kind!r}")


def build_links(tree: FatTree,
                failure: Optional[FailureSpec]) -> Optional[LinkState]:
    """The campaign interpretation of a FailureSpec (None = all links up)."""
    if failure is None:
        return None
    return LinkState.random_failures(tree, failure.p_fail,
                                     np.random.default_rng(failure.rng_seed))


class _Cache:
    """Memoized topology / workload / failure-state construction."""

    def __init__(self):
        self.trees: Dict[int, FatTree] = {}
        self.wls: Dict[Tuple, object] = {}
        self.links: Dict[Tuple, LinkState] = {}
        self.rhos: Dict[Tuple, float] = {}

    def tree(self, k: int) -> FatTree:
        if k not in self.trees:
            self.trees[k] = FatTree(k)
        return self.trees[k]

    def workload(self, k: int, load: WorkloadSpec):
        key = (k, load)
        if key not in self.wls:
            self.wls[key] = build_workload(self.tree(k), load)
        return self.wls[key]

    def link_state(self, k: int,
                   failure: Optional[FailureSpec]) -> Optional[LinkState]:
        if failure is None:
            return None
        key = (k, failure)
        if key not in self.links:
            self.links[key] = build_links(self.tree(k), failure)
        return self.links[key]

    def rho_auto(self, k: int, load: WorkloadSpec,
                 failure: Optional[FailureSpec]) -> float:
        key = (k, load, failure)
        if key not in self.rhos:
            links = self.link_state(k, failure)
            wl = self.workload(k, load)
            self.rhos[key] = (rho_max(self.tree(k), links, wl.flow_src,
                                      wl.flow_dst)
                              if links is not None else 1.0)
        return self.rhos[key]


def _run_fast_mega(mega: MegaBatch, campaign: Campaign, cache: _Cache):
    """One fused dispatch for all member batches; returns results per member."""
    items = [(cache.tree(b.k), cache.workload(b.k, b.load),
              lbs.by_name(b.scheme), b.seeds,
              cache.link_state(b.k, b.failure)) for b in mega.members]
    n_shards = "auto" if campaign.shard == "auto" else 1
    return fastsim.simulate_megabatch(items, prop_slots=campaign.prop_slots,
                                      backend=campaign.backend,
                                      npk_pad=mega.npk_pad,
                                      n_shards=n_shards, k_pad=mega.k_pad)


def _run_loop_mega(mega: MegaBatch, campaign: Campaign, cache: _Cache):
    """One fused loop-engine dispatch for all member batches; rho (possibly
    rho_max under each member's failure pattern) and g_converge are per-row
    operands, so the whole grid slice shares one compiled engine."""
    rho_opt = campaign.loop_options().get("rho", 1.0)
    items = []
    for b in mega.members:
        rho = (cache.rho_auto(b.k, b.load, b.failure) if rho_opt == "auto"
               else float(rho_opt))
        items.append((cache.tree(b.k), cache.workload(b.k, b.load),
                      lbs.by_name(b.scheme), campaign.loop_config(rho),
                      b.seeds, cache.link_state(b.k, b.failure),
                      b.g_converge))
    n_shards = "auto" if campaign.shard == "auto" else 1
    return loopsim.simulate_megabatch(items, npk_pad=mega.npk_pad,
                                      n_shards=n_shards, k_pad=mega.k_pad)


def run_campaign(campaign: Campaign, store: Optional[ResultStore] = None,
                 keep_full: bool = False,
                 progress: Optional[Callable[[str], None]] = None,
                 compile_cache_dir: Optional[str] = None):
    """Execute a campaign; returns (records, full_results).

    ``records`` is the flat list of per-point dicts (also appended to
    ``store`` when given, in grid-plan order).  ``full_results`` maps
    ``GridPoint -> FastSimResult/LoopSimResult`` when ``keep_full=True``
    (tests and figure code that need raw delivery vectors), else ``{}``.
    ``compile_cache_dir`` (or the ``REPRO_COMPILE_CACHE`` env var) enables
    the persistent JAX compilation cache, so repeat invocations skip
    compiles entirely; pass ``False`` to keep it off even when the env var
    is set.
    """
    cache_dir = (None if compile_cache_dir is False
                 else compile_cache.enable(compile_cache_dir))
    p = plan(campaign)
    if progress:
        progress(p.describe())
        if cache_dir:
            progress(f"persistent compile cache: {cache_dir}")
    cache = _Cache()
    store = store if store is not None else ResultStore(None)
    n_before = len(store.records)   # store may be shared across campaigns
    full: Dict = {}
    t0 = time.perf_counter()
    for mega in p.megabatches:
        tb = time.perf_counter()
        if mega.engine == "loop":
            per_member = _run_loop_mega(mega, campaign, cache)
            to_record = loop_point_record
        else:
            per_member = _run_fast_mega(mega, campaign, cache)
            to_record = point_record
        secs = time.perf_counter() - tb
        for batch, results in zip(mega.members, per_member):
            for point, res in zip(batch.points(), results):
                store.append(to_record(point, res))
                if keep_full:
                    full[point] = res
            # Apportion the fused dispatch's wall time over members by their
            # share of fused points, so per-scheme timing summaries stay
            # meaningful.
            store.timings.append((batch, secs * len(batch.seeds)
                                  / max(mega.n_points, 1)))
            if progress:
                progress(f"  {batch.scheme:>16s} k={batch.k} "
                         f"{batch.load.label():<22s} x{len(batch.seeds)} "
                         f"seeds: {store.timings[-1][1]:.2f}s")
    if progress:
        progress(f"campaign {campaign.name!r} done in "
                 f"{time.perf_counter() - t0:.2f}s "
                 f"({p.n_points} points, {p.n_dispatches} dispatches, "
                 f"{p.n_shapes} shapes)")
    return store.records[n_before:], full
