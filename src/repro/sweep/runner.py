"""Campaign execution: one fused megabatch dispatch per compiled shape.

The runner walks the planner's megabatch list, memoizing topologies,
workloads and failure states across batches, and executes

  * fast-engine megabatches as a single ``fastsim.simulate_megabatch`` call:
    every member (scheme, load, failure, seed) cell stacks onto one fused,
    jitted batch axis -- padded to the megabatch's bucketed packet shape and,
    when several devices are visible (``Campaign.shard='auto'``),
    ``shard_map``-sharded across them;
  * loop-engine megabatches (ACK/ECN schemes) as a single
    ``loopsim.simulate_megabatch`` call: the scheme/load/failure/seed cells
    of one compiled slotted engine -- plus the ``g_converge`` and rho axes,
    which ride as per-row operands -- fuse the same way.

Each grid point yields one record in the :class:`~repro.sweep.results
.ResultStore`; per-point results are bitwise-identical to standalone
``fastsim.simulate`` calls with the same seeds (tested in
``tests/test_sweep.py``).  Pass ``compile_cache=<dir>`` (or set
``REPRO_COMPILE_CACHE``) to persist compiled pipelines across invocations.

Telemetry (``repro.obs``): every run can emit a versioned JSONL dispatch
trace (``trace=TraceWriter(...)``) -- one span per fused dispatch carrying
the member population, padding-fill ratios, device fill, wall seconds and
compile-cache state -- and logs through a :class:`~repro.obs.log
.SweepLogger` (default one line per dispatch).  Both are pure observers:
with them off (the defaults) the runner's outputs are byte-identical to the
pre-telemetry runner (tested in ``tests/test_obs.py``).
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from ..net.topology import FatTree, LinkState, rho_max
from ..net import workloads, fastsim, loopsim
from ..core import lb_schemes as lbs
from ..core.retry import retry_call
from ..faults import FaultSchedule
from ..obs.log import SweepLogger, dispatch_line
from ..obs.probes import probe_shape
from ..obs.trace import TraceWriter
from . import compile_cache
from .planner import MegaBatch, SeedBatch, plan
from .results import ResultStore, loop_point_record, point_record
from .spec import Campaign, FailureSpec, GridPoint, WorkloadSpec


def build_workload(tree: FatTree, load: WorkloadSpec):
    if load.kind == "permutation":
        return workloads.permutation(tree, load.msg_packets,
                                     np.random.default_rng(load.rng_seed),
                                     inter_pod_only=load.inter_pod_only)
    if load.kind == "all_to_all":
        return workloads.all_to_all(tree, load.msg_packets)
    if load.kind == "fsdp_rings":
        return workloads.fsdp_rings(tree, load.gpus_per_server,
                                    load.msg_packets,
                                    np.random.default_rng(load.rng_seed))
    raise ValueError(f"unknown workload kind {load.kind!r}")


def build_links(tree: FatTree,
                failure: Optional[FailureSpec]) -> Optional[LinkState]:
    """The campaign interpretation of a FailureSpec (None = all links up):
    counter-keyed draws by default, the old sequential ``np.random`` stream
    when the spec pins ``legacy_rng``."""
    if failure is None:
        return None
    if failure.legacy_rng:
        return LinkState.random_failures(
            tree, failure.p_fail, np.random.default_rng(failure.rng_seed))
    return LinkState.random_failures(tree, failure.p_fail,
                                     seed=failure.rng_seed)


class _Cache:
    """Memoized topology / workload / failure-state construction."""

    def __init__(self):
        self.trees: Dict[int, FatTree] = {}
        self.wls: Dict[Tuple, object] = {}
        self.cps: Dict[Tuple, object] = {}
        self.links: Dict[Tuple, LinkState] = {}
        self.rhos: Dict[Tuple, float] = {}

    def tree(self, k: int) -> FatTree:
        if k not in self.trees:
            self.trees[k] = FatTree(k)
        return self.trees[k]

    def compiled_phases(self, k: int, load: WorkloadSpec, phase):
        """The ``repro.phases.CompiledPhases`` of a phased point (its fused
        workload plus the per-phase bookkeeping the records need)."""
        key = (k, load, phase)
        if key not in self.cps:
            self.cps[key] = phase.compile(self.tree(k), load.msg_packets,
                                          rng_seed=load.rng_seed)
        return self.cps[key]

    def workload(self, k: int, load: WorkloadSpec, phase=None):
        if phase is not None:
            return self.compiled_phases(k, load, phase).workload
        key = (k, load)
        if key not in self.wls:
            self.wls[key] = build_workload(self.tree(k), load)
        return self.wls[key]

    def link_state(self, k: int,
                   failure: Optional[FailureSpec]) -> Optional[LinkState]:
        """Static link state for FailureSpec rows.  FaultSchedule rows get
        None: the engines compile the schedule's epoch stack themselves."""
        if failure is None or isinstance(failure, FaultSchedule):
            return None
        key = (k, failure)
        if key not in self.links:
            self.links[key] = build_links(self.tree(k), failure)
        return self.links[key]

    def rho_links(self, k: int, failure) -> Optional[LinkState]:
        """The link state ``rho='auto'`` resolves against.  For dynamic
        schedules this is deterministically the *epoch-0* pattern: the
        sending rate is fixed before the collective starts, when only the
        base failure state is observable."""
        if isinstance(failure, FaultSchedule):
            key = (k, failure, "ep0")
            if key not in self.links:
                self.links[key] = failure.compile(self.tree(k)).links[0]
            links = self.links[key]
            return links if links.any_failure() else None
        return self.link_state(k, failure)

    def rho_auto(self, k: int, load: WorkloadSpec, failure,
                 phase=None) -> float:
        key = (k, load, failure, phase)
        if key not in self.rhos:
            links = self.rho_links(k, failure)
            wl = self.workload(k, load, phase)
            self.rhos[key] = (rho_max(self.tree(k), links, wl.flow_src,
                                      wl.flow_dst)
                              if links is not None else 1.0)
        return self.rhos[key]


def _fault_of(b: SeedBatch):
    """The dynamic-schedule item field: the failure itself for FaultSchedule
    rows (the engines compile the epoch stack), None for static rows."""
    return b.failure if isinstance(b.failure, FaultSchedule) else None


def _run_fast_mega(mega: MegaBatch, campaign: Campaign, cache: _Cache):
    """One fused dispatch for all member batches; returns results per member."""
    items = [(cache.tree(b.k), cache.workload(b.k, b.load, b.phase),
              lbs.by_name(b.scheme), b.seeds,
              cache.link_state(b.k, b.failure), _fault_of(b))
             for b in mega.members]
    n_shards = "auto" if campaign.shard == "auto" else 1
    return fastsim.simulate_megabatch(items, prop_slots=campaign.prop_slots,
                                      backend=campaign.backend,
                                      npk_pad=mega.npk_pad,
                                      n_shards=n_shards, k_pad=mega.k_pad,
                                      probes=campaign.probes)


def _run_loop_mega(mega: MegaBatch, campaign: Campaign, cache: _Cache):
    """One fused loop-engine dispatch for all member batches; rho (possibly
    rho_max under each member's failure pattern) and g_converge are per-row
    operands, so the whole grid slice shares one compiled engine.  Schedule
    rows carry ``g_converge=None`` from the grid (``Campaign.points``):
    their reaction delays come from the schedule itself."""
    rho_opt = campaign.loop_options().get("rho", 1.0)
    items = []
    for b in mega.members:
        rho = (cache.rho_auto(b.k, b.load, b.failure, b.phase)
               if rho_opt == "auto" else float(rho_opt))
        items.append((cache.tree(b.k), cache.workload(b.k, b.load, b.phase),
                      lbs.by_name(b.scheme),
                      campaign.loop_config(rho, timing=b.timing),
                      b.seeds, cache.link_state(b.k, b.failure),
                      b.g_converge, _fault_of(b)))
    n_shards = "auto" if campaign.shard == "auto" else 1
    return loopsim.simulate_megabatch(items, npk_pad=mega.npk_pad,
                                      n_shards=n_shards, k_pad=mega.k_pad,
                                      probes=campaign.probes)


def _probe_field(campaign: Campaign):
    stride, samples = probe_shape(campaign.probes)
    return [stride, samples] if samples else None


def _compile_misses() -> int:
    """Total in-process compile-cache misses across both engines; the delta
    around a dispatch distinguishes a fresh compile from a cache hit."""
    return (fastsim._build_run.cache_info().misses
            + loopsim._compiled.cache_info().misses)


def _cache_files(cache_dir) -> int:
    if not cache_dir:
        return 0
    try:
        import pathlib
        return sum(1 for f in pathlib.Path(cache_dir).rglob("*")
                   if f.is_file())
    except OSError:
        return 0


def _dispatch_span(idx: int, mega: MegaBatch, campaign: Campaign,
                   n_shards_pol, devices: int) -> Dict:
    """The deterministic part of a dispatch span: member population and
    padding accounting, computable before execution."""
    rows = mega.n_points
    n_shards = (max(1, min(devices, rows))
                if n_shards_pol == "auto" else 1)
    rows_padded = -(-rows // n_shards) * n_shards
    pkt_rows_real = sum(b.n_packets(b.k) * len(b.seeds)
                        for b in mega.members)
    pkt_rows_padded = rows_padded * mega.npk_pad
    span = {
        "kind": "dispatch",
        "campaign": campaign.name,
        "dispatch": idx,
        "engine": mega.engine,
        "key": repr(mega.key),
        "n_members": len(mega.members),
        "n_points": rows,
        "schemes": sorted({b.scheme for b in mega.members}),
        "trees": sorted({b.k for b in mega.members}),
        "k_pad": mega.k_pad,
        "npk_pad": mega.npk_pad,
        "pkt_rows_real": pkt_rows_real,
        "pkt_rows_padded": pkt_rows_padded,
        "pkt_fill": pkt_rows_real / max(pkt_rows_padded, 1),
        "rows_padded": rows_padded,
        "row_fill": rows / max(rows_padded, 1),
        "n_shards": n_shards,
        "devices": devices,
        "probes": _probe_field(campaign),
    }
    if mega.engine == "loop":
        span["slot_budget"] = int(campaign.max_slots)
        from ..kernels.slot_step import ops as _slot
        span["impl"] = _slot.resolve_impl(campaign.loop_config().impl)
    # Collective-phase members (only-when-set: phase-free campaigns keep
    # byte-identical spans): which schedules ride this dispatch and how
    # many of its fused points are phased.
    phased = [b for b in mega.members if b.phase is not None]
    if phased:
        span["phases"] = sorted({b.phase.label() for b in phased})
        span["phase_points"] = sum(len(b.seeds) for b in phased)
        span["phase_instances"] = max(b.phase.n_instances for b in phased)
    return span


def _point_key(point: GridPoint) -> Tuple:
    """Record-identity tuple of a grid point, matching :func:`_record_key`
    on the record the runner would write for it."""
    tm = point.timing if point.timing is not None else (None, None)
    return (point.campaign, point.k, point.load.label(),
            point.failure.label() if point.failure else None,
            point.scheme, point.seed, point.g_converge,
            int(tm[0]) if tm[0] is not None else None,
            int(tm[1]) if tm[1] is not None else None,
            point.phase.label() if point.phase is not None else None)


def _record_key(rec: Dict) -> Tuple:
    # Fast-engine records carry no g_converge field; .get(None) matches the
    # fast-campaign grid's g_converge=None axis value.  Likewise
    # prop_slots/ack_delay appear only on timing-axis loop records and
    # "phases" only on collective-phase records (pre-phase results.jsonl
    # files resume byte-identically).
    return (rec.get("campaign"), rec.get("k"), rec.get("workload"),
            rec.get("failure"), rec.get("scheme"), rec.get("seed"),
            rec.get("g_converge"), rec.get("prop_slots"),
            rec.get("ack_delay"), rec.get("phases"))


def _run_with_recovery(idx: int, mega: MegaBatch, campaign: Campaign,
                       cache: _Cache, run: Callable, *, retry: int,
                       backoff_s: float, sleep: Callable,
                       log: SweepLogger) -> Tuple[list, List[Dict]]:
    """Execute one fused dispatch with bounded retry and the degradation
    ladder: whole megabatch -> per-member dispatches -> serial per-point.

    Returns (per_member, spans): ``per_member`` aligns with
    ``mega.members``, each entry a per-seed result list in which points
    that failed terminally are None (they yield no records -- the error
    spans are their trace).  ``spans`` are the retry/error/degrade spans
    to emit, in event order.
    """
    spans: List[Dict] = []

    def _base(**kw) -> Dict:
        return {"campaign": campaign.name, "dispatch": idx, **kw}

    def _attempt(fn, stage, **ctx):
        """retry_call around one ladder rung; returns (value, ok)."""
        def on_retry(attempt, e, delay):
            spans.append(_base(kind="retry", stage=stage, attempt=attempt,
                               error=repr(e), backoff_s=delay, **ctx))
            log.info(f"dispatch {idx} [{stage}] attempt {attempt} failed: "
                     f"{e!r}; backing off {delay:.2f}s")
        try:
            return retry_call(fn, max_retries=retry, backoff_s=backoff_s,
                              sleep=sleep, on_retry=on_retry), True
        except Exception as e:  # noqa: BLE001 -- degrade, don't die
            spans.append(_base(kind="error", stage=stage, error=repr(e),
                               **ctx))
            log.info(f"dispatch {idx} [{stage}] failed terminally: {e!r}")
            return None, False

    out, ok = _attempt(lambda: run(mega, campaign, cache), "megabatch")
    if ok:
        return out, spans

    # Rung 2: one dispatch per member batch (halves the blast radius of a
    # compile/OOM failure: a poisoned member no longer sinks its siblings).
    per_member: list = []
    for m, b in enumerate(mega.members):
        sub = MegaBatch(key=mega.key, members=[b])
        out, ok = _attempt(lambda sub=sub: run(sub, campaign, cache)[0],
                           "member", member=m, scheme=b.scheme)
        if ok:
            spans.append(_base(kind="degrade", stage="member", member=m,
                               scheme=b.scheme))
            per_member.append(out)
            continue
        # Rung 3: serial per-point; surviving seeds still record.
        results = []
        for s in b.seeds:
            one = MegaBatch(key=mega.key,
                            members=[dataclasses.replace(b, seeds=(s,))])
            res, ok = _attempt(lambda one=one: run(one, campaign, cache)[0][0],
                               "point", member=m, scheme=b.scheme, seed=s)
            results.append(res if ok else None)
        spans.append(_base(kind="degrade", stage="serial", member=m,
                           scheme=b.scheme,
                           failed=sum(r is None for r in results)))
        per_member.append(results)
    return per_member, spans


def run_campaign(campaign: Campaign, store: Optional[ResultStore] = None,
                 keep_full: bool = False,
                 progress: Optional[Callable[[str], None]] = None,
                 compile_cache_dir: Optional[str] = None,
                 trace: Optional[TraceWriter] = None,
                 log: Optional[SweepLogger] = None,
                 timing_split: bool = False,
                 profile_dir: Optional[str] = None,
                 retry: int = 0, backoff_s: float = 0.5,
                 sleep: Callable[[float], None] = time.sleep,
                 resume: bool = False,
                 cost_params=None):
    """Execute a campaign; returns (records, full_results).

    ``records`` is the flat list of per-point dicts (also appended to
    ``store`` when given, in grid-plan order).  ``full_results`` maps
    ``GridPoint -> FastSimResult/LoopSimResult`` when ``keep_full=True``
    (tests and figure code that need raw delivery vectors), else ``{}``.
    ``compile_cache_dir`` (or the ``REPRO_COMPILE_CACHE`` env var) enables
    the persistent JAX compilation cache, so repeat invocations skip
    compiles entirely; pass ``False`` to keep it off even when the env var
    is set.

    Observability (all optional, all pure observers):

    * ``trace`` -- a :class:`~repro.obs.trace.TraceWriter`; the runner emits
      one plan span, one span per fused dispatch and one campaign bookend.
    * ``log`` -- a :class:`~repro.obs.log.SweepLogger`; defaults to quiet
      when neither ``log`` nor ``progress`` is given.  The legacy
      ``progress`` callable maps to a debug-level logger with ``progress``
      as its sink, reproducing the old per-member output verbatim.
    * ``timing_split`` -- dispatch twice (second call hits the in-process
      compile caches and returns identical results) and report
      ``compile_s`` / ``execute_s`` separately in the trace.
    * ``profile_dir`` -- wrap execution in ``jax.profiler.trace`` for
      TensorBoard-grade timelines (skipped with a log line if the profiler
      is unavailable on this backend).

    Robustness:

    * ``retry`` / ``backoff_s`` -- each dispatch (and each rung of the
      degradation ladder below it) gets ``retry`` extra attempts with
      exponential backoff ``backoff_s * 2**attempt`` before degrading:
      whole megabatch -> per-member dispatches -> serial per-point.  Points
      that fail terminally yield error spans instead of records; the
      campaign keeps going.  ``sleep`` is injectable for tests.
    * ``resume`` -- treat ``store``'s existing records as a checkpoint:
      dispatches whose full record block is already present are skipped,
      a partially-recorded dispatch is truncated off and re-run whole.
      With a canonical JSONL store the finished file is byte-identical to
      an uninterrupted run's (``tests/test_faults.py``).
    * ``cost_params`` -- a ``sweep.costmodel.CostParams`` for cost-modeled
      campaigns (``Campaign.planner == 'cost'``), e.g. calibrated from a
      measured trace via ``CostParams.from_trace``; ``None`` uses the
      model defaults.  The chosen policy, its predicted cost/fill and the
      rejected alternatives land in the plan span; the campaign bookend
      span carries the realized padded-row fill to compare against.
    """
    if log is None:
        log = (SweepLogger("debug", sink=progress) if progress is not None
               else SweepLogger("quiet"))
    cache_dir = (None if compile_cache_dir is False
                 else compile_cache.enable(compile_cache_dir))
    import jax
    devices = len(jax.devices())
    p = plan(campaign, cost_params=cost_params)
    log.info(p.describe())
    if cache_dir:
        log.info(f"persistent compile cache: {cache_dir}")
    if trace:
        span = {
            "kind": "plan", "campaign": campaign.name,
            "n_points": p.n_points, "n_dispatches": p.n_dispatches,
            "n_shapes": p.n_shapes, "devices": devices,
            "engine": campaign.engine, "shard": campaign.shard,
            "probes": _probe_field(campaign),
            "cache_dir": str(cache_dir) if cache_dir else None,
        }
        if any(ph is not None for ph in campaign.phases):
            span["phases"] = [ph.label() if ph is not None else None
                              for ph in campaign.phases]
        if p.policy is not None:
            # Cost-modeled planning: the chosen policy, its predicted
            # cost/fill, and the rejected alternatives -- the prediction
            # the campaign bookend's realized fill is compared against.
            span["planner"] = "cost"
            span["policy"] = p.policy.label
            span["kmap"] = [list(kv) for kv in p.policy.kmap]
            span["pkt_exact"] = list(p.policy.pkt_exact)
            if p.cost is not None:
                span["predicted"] = p.cost.as_dict()
            span["alternatives"] = [
                {"policy": lbl, "cost": c, "pkt_fill": f}
                for (lbl, c, f) in p.alternatives]
            if cost_params is not None:
                span["calibration"] = cost_params.source
        trace.emit(span)
    cache = _Cache()
    store = store if store is not None else ResultStore(None)
    n_before = len(store.records)   # store may be shared across campaigns
    full: Dict = {}

    done = 0                        # dispatches already complete on resume
    if resume:
        # The checkpoint region is this campaign's block of pre-existing
        # records (records of other campaigns sharing the store never match
        # _point_key, which carries the campaign name).  Walk dispatches in
        # plan order; a dispatch counts as complete only if the store holds
        # its *entire* record block, in order, at the expected offset.
        # Everything after the last complete dispatch is truncated off (a
        # partially-recorded dispatch re-runs whole), so the finished file
        # is byte-identical to an uninterrupted run's.
        pos = next((i for i, r in enumerate(store.records)
                    if r.get("campaign") == campaign.name),
                   len(store.records))
        for mega in p.megabatches:
            keys = [_point_key(pt) for b in mega.members
                    for pt in b.points()]
            nxt = pos + len(keys)
            if (nxt <= len(store.records)
                    and all(_record_key(store.records[pos + i]) == kk
                            for i, kk in enumerate(keys))):
                pos, done = nxt, done + 1
            else:
                break
        store.truncate(pos)
        n_before = len(store.records)   # kept prefix is not "new" records
        kept = sum(len(b.seeds) for m in p.megabatches[:done]
                   for b in m.members)
        if trace:
            trace.emit({"kind": "resume", "campaign": campaign.name,
                        "dispatches_kept": done, "records_kept": kept})
        log.info(f"resume: {done}/{p.n_dispatches} dispatches already "
                 f"complete ({len(store.records)} records kept)")

    prof = contextlib.nullcontext()
    if profile_dir:
        try:
            prof = jax.profiler.trace(str(profile_dir))
        except Exception as e:          # profiler missing on this backend
            log.info(f"jax.profiler unavailable ({e}); profiling skipped")

    cache_files0 = _cache_files(cache_dir)
    real_rows = padded_rows = 0     # realized padded-row fill this run
    t0 = time.perf_counter()
    with prof:
        for idx, mega in enumerate(p.megabatches):
            if idx < done:          # resume: records already on disk
                continue
            span = _dispatch_span(idx, mega, campaign, campaign.shard,
                                  devices)
            real_rows += span["pkt_rows_real"]
            padded_rows += span["pkt_rows_padded"]
            run = (_run_loop_mega if mega.engine == "loop"
                   else _run_fast_mega)
            to_record = (loop_point_record if mega.engine == "loop"
                         else point_record)
            misses0 = _compile_misses()
            tb = time.perf_counter()
            per_member, rspans = _run_with_recovery(
                idx, mega, campaign, cache, run, retry=retry,
                backoff_s=backoff_s, sleep=sleep, log=log)
            t1 = time.perf_counter()
            span["wall_s"] = secs = t1 - tb
            span["cache"] = ("hit" if _compile_misses() == misses0
                             else "miss")
            if timing_split and not rspans:
                # Second dispatch hits the in-process compile caches, so its
                # wall time is pure execute; the first call's excess is the
                # compile (+trace) cost.  Results are identical by the
                # megabatch determinism contract.
                per_member = run(mega, campaign, cache)
                t2 = time.perf_counter()
                span["execute_s"] = t2 - t1
                span["compile_s"] = max(0.0, (t1 - tb) - (t2 - t1))
            if mega.engine == "loop":
                slots = [float(r.cct_acked_slots)
                         for results in per_member for r in results
                         if r is not None]
                span["slots_run"] = int(max(slots)) if slots else 0
                span["slot_fill"] = (span["slots_run"]
                                     / max(span["slot_budget"], 1))
            if trace:
                for s in rspans:    # retry/error/degrade, in event order
                    trace.emit(s)
                trace.emit(span)
            log.info(dispatch_line(span, p.n_dispatches))
            for batch, results in zip(mega.members, per_member):
                cp = (cache.compiled_phases(batch.k, batch.load, batch.phase)
                      if batch.phase is not None else None)
                for point, res in zip(batch.points(), results):
                    if res is None:     # terminal failure: error span only
                        continue
                    store.append(to_record(point, res, phases=cp))
                    if keep_full:
                        full[point] = res
                # Apportion the fused dispatch's wall time over members by
                # their share of fused points, so per-scheme timing summaries
                # stay meaningful.
                store.timings.append((batch, secs * len(batch.seeds)
                                      / max(mega.n_points, 1)))
                log.debug(f"  {batch.scheme:>16s} k={batch.k} "
                          f"{batch.load.label():<22s} x{len(batch.seeds)} "
                          f"seeds: {store.timings[-1][1]:.2f}s")
    wall = time.perf_counter() - t0
    if trace:
        trace.emit({
            "kind": "campaign", "campaign": campaign.name,
            "n_points": p.n_points, "n_dispatches": p.n_dispatches,
            # Realized padded-row fill over the dispatches this run
            # executed (resume-skipped dispatches excluded): the
            # measurement the plan span's predicted fill is checked
            # against, and the input --plan-from-trace calibrates on.
            "pkt_rows_real": real_rows,
            "pkt_rows_padded": padded_rows,
            "pkt_fill": real_rows / max(padded_rows, 1),
            "wall_s": wall,
            "cache_entries_added": (_cache_files(cache_dir) - cache_files0
                                    if cache_dir else 0),
            "emit_s": trace.emit_s,
        })
    log.info(f"campaign {campaign.name!r} done in {wall:.2f}s "
             f"({p.n_points} points, {p.n_dispatches} dispatches, "
             f"{p.n_shapes} shapes)")
    return store.records[n_before:], full
