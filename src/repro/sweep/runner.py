"""Campaign execution: one vmapped dispatch per seed batch.

The runner walks the planner's batch list in compile-reuse order, memoizing
topologies, workloads and failure states across batches, and executes

  * ``engine='fast'`` batches as a single ``fastsim.simulate_batch`` call
    (all replicate seeds in one jitted, seed-vmapped dispatch), or
  * ``engine='loop'`` batches (and any ACK/ECN scheme) serially on the
    slotted feedback engine.

Each grid point yields one record in the :class:`~repro.sweep.results
.ResultStore`; per-point results are bitwise-identical to standalone
``fastsim.simulate`` calls with the same seeds (tested in
``tests/test_sweep.py``).
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from ..net.topology import FatTree, LinkState, rho_max
from ..net import workloads, fastsim, loopsim
from ..core import lb_schemes as lbs
from .planner import SeedBatch, plan
from .results import ResultStore, loop_point_record, point_record
from .spec import Campaign, FailureSpec, WorkloadSpec


def build_workload(tree: FatTree, load: WorkloadSpec):
    if load.kind == "permutation":
        return workloads.permutation(tree, load.msg_packets,
                                     np.random.default_rng(load.rng_seed),
                                     inter_pod_only=load.inter_pod_only)
    if load.kind == "all_to_all":
        return workloads.all_to_all(tree, load.msg_packets)
    if load.kind == "fsdp_rings":
        return workloads.fsdp_rings(tree, load.gpus_per_server,
                                    load.msg_packets,
                                    np.random.default_rng(load.rng_seed))
    raise ValueError(f"unknown workload kind {load.kind!r}")


def build_links(tree: FatTree,
                failure: Optional[FailureSpec]) -> Optional[LinkState]:
    """The campaign interpretation of a FailureSpec (None = all links up)."""
    if failure is None:
        return None
    return LinkState.random_failures(tree, failure.p_fail,
                                     np.random.default_rng(failure.rng_seed))


class _Cache:
    """Memoized topology / workload / failure-state construction."""

    def __init__(self):
        self.trees: Dict[int, FatTree] = {}
        self.wls: Dict[Tuple, object] = {}
        self.links: Dict[Tuple, LinkState] = {}
        self.rhos: Dict[Tuple, float] = {}

    def tree(self, k: int) -> FatTree:
        if k not in self.trees:
            self.trees[k] = FatTree(k)
        return self.trees[k]

    def workload(self, k: int, load: WorkloadSpec):
        key = (k, load)
        if key not in self.wls:
            self.wls[key] = build_workload(self.tree(k), load)
        return self.wls[key]

    def link_state(self, k: int,
                   failure: Optional[FailureSpec]) -> Optional[LinkState]:
        if failure is None:
            return None
        key = (k, failure)
        if key not in self.links:
            self.links[key] = build_links(self.tree(k), failure)
        return self.links[key]

    def rho_auto(self, k: int, load: WorkloadSpec,
                 failure: Optional[FailureSpec]) -> float:
        key = (k, load, failure)
        if key not in self.rhos:
            links = self.link_state(k, failure)
            wl = self.workload(k, load)
            self.rhos[key] = (rho_max(self.tree(k), links, wl.flow_src,
                                      wl.flow_dst)
                              if links is not None else 1.0)
        return self.rhos[key]


def _run_fast_batch(batch: SeedBatch, campaign: Campaign, cache: _Cache):
    tree = cache.tree(batch.k)
    wl = cache.workload(batch.k, batch.load)
    links = cache.link_state(batch.k, batch.failure)
    scheme = lbs.by_name(batch.scheme)
    return fastsim.simulate_batch(tree, wl, scheme, batch.seeds,
                                  prop_slots=campaign.prop_slots,
                                  links=links, backend=campaign.backend)


def _run_loop_batch(batch: SeedBatch, campaign: Campaign, cache: _Cache):
    tree = cache.tree(batch.k)
    wl = cache.workload(batch.k, batch.load)
    links = cache.link_state(batch.k, batch.failure)
    scheme = lbs.by_name(batch.scheme)
    opts = campaign.loop_options()
    g_converge = opts.pop("g_converge", None)
    rho = opts.pop("rho", 1.0)
    if rho == "auto":
        rho = cache.rho_auto(batch.k, batch.load, batch.failure)
    cfg = loopsim.LoopConfig(prop_slots=int(round(campaign.prop_slots)),
                             rho=float(rho), **opts)
    return [loopsim.simulate(tree, wl, scheme, cfg, seed=s, links=links,
                             g_converge=g_converge) for s in batch.seeds]


def run_campaign(campaign: Campaign, store: Optional[ResultStore] = None,
                 keep_full: bool = False,
                 progress: Optional[Callable[[str], None]] = None):
    """Execute a campaign; returns (records, full_results).

    ``records`` is the flat list of per-point dicts (also appended to
    ``store`` when given, in grid-plan order).  ``full_results`` maps
    ``GridPoint -> FastSimResult/LoopSimResult`` when ``keep_full=True``
    (tests and figure code that need raw delivery vectors), else ``{}``.
    """
    p = plan(campaign)
    if progress:
        progress(p.describe())
    cache = _Cache()
    store = store if store is not None else ResultStore(None)
    n_before = len(store.records)   # store may be shared across campaigns
    full: Dict = {}
    t0 = time.perf_counter()
    for batch in p.batches:
        tb = time.perf_counter()
        if campaign.engine == "loop" or lbs.by_name(batch.scheme).needs_feedback:
            results = _run_loop_batch(batch, campaign, cache)
            to_record = loop_point_record
        else:
            results = _run_fast_batch(batch, campaign, cache)
            to_record = point_record
        for point, res in zip(batch.points(), results):
            store.append(to_record(point, res))
            if keep_full:
                full[point] = res
        store.timings.append((batch, time.perf_counter() - tb))
        if progress:
            progress(f"  {batch.scheme:>16s} k={batch.k} "
                     f"{batch.load.label():<22s} x{len(batch.seeds)} seeds: "
                     f"{store.timings[-1][1]:.2f}s")
    if progress:
        progress(f"campaign {campaign.name!r} done in "
                 f"{time.perf_counter() - t0:.2f}s "
                 f"({p.n_points} points, {p.n_dispatches} dispatches)")
    return store.records[n_before:], full
