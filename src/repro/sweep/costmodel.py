"""Per-campaign cost model for bucket-policy selection.

The planner's default bucketing is a fixed heuristic: greedy 2x k-buckets
(up to ~8x padded packet rows on host-linear workloads, ~64x on
``all_to_all`` -- quadratic in hosts) and pow2 packet buckets (up to 2x).
This module replaces "hope the heuristic holds" with a per-campaign model:
enumerate candidate bucketings of the tree and packet axes, score each as

    total = padded packet rows            (the padded-FLOP proxy: every
                                           fused row executes its bucket's
                                           full packet axis)
          + slot-budget waste rows        (loop engine: the pow2 slot
                                           bucket overshoot, prorated)
          + compile_rows * n_shapes       (a per-new-shape compile charge
                                           in the same padded-row unit)

and plan under the minimizer.  The heuristic policy is always in the
candidate set, so the chosen bucketing never costs more than it under the
model -- splitting a pathological group (mixed-k ``all_to_all``) buys its
extra compiles explicitly, against the padding they save.

``compile_rows`` -- how many padded packet rows one fresh compile is worth
-- is the one free parameter.  :meth:`CostParams.from_trace` calibrates it
from a measured PR-6 trace (``--plan-from-trace``): dispatch spans written
under ``timing_split`` carry ``compile_s``/``execute_s``, giving both the
per-padded-row execute rate and the typical compile cost in seconds.

Selection is deterministic given (campaign, calibration): candidates are
enumerated in a fixed order and ties keep the earliest candidate.
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
from typing import Dict, List, Optional, Tuple

from ..net._batching import k_buckets, pow2_bucket
from .spec import Campaign


@dataclasses.dataclass(frozen=True)
class CostParams:
    """Cost-model calibration.

    ``compile_rows`` is the padded-packet-row-equivalent charge of one
    fresh pipeline compile.  The default (64k rows) is deliberately
    mid-scale: small fused groups keep fusing (a permutation sweep's 2x
    padding never outweighs a compile), while the quadratic blow-up of a
    mixed-k ``all_to_all`` group buys its split.  Calibrate from a real
    trace for anything load-bearing.
    """
    compile_rows: float = 65536.0
    source: Optional[str] = None       # provenance label for the plan span

    @classmethod
    def from_trace(cls, path) -> "CostParams":
        """Calibrate ``compile_rows`` from a measured dispatch trace.

        Uses the ``timing_split`` fields of dispatch spans: the summed
        ``execute_s`` over summed ``pkt_rows_padded`` gives seconds per
        padded packet row; the median ``compile_s`` over that rate is the
        row-equivalent compile charge.  A trace without usable timing
        spans falls back to the defaults (``source`` says so), so a
        heuristic-run trace can always be fed back in.
        """
        from ..obs.trace import load_trace
        spans = load_trace(path)
        timed = [s for s in spans if s.get("kind") == "dispatch"
                 and s.get("execute_s") and s.get("pkt_rows_padded")]
        compiles = sorted(float(s["compile_s"]) for s in timed
                          if s.get("compile_s"))
        rows = sum(int(s["pkt_rows_padded"]) for s in timed)
        exec_s = sum(float(s["execute_s"]) for s in timed)
        if not compiles or rows <= 0 or exec_s <= 0.0:
            return cls(source=f"{path} (no timing_split spans; defaults)")
        per_row_s = exec_s / rows
        median_compile_s = compiles[len(compiles) // 2]
        compile_rows = min(max(median_compile_s / per_row_s, 1.0), 1e12)
        return cls(compile_rows=compile_rows, source=str(path))


@dataclasses.dataclass(frozen=True)
class BucketPolicy:
    """One candidate bucketing of the tree and packet axes.

    ``kmap`` maps every campaign tree size to its bucket head (ascending,
    as ``(k, k_pad)`` pairs); ``pkt_exact`` lists the bucket heads whose
    packet axis keys on the *exact* packet count instead of its pow2
    bucket -- tighter padding (up to 2x) at the price of splitting loads
    with different packet counts into separate shapes.
    """
    kmap: Tuple[Tuple[int, int], ...]
    pkt_exact: Tuple[int, ...] = ()
    label: str = "greedy2x/pow2"

    def kmap_dict(self) -> Dict[int, int]:
        return dict(self.kmap)

    def pkt_bucket(self, k_pad: int, n: int) -> int:
        """Packet-axis shape key for a load with ``n`` packets at bucket
        head ``k_pad``."""
        if k_pad in self.pkt_exact:
            return max(int(n), 1)
        return pow2_bucket(n)

    @classmethod
    def heuristic(cls, trees) -> "BucketPolicy":
        """The planner's default greedy-2x / pow2 policy as a
        :class:`BucketPolicy` (always candidate #0, so the model can never
        pick anything worse than it)."""
        return cls(kmap=tuple(sorted(k_buckets(trees).items())),
                   pkt_exact=(), label="greedy2x/pow2")


@dataclasses.dataclass(frozen=True)
class PlanCost:
    """Model cost of one (campaign, policy) plan, in padded-row units."""
    pkt_rows_real: int
    pkt_rows_padded: int
    slot_waste_rows: float
    compile_charge: float
    n_dispatches: int
    n_shapes: int

    @property
    def total(self) -> float:
        return (float(self.pkt_rows_padded) + self.slot_waste_rows
                + self.compile_charge)

    @property
    def pkt_fill(self) -> float:
        return self.pkt_rows_real / max(self.pkt_rows_padded, 1)

    def as_dict(self) -> Dict:
        return {"pkt_rows_real": self.pkt_rows_real,
                "pkt_rows_padded": self.pkt_rows_padded,
                "pkt_fill": self.pkt_fill,
                "slot_waste_rows": self.slot_waste_rows,
                "compile_charge": self.compile_charge,
                "n_dispatches": self.n_dispatches,
                "n_shapes": self.n_shapes,
                "total": self.total}


def _grouped(trees: List[int], groups: List[List[int]],
             pkt_exact: Tuple[int, ...]) -> BucketPolicy:
    kmap = tuple((k, max(g)) for g in groups for k in sorted(g))
    label = "k[" + "|".join(",".join(str(k) for k in sorted(g))
                            for g in groups) + "]"
    if pkt_exact:
        label += "+exact[" + ",".join(str(h) for h in pkt_exact) + "]"
    return BucketPolicy(kmap=kmap, pkt_exact=pkt_exact, label=label)


def candidate_policies(campaign: Campaign) -> List[BucketPolicy]:
    """The deterministic candidate set: the heuristic policy first, then
    every contiguous partition of the ascending tree axis (each group pads
    to its largest member) crossed with per-bucket-head exact-vs-pow2
    packet modes.  Contiguity is lossless -- padding cost is monotone in
    ``k``, so an optimal grouping never skips over a middle size.  Wide
    axes cap the enumeration (per-k split and full fuse only past 7 trees;
    all-exact/all-pow2 only past 4 bucket heads) to keep planning O(ms).
    """
    trees = sorted({int(k) for k in campaign.trees})
    cands = [BucketPolicy.heuristic(campaign.trees)]
    m = len(trees)
    partitions: List[List[List[int]]] = []
    if m <= 7:
        for mask in range(1 << (m - 1)):
            groups, cur = [], [trees[0]]
            for i in range(1, m):
                if (mask >> (i - 1)) & 1:
                    groups.append(cur)
                    cur = [trees[i]]
                else:
                    cur.append(trees[i])
            groups.append(cur)
            partitions.append(groups)
    else:
        partitions = [[[t] for t in trees], [list(trees)]]
    seen = {(cands[0].kmap, cands[0].pkt_exact)}
    for groups in partitions:
        heads = sorted({max(g) for g in groups})
        if len(heads) <= 4:
            exact_sets = [tuple(c) for r in range(len(heads) + 1)
                          for c in itertools.combinations(heads, r)]
        else:
            exact_sets = [(), tuple(heads)]
        for ex in exact_sets:
            pol = _grouped(trees, groups, ex)
            sig = (pol.kmap, pol.pkt_exact)
            if sig not in seen:
                seen.add(sig)
                cands.append(pol)
    return cands


def evaluate_policy(campaign: Campaign, policy: BucketPolicy,
                    params: Optional[CostParams] = None) -> PlanCost:
    """Model cost of planning ``campaign`` under ``policy`` (no dispatching
    -- this is pure host-side accounting over the would-be megabatches)."""
    from .planner import plan
    params = params if params is not None else CostParams()
    p = plan(campaign, policy=policy)
    real = padded = 0
    loop_padded = 0
    for mega in p.megabatches:
        rows = mega.n_points
        real += sum(len(b.seeds) * b.n_packets(b.k)
                    for b in mega.members)
        padded += rows * mega.npk_pad
        if mega.engine == "loop":
            loop_padded += rows * mega.npk_pad
    slot_waste = 0.0
    if loop_padded:
        budget = max(int(campaign.max_slots), 1)
        bucket = pow2_bucket(budget)
        slot_waste = loop_padded * (bucket - budget) / float(bucket)
    return PlanCost(pkt_rows_real=real, pkt_rows_padded=padded,
                    slot_waste_rows=slot_waste,
                    compile_charge=float(params.compile_rows) * p.n_shapes,
                    n_dispatches=p.n_dispatches, n_shapes=p.n_shapes)


@functools.lru_cache(maxsize=64)
def choose_policy(campaign: Campaign,
                  params: Optional[CostParams] = None
                  ) -> Tuple[BucketPolicy, PlanCost, Tuple]:
    """Pick the cost-minimizing bucket policy for ``campaign``.

    Returns ``(policy, cost, alternatives)`` where ``alternatives`` are the
    *rejected* candidates as ``(label, total_cost, predicted_pkt_fill)``
    rows sorted by cost (the plan span records them).  Deterministic given
    (campaign, params): candidate order is fixed and ties keep the earliest
    -- in particular the heuristic wins exact ties, so cost-mode plans on
    campaigns the heuristic already handles optimally are unchanged up to
    dispatch order.
    """
    params = params if params is not None else CostParams()
    scored = [(pol, evaluate_policy(campaign, pol, params))
              for pol in candidate_policies(campaign)]
    best_i = min(range(len(scored)), key=lambda i: scored[i][1].total)
    policy, cost = scored[best_i]
    alternatives = tuple(sorted(
        ((pol.label, c.total, c.pkt_fill)
         for i, (pol, c) in enumerate(scored) if i != best_i),
        key=lambda row: row[1]))
    return policy, cost, alternatives
