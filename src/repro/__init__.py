"""repro — "Load Balancing for AI Training Workloads" as a multi-pod JAX
framework.

Public API quick map:

    repro.configs.base.get_config(name)      architecture configs
    repro.models.registry.get_model(name)    uniform model API
    repro.net.{topology,workloads,fastsim,loopsim}   the fabric simulators
    repro.core.{lb_schemes,ofan,theory}      the paper's contribution
    repro.collectives.{engine,planner}       DR-rotation collective engine
    repro.train / repro.serve                training & serving substrate
    repro.launch.{mesh,dryrun,roofline,perf} multi-pod tooling
"""

__version__ = "1.0.0"
