"""Collective schedule planner: chooses between XLA one-shot collectives and
DR rotation schedules using the paper's queue laws as the congestion model.

For a collective of ``m`` bytes per destination over a fabric whose
load-balancing discipline has queue law q(m), the expected completion is

    T(m) ~ serialization(m) + queue_delay(q(m)) + propagation

The paper's result: with hash-based fabric LB (the default on multi-tenant
DCNs), q grows like sqrt(m) (or m under synchronization), while a rotation
schedule keeps every round a permutation => q = O(1) (ND/D/1).  The planner
therefore prefers rotation for large cross-pod transfers and XLA's fused
collectives intra-pod (ICI is deterministically routed; rotation only adds
dispatch overhead there).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

from ..core import theory


@dataclasses.dataclass(frozen=True)
class FabricModel:
    link_bw_Bps: float = 50e9          # per ICI/DCN link
    rtt_s: float = 25e-6
    packet_B: int = 4178
    lb_scheme: str = "host_pkt"        # fabric's LB for one-shot collectives
    fat_tree_k: int = 16

    def queue_pkts(self, m_pkts: float) -> float:
        if self.lb_scheme in ("ofan", "host_dr"):
            return theory.q_nd_d_1(self.fat_tree_k ** 2 / 4, 1.0)
        if self.lb_scheme in ("simple_rr", "jsq", "flow_ecmp"):
            return theory.q_linear(m_pkts, 0.5)
        return float(theory.q_sqrt(m_pkts, self.fat_tree_k))


@dataclasses.dataclass
class Plan:
    impl: str           # 'xla' | 'rotation' | 'ring' | 'rs_ag' | 'none'
    est_time_s: float
    reason: str


def _empty_plan(what: str) -> Plan:
    """Degenerate collective: a single participant or non-positive bytes
    moves no traffic, so return an explicit empty plan instead of letting
    the queue laws divide by zero / go negative."""
    return Plan("none", 0.0, f"degenerate collective ({what}): no traffic")


def plan_all_to_all(bytes_per_pair: float, n: int,
                    fabric: FabricModel = FabricModel(),
                    intra_pod: bool = True) -> Plan:
    """Choose the AllToAll schedule across an axis of size n."""
    if n <= 1:
        return _empty_plan(f"n={n}")
    if bytes_per_pair <= 0:
        return _empty_plan(f"bytes_per_pair={bytes_per_pair:g}")
    m_pkts = bytes_per_pair / fabric.packet_B
    ser = bytes_per_pair * (n - 1) / fabric.link_bw_Bps
    if intra_pod:
        return Plan("xla", ser + fabric.rtt_s,
                    "ICI is deterministically routed; one-shot a2a")
    # One-shot over the DCN: the fabric queue q(m) inflates delay, and the
    # delay-targeting CCA throttles throughput to keep queues near its
    # target (the paper's Fig. 13 mechanism: spraying schemes get reined in,
    # DR does not).  util = target / (target + queue_delay).
    q = fabric.queue_pkts(m_pkts * (n - 1))
    q_delay = q * fabric.packet_B * 8 / fabric.link_bw_Bps
    target = fabric.rtt_s            # Swift-style: ~BDP-scale target delay
    util = target / (target + q_delay)
    t_oneshot = ser / max(util, 1e-3) + fabric.rtt_s + q_delay
    # rotation: n-1 rounds, each a clean permutation (O(1) queues, no
    # throttling), but each round pays an RTT-scale dispatch latency
    q_rot = theory.q_nd_d_1(fabric.fat_tree_k ** 2 / 4, 1.0)
    t_rot = (ser + (n - 1) * fabric.rtt_s
             + (n - 1) * q_rot * fabric.packet_B * 8 / fabric.link_bw_Bps)
    if t_rot < t_oneshot:
        return Plan("rotation", t_rot,
                    f"DR rotation wins: queue {q:.0f} pkts one-shot vs "
                    f"O(1) per round")
    return Plan("xla", t_oneshot, "message too small: per-round RTT dominates")


def plan_all_reduce(bytes_total: float, n: int,
                    fabric: FabricModel = FabricModel(),
                    intra_pod: bool = True) -> Plan:
    if n <= 1:
        return _empty_plan(f"n={n}")
    if bytes_total <= 0:
        return _empty_plan(f"bytes_total={bytes_total:g}")
    ser = 2 * bytes_total * (n - 1) / n / fabric.link_bw_Bps
    if intra_pod:
        return Plan("xla", ser + fabric.rtt_s, "ICI: fused all-reduce")
    m_pkts = bytes_total / fabric.packet_B
    q = fabric.queue_pkts(m_pkts)
    q_delay = q * fabric.packet_B * 8 / fabric.link_bw_Bps
    util = fabric.rtt_s / (fabric.rtt_s + q_delay)
    t_oneshot = ser / max(util, 1e-3) + fabric.rtt_s + q_delay
    t_rsag = ser + 2 * (n - 1) * fabric.rtt_s
    if t_rsag < t_oneshot:
        return Plan("rs_ag", t_rsag,
                    "ring RS+AG (two rotation phases) beats one-shot under "
                    f"fabric queue ~{q:.0f} pkts")
    return Plan("xla", t_oneshot, "small reduction: RTTs dominate")
