"""Gradient compression for the cross-pod (DCN) reduction.

The pod axis crosses the fat-tree fabric the paper studies; halving the
bytes halves the collective's network time regardless of the LB scheme, and
composes with the DR schedule.  Implemented:

  * bf16 -- cast, psum over 'pod', cast back (2x);
  * int8 -- per-tensor scale quantization with **error feedback** carried in
    fp32 residual state (4x; EF keeps convergence).

Both run inside shard_map over the 'pod' axis only; intra-pod reductions
stay full precision.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from ..models import sharding as sh


def _psum_pod(x):
    return jax.lax.psum(x, "pod")


def compressed_psum_pod(grads, method: str = "bf16", residual=None):
    """All-reduce grads across the 'pod' mesh axis with compression.

    Without a 'pod' axis this is a no-op (single-pod runs).  Returns grads
    (and, for int8 with error feedback, the new residual when one is
    passed).
    """
    mesh = sh.current_mesh()
    if mesh is None or "pod" not in mesh.shape or mesh.shape["pod"] == 1:
        return grads if residual is None else (grads, residual)

    npods = mesh.shape["pod"]

    def reduce_leaf(g):
        if method == "bf16":
            def inner(x):
                return jax.lax.psum(x.astype(jnp.bfloat16), "pod").astype(
                    jnp.float32) / npods * npods
        elif method == "int8":
            def inner(x):
                scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
                q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
                # psum int8 partials in int32 to avoid overflow
                s = jax.lax.psum(q.astype(jnp.int32), "pod")
                smax = jax.lax.pmax(scale, "pod")
                return s.astype(jnp.float32) * smax
        else:
            raise ValueError(method)
        # grads are already identical across 'pod'? No: with batch sharded
        # over pod, GSPMD keeps per-pod partials only if we ask; here we
        # assume the caller passes per-pod partial grads sharded P() within
        # pod and performs the cross-pod sum here.
        return shard_map(inner, mesh=mesh,
                         in_specs=P(*(None,) * g.ndim),
                         out_specs=P(*(None,) * g.ndim),
                         check_rep=False)(g)

    out = jax.tree_util.tree_map(reduce_leaf, grads)
    if residual is not None:
        return out, residual
    return out


def quantize_int8_ef(g, residual):
    """Error-feedback int8 quantization (single-tensor helper used by tests
    and the planner's what-if cost model)."""
    x = g.astype(jnp.float32) + residual
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    deq = q * scale
    return q.astype(jnp.int8), scale, x - deq
