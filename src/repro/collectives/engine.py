"""DR-scheduled collective engine — the paper's discipline at the collective
layer.

The paper proves destination-based rotation (every communication round is a
*permutation*) achieves Theta(1) queueing where hash-based spraying gets
Omega(sqrt(m)) and round-robin Theta(m).  On a TPU/DCN deployment the
schedule of a collective plays the role the switch scheduler plays in the
fabric: XLA's one-shot ``all_to_all`` / ``all_gather`` leaves balancing to
the fabric, while a **rotation schedule** (n-1 ``ppermute`` rounds, each a
perfect permutation) is per-destination balanced *by construction*.

Implementations (all inside ``shard_map`` over a chosen mesh axis):

  all_gather:      'xla' | 'ring' (n-1 neighbor rounds)
  reduce_scatter:  'xla' | 'ring'
  all_reduce:      'xla' | 'rs_ag' (ring RS + ring AG -- the bandwidth-
                    optimal schedule; both phases are rotations)
  all_to_all:      'xla' | 'rotation' ((n-1) destination rotations -- the
                    paper's "(n-1) permutation matrices")

Every custom schedule is validated against its XLA counterpart in
``tests/test_collectives.py``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


# ---------------------------------------------------------------------------
# shard_map inner collectives (take local shard, return local shard)
# ---------------------------------------------------------------------------

def ring_all_gather(x_loc, axis: str, n: int):
    """(d0, ...) -> (n*d0, ...): n-1 rounds; round r forwards the block
    received in round r-1 to the next neighbor (each round is the rotation
    permutation i -> i+1)."""
    if n == 1:
        return x_loc
    me = jax.lax.axis_index(axis)
    perm = [(i, (i + 1) % n) for i in range(n)]
    out = jnp.zeros((n,) + x_loc.shape, x_loc.dtype)
    out = jax.lax.dynamic_update_slice(
        out, x_loc[None], (me,) + (0,) * x_loc.ndim)
    blk = x_loc
    for r in range(1, n):
        blk = jax.lax.ppermute(blk, axis, perm)
        src = (me - r) % n
        out = jax.lax.dynamic_update_slice(
            out, blk[None], (src,) + (0,) * x_loc.ndim)
    return out.reshape((n * x_loc.shape[0],) + x_loc.shape[1:])


def ring_reduce_scatter(x_loc, axis: str, n: int):
    """(n*d0, ...) -> (d0, ...) summed across the axis; n-1 rotation rounds.

    The partial for destination block k starts at shard k+1 (value
    b_{k+1}[k]) and flows k+1 -> k+2 -> ... -> k, each visited shard j
    adding its own contribution b_j[k]; shard j therefore holds partial
    P_{j-r-1} after round r and finishes with P_j = sum_i b_i[j]."""
    if n == 1:
        return x_loc
    me = jax.lax.axis_index(axis)
    d0 = x_loc.shape[0] // n
    blocks = x_loc.reshape((n, d0) + x_loc.shape[1:])
    perm = [(i, (i + 1) % n) for i in range(n)]
    acc = jnp.take(blocks, (me - 1) % n, axis=0)       # P_{me-1} seed
    for r in range(1, n):
        acc = jax.lax.ppermute(acc, axis, perm)
        acc = acc + jnp.take(blocks, (me - r - 1) % n, axis=0)
    return acc


def rotation_all_to_all(x_loc, axis: str, n: int, split: int = 0,
                        concat: int = 0):
    """Tiled all-to-all as n-1 destination rotations (paper §2: an AlltoAll
    is (n-1) permutation matrices applied iteratively)."""
    if n == 1:
        return x_loc
    me = jax.lax.axis_index(axis)
    chunks = jnp.stack(jnp.split(x_loc, n, axis=split), axis=0)
    out_shape = list(chunks.shape[1:])
    out_shape[concat] *= n
    out = jnp.zeros(out_shape, x_loc.dtype)
    csz = chunks.shape[1:][concat]

    def put(arr, block, pos):
        start = [0] * arr.ndim
        start[concat] = pos * csz
        return jax.lax.dynamic_update_slice(arr, block, tuple(start))

    out = put(out, jnp.take(chunks, me, axis=0), me)
    for r in range(1, n):
        send = jnp.take(chunks, (me + r) % n, axis=0)
        recv = jax.lax.ppermute(send, axis,
                                [(i, (i + r) % n) for i in range(n)])
        out = put(out, recv, (me - r) % n)
    return out


def ring_all_reduce(x_loc, axis: str, n: int):
    """Bandwidth-optimal all-reduce: ring reduce-scatter + ring all-gather.
    Requires leading dim divisible by n."""
    if n == 1:
        return x_loc
    scat = ring_reduce_scatter(x_loc, axis, n)
    return ring_all_gather(scat, axis, n)


# ---------------------------------------------------------------------------
# Public (global-array) entry points
# ---------------------------------------------------------------------------

def _axis_size(mesh: Mesh, axis: str) -> int:
    return mesh.shape[axis]


def all_gather(x, mesh: Mesh, axis: str, impl: str = "rotation"):
    """Gather shards of x (sharded on dim 0 over ``axis``) -> replicated."""
    n = _axis_size(mesh, axis)

    def inner(xl):
        if impl == "xla":
            return jax.lax.all_gather(xl, axis, axis=0, tiled=True)
        return ring_all_gather(xl, axis, n)

    return shard_map(inner, mesh=mesh, in_specs=P(axis),
                     out_specs=P(), check_rep=False)(x)


def all_reduce(x, mesh: Mesh, axis: str, impl: str = "rotation"):
    """Sum x (replicated shards with distinct partials... i.e. psum) over
    ``axis``.  x must have leading dim divisible by the axis size for the
    ring schedule."""
    n = _axis_size(mesh, axis)

    def inner(xl):
        if impl == "xla":
            return jax.lax.psum(xl, axis)
        return ring_all_reduce(xl, axis, n)

    return shard_map(inner, mesh=mesh, in_specs=P(), out_specs=P(),
                     check_rep=False)(x)


def reduce_scatter(x, mesh: Mesh, axis: str, impl: str = "rotation"):
    n = _axis_size(mesh, axis)

    def inner(xl):
        if impl == "xla":
            return jax.lax.psum_scatter(xl, axis, scatter_dimension=0,
                                        tiled=True)
        return ring_reduce_scatter(xl, axis, n)

    return shard_map(inner, mesh=mesh, in_specs=P(), out_specs=P(axis),
                     check_rep=False)(x)


def all_to_all(x, mesh: Mesh, axis: str, impl: str = "rotation"):
    """x sharded on dim 0; block-transpose across the axis (tiled a2a)."""
    n = _axis_size(mesh, axis)

    def inner(xl):
        if impl == "xla":
            return jax.lax.all_to_all(xl, axis, split_axis=0, concat_axis=0,
                                      tiled=True)
        return rotation_all_to_all(xl, axis, n, split=0, concat=0)

    return shard_map(inner, mesh=mesh, in_specs=P(axis), out_specs=P(axis),
                     check_rep=False)(x)
