"""Declarative mid-run link fault schedules.

A :class:`FaultSchedule` describes the fabric's link state as a *function of
time*: an optional random base failure pattern active from slot 0 (the static
``FailureSpec`` model) plus a train of timed :class:`LinkEvent` down/up
edits.  :meth:`FaultSchedule.compile` lowers it, for one concrete
:class:`~repro.net.topology.FatTree`, into an epoch timeline::

    ep_start = [0, t_1, t_2, ...]        # slot each epoch takes effect
    links    = [LinkState_0, LinkState_1, ...]

where every distinct event time opens a new epoch whose ``LinkState`` is the
previous epoch's masks with that slot's events applied.  The engines derive
all per-epoch routing state (alive masks, W-ECMP port lists, OFAN IWRR
tables, REPS/PLB valid-label pools, host label redraws) from these stacks and
gather the current epoch by slot inside the simulation, so schedules ride the
fused campaign axis like any other grid dimension (epoch counts pad to the
dispatch maximum; pad epochs start at an unreachable sentinel slot and are
bitwise-inert).

Reaction-delay semantics: the *physical* link state (packets black-holing on
dead queues) switches exactly at ``ep_start[e]``; the *routing* state reacts
``host_react`` slots later for host-visible schemes (host-labelled ``pre``
schemes and ACK-adaptive REPS/PLB, which observe path changes end-to-end)
and ``switch_react`` slots later for switch-local schemes (RR/JSQ/OFAN,
which wait on local port-status/W-ECMP convergence) -- the per-scheme split
is :meth:`LBScheme.reaction_class`.  Before the first reaction slot, routing
is failure-unaware ("stale"), generalizing the static model's single
``g_converge`` convergence slot: a one-epoch schedule with
``host_react == switch_react == G`` is bitwise-identical to the old
``FailureSpec`` + ``g_converge=G`` path (tested in ``tests/test_faults.py``).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Optional, Sequence, Tuple

import numpy as np

from ..net.topology import FatTree, LinkState

# Routing never reacts past this slot (also the pad-epoch start sentinel):
# far beyond any max_slots budget, well inside int32.
NEVER = 2 ** 30


@dataclasses.dataclass(frozen=True)
class LinkEvent:
    """One timed link edit: at slot ``t`` the link goes up (``up=True``) or
    down.  ``layer`` selects the mask: ``"ea"`` (edge<->agg, coordinates
    (pod, edge, agg)) or ``"ac"`` (agg<->core, coordinates (pod, agg, sub));
    ``i``/``j`` are the two intra-pod indices in [0, k/2)."""
    t: int
    layer: str          # 'ea' | 'ac'
    pod: int
    i: int
    j: int
    up: bool

    def __post_init__(self):
        if self.layer not in ("ea", "ac"):
            raise ValueError(f"LinkEvent layer must be 'ea' or 'ac', "
                             f"got {self.layer!r}")
        if self.t < 0:
            raise ValueError(f"LinkEvent t must be >= 0, got {self.t}")


@dataclasses.dataclass(frozen=True)
class CompiledFaults:
    """One schedule lowered for one concrete tree: ``links[e]`` is active
    from slot ``ep_start[e]`` (``ep_start[0] == 0``) to ``ep_start[e+1]``."""
    ep_start: Tuple[int, ...]
    links: Tuple[LinkState, ...]
    host_react: int
    switch_react: int

    @property
    def n_epochs(self) -> int:
        return len(self.links)

    def react_starts(self, reaction_class: str) -> np.ndarray:
        """Per-epoch slot at which *routing* reflects the epoch, saturated
        at :data:`NEVER` (int32-safe: the engines never add the reaction
        delay to a start themselves -- a pad epoch's sentinel start plus a
        large delay would overflow)."""
        react = (self.host_react if reaction_class == "host"
                 else self.switch_react)
        starts = np.asarray(self.ep_start, np.int64) + int(react)
        return np.minimum(starts, NEVER).astype(np.int32)


@dataclasses.dataclass(frozen=True)
class FaultSchedule:
    """Timed link down/up events over an optional random base failure.

    ``p_fail``/``rng_seed``/``legacy_rng`` define the epoch-0 base pattern
    exactly like ``FailureSpec`` (``legacy_rng`` selects the old sequential
    ``np.random`` draws instead of the counter-keyed default; see
    ``LinkState.random_failures``).  ``host_react``/``switch_react`` are the
    reaction delays (slots) described in the module docstring.
    """
    events: Tuple[LinkEvent, ...] = ()
    p_fail: float = 0.0
    rng_seed: int = 42
    legacy_rng: bool = False
    host_react: int = 0
    switch_react: int = 0

    def __post_init__(self):
        object.__setattr__(self, "events", tuple(self.events))

    # ---- constructors ------------------------------------------------------
    @classmethod
    def static(cls, p_fail: float, rng_seed: int = 42, **kw) -> "FaultSchedule":
        """Single-epoch schedule: the ``FailureSpec`` model with reaction
        delays playing the role of ``g_converge``."""
        return cls(events=(), p_fail=p_fail, rng_seed=rng_seed, **kw)

    @classmethod
    def flap(cls, layer: str = "ea", pod: int = 0, i: int = 0, j: int = 0,
             t0: int = 0, period: int = 256, cycles: int = 1,
             **kw) -> "FaultSchedule":
        """Flap train: the link goes down at ``t0``, back up ``period``
        slots later, repeated ``cycles`` times (2 epochs per cycle beyond
        the base epoch when ``t0 > 0``)."""
        if period <= 0 or cycles <= 0:
            raise ValueError("flap needs period > 0 and cycles > 0")
        ev = tuple(LinkEvent(t0 + m * period, layer, pod, i, j, up=bool(m % 2))
                   for m in range(2 * cycles))
        return cls(events=ev, **kw)

    @classmethod
    def burst(cls, down: Sequence[Tuple[str, int, int, int]],
              t_down: int, t_up: Optional[int] = None, **kw) -> "FaultSchedule":
        """Correlated burst: every ``(layer, pod, i, j)`` in ``down`` fails
        at ``t_down`` and (when ``t_up`` is given) recovers at ``t_up``."""
        ev = [LinkEvent(t_down, lay, p, i, j, up=False)
              for (lay, p, i, j) in down]
        if t_up is not None:
            if t_up <= t_down:
                raise ValueError("burst recovery must be after the failure")
            ev += [LinkEvent(t_up, lay, p, i, j, up=True)
                   for (lay, p, i, j) in down]
        return cls(events=tuple(ev), **kw)

    # ---- identity ----------------------------------------------------------
    @property
    def n_epochs(self) -> int:
        """Tree-independent epoch count: 1 + #distinct event times > 0."""
        return 1 + len({e.t for e in self.events if e.t > 0})

    def label(self) -> str:
        """Deterministic record label (the result store's ``failure`` field).
        Carries the knobs a reader scans for plus an event digest."""
        sig = hashlib.md5(repr(tuple(
            dataclasses.astuple(e) for e in self.events)).encode()
        ).hexdigest()[:8]
        legacy = "-np" if self.legacy_rng else ""
        return (f"sched{self.n_epochs}e-p{self.p_fail:g}-r{self.rng_seed}"
                f"{legacy}-hr{self.host_react}-sr{self.switch_react}-{sig}")

    # ---- lowering ----------------------------------------------------------
    def base_links(self, tree: FatTree) -> LinkState:
        if self.p_fail <= 0.0:
            return LinkState.all_up(tree)
        if self.legacy_rng:
            return LinkState.random_failures(
                tree, self.p_fail, np.random.default_rng(self.rng_seed))
        return LinkState.random_failures(tree, self.p_fail,
                                         seed=self.rng_seed)

    def compile(self, tree: FatTree) -> CompiledFaults:
        """Lower to the epoch timeline for one concrete tree (see module
        docstring).  Events are applied cumulatively in (t, definition)
        order; coordinates are validated against the tree."""
        h = tree.half
        for e in self.events:
            if not (0 <= e.pod < tree.k and 0 <= e.i < h and 0 <= e.j < h):
                raise ValueError(f"event {e} out of range for k={tree.k}")
        base = self.base_links(tree)
        ea, ac = base.ea.copy(), base.ac.copy()
        by_t: dict = {}
        for e in self.events:
            by_t.setdefault(e.t, []).append(e)
        ep_start = sorted(set(by_t) | {0})
        links = []
        for t in ep_start:
            for e in by_t.get(t, ()):
                (ea if e.layer == "ea" else ac)[e.pod, e.i, e.j] = e.up
            links.append(LinkState(tree, ea.copy(), ac.copy()))
        return CompiledFaults(ep_start=tuple(ep_start), links=tuple(links),
                              host_react=self.host_react,
                              switch_react=self.switch_react)

    # ---- JSON --------------------------------------------------------------
    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["events"] = [dataclasses.asdict(e) for e in self.events]
        d["kind"] = "schedule"          # discriminates from FailureSpec
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "FaultSchedule":
        d = dict(d)
        d.pop("kind", None)
        d["events"] = tuple(LinkEvent(**e) for e in d.get("events", ()))
        return cls(**d)
