"""Dynamic fault injection: declarative link down/up schedules that compile
to epoch-indexed ``LinkState`` stacks both engines consume as time-varying
operands (see :mod:`repro.faults.schedule`)."""
from .schedule import CompiledFaults, FaultSchedule, LinkEvent

__all__ = ["CompiledFaults", "FaultSchedule", "LinkEvent"]
