"""Qwen1.5-4B [hf:Qwen/Qwen1.5-0.5B family; hf]: dense with QKV bias."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen1.5-4b", family="dense",
    n_layers=40, d_model=2560, n_heads=20, n_kv_heads=20,
    d_ff=6912, vocab=151936, head_dim=128, qkv_bias=True,
    rope_theta=5000000.0, optimizer="adamw", microbatch=4,
))
