"""Whisper-small [arXiv:2212.04356; unverified]: enc-dec; conv frontend is a
stub (precomputed 1500-frame embeddings via input_specs)."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-small", family="encdec",
    n_layers=12, n_encoder_layers=12,
    d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab=51968, head_dim=64,   # vocab 51865 padded to a
    # multiple of 128 for tensor-parallel logits sharding (weights beyond
    # 51865 are dead; standard practice)
    n_frontend_tokens=1500, frontend_dim=768,
    optimizer="adamw", microbatch=8,
))
