"""LLaVA-NeXT-34B [hf:llava-hf/llava-v1.6; unverified]: dense 60L backbone;
anyres vision tiling stubbed as precomputed patch embeddings (2880 tokens)."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=20480, vocab=64000, head_dim=128,
    n_frontend_tokens=2880, frontend_dim=1024,
    rope_theta=5000000.0, optimizer="adafactor", microbatch=8,
))
