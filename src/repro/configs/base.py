"""Model / run configuration system.

Every assigned architecture is a ``ModelConfig`` in ``repro/configs/<id>.py``;
``repro.configs.get(name)`` resolves either a full config or its reduced
smoke-test variant.  Input shapes are the four assigned cells; ``long_500k``
only applies to sub-quadratic (SSM/hybrid) families.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    qkv_bias: bool = False
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    # --- MoE ---
    n_experts: int = 0
    experts_per_tok: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0
    n_dense_layers: int = 0        # leading dense layers (DeepSeek-V3: 3)
    capacity_factor: float = 1.25
    moe_impl: str = "a2a"          # a2a | rotation | dense

    # --- MLA (DeepSeek) ---
    mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128

    # --- SSM (Mamba2) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_groups: int = 1
    ssm_conv: int = 4

    # --- hybrid (Zamba2) ---
    shared_attn_every: int = 0     # apply the shared attention block every N

    # --- enc-dec (Whisper) ---
    n_encoder_layers: int = 0
    n_frontend_tokens: int = 0     # stub frontend sequence (audio frames /
    frontend_dim: int = 0          # vision patches), pre-embedded

    # --- training ---
    optimizer: str = "adamw"       # adamw | adafactor
    remat: bool = True
    microbatch: int = 0            # 0 = auto
    # dry-run probe flag: unroll layer scans so cost_analysis counts every
    # layer (XLA counts while bodies once; see launch/dryrun.py calibration)
    scan_unroll: bool = False
    # 100B+ archs: FSDP params/grads across pods too (ZeRO-3 over the DCN)
    fsdp_over_pod: bool = False
    # remat policy: 'nothing' (recompute all) | 'dots' (save matmul outputs)
    remat_policy: str = "nothing" 

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    @property
    def is_subquadratic(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs have a decode path

    def scaled_down(self) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        def shrink(v, lo, fac):
            return max(lo, v // fac)
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=min(self.n_layers, 4 if self.shared_attn_every else 2),
            n_encoder_layers=min(self.n_encoder_layers, 2),
            d_model=128, d_ff=256, moe_d_ff=64 if self.moe_d_ff else 0,
            n_heads=4, n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            head_dim=32, vocab=512,
            n_experts=min(self.n_experts, 8),
            experts_per_tok=min(self.experts_per_tok, 2),
            n_dense_layers=min(self.n_dense_layers, 1),
            q_lora_rank=64 if self.q_lora_rank else 0,
            kv_lora_rank=32 if self.kv_lora_rank else 0,
            rope_head_dim=16 if self.mla else self.rope_head_dim,
            nope_head_dim=32 if self.mla else self.nope_head_dim,
            v_head_dim=32 if self.mla else self.v_head_dim,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else 64,
            shared_attn_every=2 if self.shared_attn_every else 0,
            n_frontend_tokens=min(self.n_frontend_tokens, 16),
            frontend_dim=128 if self.frontend_dim else 0,
            dtype="float32", microbatch=1,
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def applicable_shapes(cfg: ModelConfig) -> Tuple[str, ...]:
    """The assigned cells for this arch.  ``long_500k`` needs sub-quadratic
    attention: run for SSM/hybrid, skip for full-attention archs (noted in
    DESIGN.md §Arch-applicability)."""
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.is_subquadratic:
        names.append("long_500k")
    return tuple(names)


# ---------------------------------------------------------------------------
# Registry (configs register themselves on import; loaded lazily to avoid
# circular imports with the model modules).
# ---------------------------------------------------------------------------

_REGISTRY: dict = {}
_ARCH_MODULES = (
    "phi4_mini_3_8b", "phi3_mini_3_8b", "yi_6b", "qwen1_5_4b",
    "deepseek_v3_671b", "qwen3_moe_30b_a3b", "mamba2_130m", "whisper_small",
    "zamba2_2_7b", "llava_next_34b",
)


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def _load_all():
    import importlib
    for mod in _ARCH_MODULES:
        importlib.import_module(f"repro.configs.{mod}")


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    if name not in _REGISTRY:
        _load_all()
    cfg = _REGISTRY[name]
    return cfg.scaled_down() if smoke else cfg


def list_architectures():
    _load_all()
    return sorted(_REGISTRY)
