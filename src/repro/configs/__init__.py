"""Assigned architecture configs (public-literature specs; see each file).

Configs self-register into ``base._REGISTRY`` on import; use
``repro.configs.base.get_config(name)`` / ``list_architectures()`` (both
lazy-load every arch module).
"""
from .base import (ModelConfig, ShapeConfig, SHAPES, applicable_shapes,
                   get_config, list_architectures, register)
