"""DeepSeek-V3 671B [arXiv:2412.19437; hf]: MLA + 1 shared + 256 routed
top-8 MoE; 3 leading dense layers; MTP noted out of scope (orthogonal to the
paper's network technique -- DESIGN.md)."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
    d_ff=18432,                     # dense-layer FFN width
    vocab=129280, head_dim=128,
    n_experts=256, experts_per_tok=8, n_shared_experts=1,
    moe_d_ff=2048, n_dense_layers=3,
    mla=True, q_lora_rank=1536, kv_lora_rank=512,
    rope_head_dim=64, nope_head_dim=128, v_head_dim=128,
    rope_theta=10000.0, optimizer="adafactor", microbatch=8,
    fsdp_over_pod=True,
))
