"""Qwen3-30B-A3B [hf:Qwen/Qwen3-30B-A3B; hf]: 128 experts top-8, GQA kv=4."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4,
    d_ff=768, moe_d_ff=768, vocab=151936, head_dim=128,
    n_experts=128, experts_per_tok=8, n_dense_layers=0,
    rope_theta=1000000.0, optimizer="adamw", microbatch=4,
))
