"""Mamba2-130M [arXiv:2405.21060; unverified]: SSD, attention-free."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab=50304,                    # 50280 padded to %128 for TP
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_groups=1,
    tie_embeddings=True, optimizer="adamw", microbatch=2,
))
