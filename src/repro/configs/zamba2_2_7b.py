"""Zamba2-2.7B [arXiv:2411.15242; hf]: Mamba2 backbone + shared attention
block every 6 layers (single parameter copy, per-application KV caches)."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab=32000, head_dim=80,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_groups=1,
    shared_attn_every=6,
    optimizer="adamw", microbatch=4,
))
