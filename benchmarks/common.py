"""Shared benchmark scaffolding.

Scaling note: the paper simulates a 128-node (k=8) fat tree with 1 MB
messages (256 x 4 KB packets per flow) on an event-driven C++ simulator.  On
this 1-core CPU container we default to the same k=8 tree but smaller
messages (quick mode); ``--full`` restores paper-scale message sizes.  All
reported metrics are *relative* (CCT increase over the lower bound, queue
sizes in packets), which is what the paper's claims are about.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np

from repro.net.topology import FatTree, LinkState, rho_max
from repro.net import workloads, fastsim, loopsim
from repro.core import lb_schemes as lbs
from repro.core import theory

NET = theory.DEFAULT_NET
PROP_SLOTS = NET.prop_slots           # ~11.97


@dataclasses.dataclass
class Scale:
    k: int = 8
    perm_msg: int = 256               # packets per flow (paper: 256 = 1 MB)
    ata_msg: int = 8                  # per-destination packets
    runs: int = 2
    loop_runs: int = 1
    max_slots: int = 60_000


QUICK = Scale()
FULL = Scale(perm_msg=256, ata_msg=32, runs=3, loop_runs=2)


_rows: List[str] = []


def emit(name: str, us_per_call: float, **derived):
    kv = ",".join(f"{k}={v}" for k, v in derived.items())
    row = f"{name},{us_per_call:.1f},{kv}"
    _rows.append(row)
    print(row, flush=True)


def rows():
    return list(_rows)


def timed(fn):
    t0 = time.time()
    out = fn()
    return out, (time.time() - t0) * 1e6


# --------------------------------------------------------------------------
# Lower bounds in *slots* for normalized CCT-increase metrics.
# --------------------------------------------------------------------------

def perm_bound_slots(m: int) -> float:
    """Data-delivery lower bound in slots: last packet leaves the host at
    (m-1) slots, then 6 store-and-forward serializations + 6 propagations.
    (The engines measure data CCT; the App-B bound -- which adds the ACK
    return dynamics -- is validated separately in tests/test_theory.py.)"""
    t_d = NET.frame_B * 8 / NET.link_rate_bps / NET.slot_s
    return (m - 1) + 6 * t_d + 6 * PROP_SLOTS


def ata_bound_slots(tree: FatTree, per_dst: int) -> float:
    total = per_dst * (tree.n_hosts - 1)
    return total + 5 * 1.0 + 6 * PROP_SLOTS


def fast_cct_increase(tree, wl, scheme_name, bound_slots, seed=0, **kw):
    res = fastsim.simulate(tree, wl, lbs.by_name(scheme_name), seed=seed,
                           prop_slots=PROP_SLOTS, **kw)
    # add the ACK return leg the bound includes for permutation workloads
    return 100.0 * (res.cct / bound_slots - 1.0), res


def loop_cct_increase(tree, wl, scheme_name, bound_slots, cfg=None, seed=0,
                      **kw):
    cfg = cfg or loopsim.LoopConfig()
    res = loopsim.simulate(tree, wl, lbs.by_name(scheme_name), cfg,
                           seed=seed, **kw)
    return 100.0 * (res.cct_slots / bound_slots - 1.0), res
