"""Per-kernel microbenchmarks for the slot-step Pallas kernels.

Times each ``kernels/slot_step`` op three ways on synthetic engine-shaped
operands:

  * **lax**: the inline engine formulation extracted as a jitted closure
    (for the SACK scoreboard this is the *per-send-lane* window scan the
    engine used before the kernel fused it per-flow);
  * **xla**: the ``ref.py`` oracle through the ``ops`` backend switch --
    what ``LoopConfig.impl="pallas"`` would run if Pallas were unavailable;
  * **pallas_interpret**: the Pallas kernel in interpret mode (the only
    mode available off-TPU).  Interpret mode is a *correctness* vehicle,
    not a performance one -- expect it orders of magnitude slower on CPU;
    the number is recorded so TPU runs have a baseline to compare against.

Results merge under ``BENCH_sweep.json:"kernels"`` (same merge contract as
``sweep_bench``), one sample per op with microseconds per call and the
operand shapes.  Registered as ``--only kernels`` in ``benchmarks.run``.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import entropy as ent
from repro.net._batching import rank_by
from repro.kernels.slot_step import ops as slot_ops

from . import common as C
from .sweep_bench import SMOKE, _merge_bench_json


def _bench(fn, iters):
    """Median-of-iters wall time per call in us (first call compiles)."""
    jax.block_until_ready(fn())
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append(time.perf_counter() - t0)
    return float(np.median(times)) * 1e6


def _operands(rng):
    """Synthetic engine-shaped operands: a k=8-ish switch layer (h ports,
    NQ queues of CAP slots) with M in-flight arrival lanes over F flows."""
    m = 32 if SMOKE else 128        # choosers / arrival lanes
    h = 8                           # ports per switch
    nq = 64 if SMOKE else 256       # queues in the layer stack
    cap = 16
    f = 32 if SMOKE else 128        # flows
    per_flow = 32 if SMOKE else 64
    p = f * per_flow                # packets
    ops = {
        "m": m, "h": h, "nq": nq, "cap": cap, "f": f, "p": p,
        "qcnt": jnp.asarray(rng.integers(0, cap, nq), jnp.int32),
        "qbuf": jnp.asarray(rng.integers(-1, p, (nq, cap)), jnp.int32),
        "qhead": jnp.asarray(rng.integers(0, cap, nq), jnp.int32),
        "qbase": jnp.asarray(rng.integers(0, nq - h, m), jnp.int32),
        "ids": jnp.arange(m, dtype=jnp.int32),
        "dead": jnp.asarray(rng.random((m, h)) < 0.1),
        "pad_pen": jnp.where(jnp.arange(h) < h - 1, 0.0, 1e9
                             ).astype(jnp.float32),
        "alive": jnp.asarray(rng.random(nq) < 0.95),
        "apk": jnp.asarray(np.where(rng.random(m) < 0.8,
                                    rng.integers(0, p, m), -1), jnp.int32),
        "aq": jnp.asarray(rng.integers(0, nq, m), jnp.int32),
        "asw": jnp.asarray(rng.integers(0, 4, m), jnp.int32),
        "p_recv": jnp.asarray(rng.random(p) < 0.5),
        "pk": jnp.asarray(rng.integers(0, p, m), jnp.int32),
        "deliv": jnp.asarray(rng.random(m) < 0.5),
        "f_cum": jnp.asarray(rng.integers(0, per_flow, f), jnp.int32),
        "fsize": jnp.full((f,), per_flow, jnp.int32),
        "pbase": jnp.arange(f, dtype=jnp.int32) * per_flow,
        "sfv": jnp.asarray(rng.integers(0, f, m), jnp.int32),
    }
    ops["avalid"] = ops["apk"] >= 0
    ops["to_agg"] = ops["avalid"] & (ops["aq"] < 4 * ops["h"])
    return ops


def _sack_lane_scan_closure(o):
    """The pre-kernel engine formulation: scatter, then the 64-wide
    first-missing window per *send lane* (gathered per-lane flow state)."""
    @jax.jit
    def run(p_recv, pk, deliv, f_cum, fsize, sfv):
        P = p_recv.shape[0]
        prec = p_recv.at[jnp.where(deliv, pk, P)].set(True, mode="drop")
        base = f_cum[sfv]
        offs = jnp.arange(64)[None, :]
        cand = jnp.minimum(base[:, None] + offs, fsize[sfv][:, None] - 1)
        got = prec[o["pbase"][sfv][:, None] + cand]
        fm = cand[jnp.arange(cand.shape[0]), jnp.argmin(got, axis=1)]
        return prec, fm
    return lambda: run(o["p_recv"], o["pk"], o["deliv"], o["f_cum"],
                       o["fsize"], o["sfv"])


def kernel_microbench(scale: C.Scale):
    """Slot-step kernel microbench: pallas-interpret vs xla oracle vs the
    inline lax closures, merged under BENCH_sweep.json:"kernels"."""
    iters = 5 if SMOKE else 20
    rng = np.random.default_rng(0)
    o = _operands(rng)
    quanta = (0.05, 0.10, 0.20)
    seed_lo, seed_hi, t = jnp.uint32(0x1234), jnp.uint32(0x9e37), 17

    def _jsq(backend, quanta_):
        fn = jax.jit(lambda qc: slot_ops.jsq_pick(
            qc, o["qbase"], o["ids"], o["dead"], o["pad_pen"],
            seed_lo, seed_hi, t, site=ent.SITE_EDGE_JSQ, quanta=quanta_,
            cap=o["cap"], backend=backend))
        return lambda: fn(o["qcnt"])

    def _enq(backend):
        fn = jax.jit(lambda qb, qc: slot_ops.enqueue(
            qb, o["qhead"], qc, o["alive"], o["apk"], o["aq"], o["avalid"],
            cap=o["cap"], ecn_thresh=12, backend=backend))
        return lambda: fn(o["qbuf"], o["qcnt"])

    def _agg(backend):
        fn = jax.jit(lambda qb, qc: slot_ops.agg_jsq_enqueue(
            qb, o["qhead"], qc, o["alive"], o["apk"], o["aq"], o["to_agg"],
            o["asw"], o["dead"], o["pad_pen"], seed_lo, seed_hi, t,
            site=ent.SITE_AGG_JSQ, quanta=None, cap=o["cap"], ecn_thresh=12,
            off1=0, h=o["h"], backend=backend))
        return lambda: fn(o["qbuf"], o["qcnt"])

    def _sack_up(backend):
        fn = jax.jit(lambda pr: slot_ops.sack_update_scan(
            pr, o["pk"], o["deliv"], o["f_cum"], o["fsize"], o["pbase"],
            backend=backend))
        return lambda: fn(o["p_recv"])

    def _sack_adv(backend):
        fn = jax.jit(lambda fc: slot_ops.sack_advance(
            o["p_recv"], fc, o["fsize"], o["pbase"], backend=backend))
        return lambda: fn(o["f_cum"])

    # The inline engine blocks as standalone jitted closures.  For jsq/
    # enqueue the inline code IS the ref formulation (ref.py mirrors it
    # op-for-op), so "lax" times the same computation outside the ops
    # dispatch layer; the SACK lane scan is genuinely different code.
    @jax.jit
    def _lax_enqueue(qbuf, qcnt):
        aq, apk, avalid = o["aq"], o["apk"], o["avalid"]
        aqc = jnp.clip(aq, 0, o["nq"] - 1)
        enq_try = avalid & o["alive"][aqc]
        rkq = rank_by(aq, enq_try)
        do_enq = enq_try & (qcnt[aqc] + rkq < o["cap"])
        pos = (o["qhead"][aqc] + qcnt[aqc] + rkq) % o["cap"]
        qbuf = qbuf.at[jnp.where(do_enq, aq, o["nq"]),
                       jnp.where(do_enq, pos, 0)].set(
            jnp.where(do_enq, apk, -1), mode="drop")
        return qbuf, qcnt.at[jnp.where(do_enq, aq, o["nq"])].add(
            1, mode="drop")

    samples = {}
    cases = [
        ("jsq_pick", _jsq("xla", None), _jsq("pallas", None),
         _jsq("xla", None)),
        ("jsq_pick_quant", _jsq("xla", quanta), _jsq("pallas", quanta),
         _jsq("xla", quanta)),
        ("enqueue", lambda: _lax_enqueue(o["qbuf"], o["qcnt"]),
         _enq("pallas"), _enq("xla")),
        ("agg_jsq_enqueue", _agg("xla"), _agg("pallas"), _agg("xla")),
        ("sack_update_scan", _sack_lane_scan_closure(o),
         _sack_up("pallas"), _sack_up("xla")),
        ("sack_advance", _sack_adv("xla"), _sack_adv("pallas"),
         _sack_adv("xla")),
    ]
    for name, lax_fn, pallas_fn, xla_fn in cases:
        lax_us = _bench(lax_fn, iters)
        xla_us = _bench(xla_fn, iters)
        pal_us = _bench(pallas_fn, max(2, iters // 4))
        samples[name] = {
            "lax_us": round(lax_us, 1),
            "xla_us": round(xla_us, 1),
            "pallas_interpret_us": round(pal_us, 1),
        }
        C.emit(f"kernel_{name}", xla_us, lax_us=round(lax_us, 1),
               pallas_interpret_us=round(pal_us, 1))

    result = {
        "shapes": {k: o[k] for k in ("m", "h", "nq", "cap", "f", "p")},
        "iters": iters, "smoke": SMOKE,
        "on_tpu": jax.default_backend() == "tpu",
        "note": ("pallas numbers are interpret-mode (off-TPU): a "
                 "correctness baseline, not a perf claim"),
        "samples": samples,
    }
    _merge_bench_json({"kernels": result})
    return result
