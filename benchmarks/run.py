"""Benchmark entry point: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run              # quick scale
    PYTHONPATH=src python -m benchmarks.run --full       # paper-scale msgs
    PYTHONPATH=src python -m benchmarks.run --only fig6,tbl3

Prints ``name,us_per_call,derived`` CSV rows (and writes them to
``experiments/bench_results.csv``).
"""
from __future__ import annotations

import argparse
import pathlib
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset, e.g. fig1,fig6,tbl3")
    ap.add_argument("--out", default="experiments/bench_results.csv")
    args = ap.parse_args(argv)

    from . import common as C
    from . import paper_figs

    scale = C.FULL if args.full else C.QUICK
    names = (args.only.split(",") if args.only
             else list(paper_figs.ALL))
    t0 = time.time()
    for name in names:
        fn = paper_figs.ALL[name]
        print(f"# --- {name} ({fn.__doc__.strip().splitlines()[0]}) ---",
              flush=True)
        fn(scale)
    out = pathlib.Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text("\n".join(C.rows()) + "\n")
    print(f"# done in {time.time()-t0:.0f}s -> {out}")


if __name__ == "__main__":
    main()
