"""One benchmark per paper table/figure.  Each function prints CSV rows
``name,us_per_call,key=value,...`` and returns a dict for EXPERIMENTS.md.

Figure -> function map (paper artifact in parens):

  fig1   LB scheme comparison, no failures (Fig. 1)
  fig3   randomized failures, G = inf (Fig. 3)
  fig4   convergence-time sweep (Fig. 4)
  fig5   failure-rate sweep at G=0 (Fig. 5)
  fig6   queue scaling vs message size (Fig. 6)
  fig7   per-layer worst-case link overload (Fig. 7)
  fig8   network-size scaling (Fig. 8)
  fig9   short buffers (Fig. 9)
  fig10  message-size sweep (Fig. 10)
  fig11  packet-size sweep + Thm 5 model (Fig. 11)
  fig12  SACK loss recovery (Fig. 12)
  fig13  MSwift congestion control (Fig. 13)
  fig14  FSDP Llama training scenario (Fig. 14)
  tbl3   queue-scaling law fits (Table 3)
"""
from __future__ import annotations

import numpy as np

from repro.net.topology import FatTree, rho_max
from repro.net import workloads, fastsim, loopsim
from repro.core import lb_schemes as lbs
from repro.core import theory
from repro import sweep

from . import common as C


FAST_SCHEMES = ["flow_ecmp", "subflow_mptcp", "host_pkt", "switch_pkt",
                "switch_pkt_ar"]
LOOP_ONLY = ["host_flowlet_ar", "host_pkt_ar"]
DR = ["host_dr", "ofan"]


def _us_by(store: sweep.ResultStore, keyfn):
    """Aggregate per-batch wall times (us/point) by an arbitrary batch key.

    Timing caveat: megabatch dispatch time is apportioned over the fused
    members, and the first dispatch of each compiled shape pays the jit
    compile; comparisons reflect batch composition, not inherent scheme
    cost."""
    tot_us: dict = {}
    n_pts: dict = {}
    for batch, secs in store.timings:
        key = keyfn(batch)
        tot_us[key] = tot_us.get(key, 0.0) + secs * 1e6
        n_pts[key] = n_pts.get(key, 0) + len(batch.seeds)
    return {k: tot_us[k] / n_pts[k] for k in tot_us}


def _run_grid(campaign: sweep.Campaign):
    """Execute a campaign grid; returns (records, per-scheme us/point, store)."""
    store = sweep.ResultStore(None)
    sweep.run_campaign(campaign, store=store)
    return store.records, _us_by(store, lambda b: b.scheme), store


def fig1(scale: C.Scale):
    """CCT increase over the lower bound, permutation + all-to-all.

    Fast-engine schemes run as one campaign per traffic matrix: every
    (scheme, seed) cell of the grid executes inside seed-vmapped batched
    dispatches instead of per-seed simulate calls."""
    tree = FatTree(scale.k)
    out = {}
    for matrix in ("perm", "ata"):
        if matrix == "perm":
            load = sweep.WorkloadSpec("permutation", scale.perm_msg,
                                      rng_seed=1)
            bound = C.perm_bound_slots(scale.perm_msg)
        else:
            load = sweep.WorkloadSpec("all_to_all", scale.ata_msg)
            bound = C.ata_bound_slots(tree, scale.ata_msg)
        recs, us, _ = _run_grid(sweep.Campaign(
            name=f"fig1_{matrix}", schemes=tuple(FAST_SCHEMES + DR),
            loads=(load,), trees=(scale.k,),
            seeds=tuple(range(scale.runs)), prop_slots=C.PROP_SLOTS))
        for name in FAST_SCHEMES + DR:
            incs = [100.0 * (r["cct"] / bound - 1.0) for r in recs
                    if r["scheme"] == name]
            C.emit(f"fig1_{matrix}_{name}", us[name],
                   cct_increase_pct=round(float(np.mean(incs)), 2))
            out[(matrix, name)] = float(np.mean(incs))
        recs, us, _ = _run_grid(sweep.Campaign(
            name=f"fig1_{matrix}_loop", schemes=tuple(LOOP_ONLY),
            loads=(load,), trees=(scale.k,), seeds=(0,), engine="loop",
            max_slots=scale.max_slots))
        for r in recs:
            inc = 100.0 * (r["cct"] / bound - 1.0)
            C.emit(f"fig1_{matrix}_{r['scheme']}", us[r["scheme"]],
                   cct_increase_pct=round(inc, 2), engine="loop")
            out[(matrix, r["scheme"])] = inc
    return out


def _failure_campaign(scale: C.Scale, name, schemes, failures, g_converge):
    """Shared spec of the §5.2 failure studies: permutation traffic, rho
    pinned to rho_max under each failure pattern, loop engine."""
    return sweep.Campaign(
        name=name, schemes=tuple(schemes),
        loads=(sweep.WorkloadSpec("permutation", scale.perm_msg, rng_seed=1),),
        trees=(scale.k,), seeds=(0,), engine="loop",
        failures=tuple(failures), g_converge=tuple(g_converge),
        max_slots=scale.max_slots,
        loop_opts=(("rho", "auto"), ("rto_slots", 300)))


def _failure_bound(tree, wl, fspec, scale: C.Scale) -> float:
    links = sweep.build_links(tree, fspec)
    rho = rho_max(tree, links, wl.flow_src, wl.flow_dst)
    return (C.perm_bound_slots(scale.perm_msg) / rho if rho > 0 else np.inf)


def fig3(scale: C.Scale, p_fail=0.01):
    """Randomized failures with G = inf (campaign grid on the megabatch
    runner; the loop engine serves the ACK/ECN schemes)."""
    tree = FatTree(scale.k)
    wl = workloads.permutation(tree, scale.perm_msg, np.random.default_rng(1))
    fspec = sweep.FailureSpec(p_fail, rng_seed=42)
    bound = _failure_bound(tree, wl, fspec, scale)
    recs, us, _ = _run_grid(_failure_campaign(
        scale, "fig3", ["host_pkt", "switch_pkt", "host_pkt_ar",
                        "switch_pkt_ar", "ofan"], [fspec], [None]))
    out = {}
    for r in recs:
        inc = 100.0 * (r["cct"] / bound - 1.0)
        C.emit(f"fig3_perm_{r['scheme']}", us[r["scheme"]],
               cct_increase_pct=round(inc, 2), drops=r["drops"],
               finished=r["finished"])
        out[r["scheme"]] = inc
    return out


def fig4(scale: C.Scale, p_fail=0.01):
    """CCT vs convergence time G: one campaign with g_converge as a grid
    axis (in multiples of min RTT ~87 slots)."""
    tree = FatTree(scale.k)
    wl = workloads.permutation(tree, scale.perm_msg, np.random.default_rng(1))
    fspec = sweep.FailureSpec(p_fail, rng_seed=42)
    bound = _failure_bound(tree, wl, fspec, scale)
    rtt = int(6 * C.PROP_SLOTS + 15)
    g_rtts = [0, 1, 4, 16, 64]
    store = sweep.ResultStore(None)
    recs, _ = sweep.run_campaign(_failure_campaign(
        scale, "fig4", ["host_pkt_ar", "switch_pkt_ar"], [fspec],
        [g * rtt for g in g_rtts]), store=store)
    us = _us_by(store, lambda b: (b.g_converge, b.scheme))
    out = {}
    for r in recs:
        g_rtt = r["g_converge"] // rtt
        inc = 100.0 * (r["cct"] / bound - 1.0)
        C.emit(f"fig4_G{g_rtt}rtt_{r['scheme']}",
               us[(r["g_converge"], r["scheme"])],
               cct_increase_pct=round(inc, 2), drops=r["drops"])
        out[(g_rtt, r["scheme"])] = inc
    return out


def fig5(scale: C.Scale):
    """Failure-rate sweep at G=0: one campaign with the failure pattern as
    a grid axis."""
    tree = FatTree(scale.k)
    wl = workloads.permutation(tree, scale.perm_msg, np.random.default_rng(1))
    fspecs = [sweep.FailureSpec(p, rng_seed=7) for p in (0.01, 0.04, 0.08)]
    bounds = {f.label(): _failure_bound(tree, wl, f, scale) for f in fspecs}
    p_fails = {f.label(): f.p_fail for f in fspecs}
    fspecs = [f for f in fspecs if np.isfinite(bounds[f.label()])]
    store = sweep.ResultStore(None)
    recs, _ = sweep.run_campaign(_failure_campaign(
        scale, "fig5", ["host_pkt_ar", "switch_pkt_ar", "ofan"], fspecs,
        [0]), store=store)
    us = _us_by(store, lambda b: (b.failure.label(), b.scheme))
    out = {}
    for r in recs:
        p_fail = p_fails[r["failure"]]
        inc = 100.0 * (r["cct"] / bounds[r["failure"]] - 1.0)
        C.emit(f"fig5_p{p_fail}_{r['scheme']}", us[(r["failure"], r["scheme"])],
               cct_increase_pct=round(inc, 2), drops=r["drops"])
        out[(p_fail, r["scheme"])] = inc
    return out


def fig6(scale: C.Scale):
    """Max queue size + CCT vs message size (the Table-3 clusters)."""
    tree = FatTree(scale.k)
    ms = [64, 256, 1024] + ([4096] if scale.runs > 2 else [])
    out = {}
    for name in ["simple_rr", "jsq", "rsq", "host_pkt", "switch_pkt_ar",
                 "host_dr", "ofan"]:
        for m in ms:
            wl = workloads.permutation(tree, m, np.random.default_rng(2),
                                       inter_pod_only=True)
            res, us = C.timed(lambda: fastsim.simulate(
                tree, wl, lbs.by_name(name), seed=3,
                prop_slots=C.PROP_SLOTS))
            C.emit(f"fig6_{name}_m{m}", us, max_queue_pkts=round(
                res.max_queue, 1), cct_slots=round(res.cct, 1))
            out[(name, m)] = res.max_queue
    # REPS via the loop engine
    cfg = loopsim.LoopConfig(max_slots=scale.max_slots)
    for m in ms[:2]:
        wl = workloads.permutation(tree, m, np.random.default_rng(2),
                                   inter_pod_only=True)
        res, us = C.timed(lambda: loopsim.simulate(
            tree, wl, lbs.host_pkt_ar(), cfg, seed=3))
        C.emit(f"fig6_host_pkt_ar_m{m}", us, max_queue_pkts=res.max_queue,
               cct_slots=res.cct_slots)
        out[("host_pkt_ar", m)] = res.max_queue
    return out


def fig7(scale: C.Scale):
    """Worst-case per-layer load increase beyond ideal (campaign grid; the
    per-layer overload ratios come straight off the point records)."""
    recs, us, _ = _run_grid(sweep.Campaign(
        name="fig7",
        schemes=("simple_rr", "jsq", "host_pkt", "host_dr", "ofan"),
        loads=(sweep.WorkloadSpec("permutation", scale.perm_msg,
                                  inter_pod_only=True, rng_seed=4),),
        trees=(scale.k,), seeds=(5,), prop_slots=C.PROP_SLOTS))
    out = {}
    for r in recs:
        overloads = {layer: round(r[f"overload_{layer.replace('->', '_')}"], 3)
                     for layer in ("E->A", "A->C", "C->A", "A->E")}
        C.emit(f"fig7_{r['scheme']}", us[r["scheme"]],
               **{f"ovl_{k.replace('->', '_')}": v
                  for k, v in overloads.items()})
        out[r["scheme"]] = overloads
    return out


def fig8(scale: C.Scale):
    """Network-size scaling."""
    out = {}
    for k in [4, 8] + ([16] if scale.runs > 2 else []):
        tree = FatTree(k)
        wl = workloads.permutation(tree, scale.perm_msg,
                                   np.random.default_rng(1))
        bound = C.perm_bound_slots(scale.perm_msg)
        for name in ["switch_pkt_ar", "host_pkt", "ofan"]:
            (inc, _), us = C.timed(
                lambda: C.fast_cct_increase(tree, wl, name, bound, seed=1))
            C.emit(f"fig8_k{k}_{name}", us, cct_increase_pct=round(inc, 2),
                   hosts=tree.n_hosts)
            out[(k, name)] = inc
    return out


def fig9(scale: C.Scale):
    """Short (20-packet) buffers: one loop-engine campaign, schemes fused
    per compiled slotted-pipeline shape."""
    bound = C.perm_bound_slots(scale.perm_msg)
    recs, us, _ = _run_grid(sweep.Campaign(
        name="fig9", schemes=("host_pkt", "switch_pkt_ar", "ofan"),
        loads=(sweep.WorkloadSpec("permutation", scale.perm_msg, rng_seed=1),),
        trees=(scale.k,), seeds=(0,), engine="loop",
        max_slots=scale.max_slots,
        loop_opts=(("buffer_pkts", 20), ("loss", "sack"),
                   ("sack_thresh", 8))))
    out = {}
    for r in recs:
        inc = 100.0 * (r["cct"] / bound - 1.0)
        C.emit(f"fig9_{r['scheme']}", us[r["scheme"]],
               cct_increase_pct=round(inc, 2), drops=r["drops"],
               rtx=r["retransmissions"])
        out[r["scheme"]] = inc
    return out


def fig10(scale: C.Scale):
    """Message-size sweep."""
    tree = FatTree(scale.k)
    out = {}
    for m in [64, 256, 1024]:
        wl = workloads.permutation(tree, m, np.random.default_rng(1))
        bound = C.perm_bound_slots(m)
        for name in ["switch_pkt_ar", "host_pkt", "ofan"]:
            (inc, _), us = C.timed(
                lambda: C.fast_cct_increase(tree, wl, name, bound, seed=2))
            C.emit(f"fig10_m{m}_{name}", us, cct_increase_pct=round(inc, 2))
            out[(m, name)] = inc
    return out


def fig11(scale: C.Scale):
    """Packet-size sweep + Theorem 5 optimum."""
    tree = FatTree(scale.k)
    out = {}
    H = 82.0
    for D in [1 << 20, 32 << 10]:          # 1 MB and 32 KB messages
        best = (None, np.inf)
        for payload in [1024, 2048, 4096, 8192]:
            m = max(2, int(round(D / payload)))
            slot_s = (payload + H) * 8 / C.NET.link_rate_bps
            wl = workloads.permutation(tree, m, np.random.default_rng(1),
                                       inter_pod_only=True)
            res, us = C.timed(lambda: fastsim.simulate(
                tree, wl, lbs.ofan(), seed=1,
                prop_slots=C.NET.link_latency_s / slot_s))
            cct_s = res.cct * slot_s
            C.emit(f"fig11_D{D}_P{payload}", us,
                   cct_us=round(cct_s * 1e6, 2),
                   queue=round(res.max_queue, 1))
            out[(D, payload)] = cct_s
            if cct_s < best[1]:
                best = (payload, cct_s)
        p_star = theory.optimal_payload_B(D, header_B=H, alpha_pkts=10)
        C.emit(f"fig11_D{D}_thm5", 0.0, model_opt_payload=round(p_star),
               sim_best_payload=best[0])
        out[(D, "thm5")] = p_star
    return out


def fig12(scale: C.Scale):
    """SACK-based loss recovery: the ``fig12`` campaign preset scaled to the
    benchmark's message size (host_pkt rides the fused 'pre/pre' slotted
    dispatch; adaptive/switch schemes compile their own shapes)."""
    bound = C.perm_bound_slots(scale.perm_msg)
    recs, us, _ = _run_grid(sweep.Campaign(
        name="fig12",
        schemes=("host_pkt", "switch_pkt_ar", "host_pkt_ar", "ofan"),
        loads=(sweep.WorkloadSpec("permutation", scale.perm_msg, rng_seed=1),),
        trees=(scale.k,), seeds=(0,), engine="loop",
        max_slots=scale.max_slots,
        loop_opts=(("loss", "sack"), ("sack_thresh", 32))))
    out = {}
    for r in recs:
        inc = 100.0 * (r["cct"] / bound - 1.0)
        C.emit(f"fig12_{r['scheme']}", us[r["scheme"]],
               cct_increase_pct=round(inc, 2), rtx=r["retransmissions"])
        out[r["scheme"]] = inc
    return out


def fig13(scale: C.Scale):
    """MSwift CCA, short vs long messages (paper: 1 MB and 16 MB): ONE
    campaign with the message size as a grid axis."""
    ms = (scale.perm_msg, scale.perm_msg * 4)
    loads = {m: sweep.WorkloadSpec("permutation", m, rng_seed=1) for m in ms}
    store = sweep.ResultStore(None)
    recs, _ = sweep.run_campaign(sweep.Campaign(
        name="fig13", schemes=("host_pkt", "switch_pkt_ar", "ofan"),
        loads=tuple(loads.values()), trees=(scale.k,), seeds=(0,),
        engine="loop", max_slots=scale.max_slots,
        loop_opts=(("cca", "mswift"), ("loss", "sack"),
                   ("sw_target_slots", 120.0))), store=store)
    us = _us_by(store, lambda b: (b.load.msg_packets, b.scheme))
    by_label = {loads[m].label(): m for m in ms}
    out = {}
    for r in recs:
        m = by_label[r["workload"]]
        inc = 100.0 * (r["cct"] / C.perm_bound_slots(m) - 1.0)
        C.emit(f"fig13_m{m}_{r['scheme']}", us[(m, r["scheme"])],
               cct_increase_pct=round(inc, 2),
               mean_cwnd=round(r["mean_cwnd"], 1))
        out[(m, r["scheme"])] = inc
    return out


def fig14(scale: C.Scale):
    """FSDP Llama scenario: hierarchical 8-GPU-server rings, MSwift+SACK,
    as ONE campaign over the three Llama message sizes.

    Packets per flow follow the paper (104 / 418 / 1570 for 7B/70B/405B at
    FP8 + 4 KB payloads); the fabric is our k=8, 128-port tree (16 servers)
    vs the paper's 1024 GPUs -- ring structure and per-flow sizes match.
    """
    llamas = (("7B", 104), ("70B", 418), ("405B", 1570))
    loads = {m: sweep.WorkloadSpec("fsdp_rings", m, gpus_per_server=8,
                                   rng_seed=11) for _, m in llamas}
    store = sweep.ResultStore(None)
    recs, _ = sweep.run_campaign(sweep.Campaign(
        name="fig14", schemes=("host_pkt_ar", "switch_pkt_ar", "ofan"),
        loads=tuple(loads.values()), trees=(scale.k,), seeds=(0,),
        engine="loop", max_slots=scale.max_slots,
        loop_opts=(("cca", "mswift"), ("loss", "sack"),
                   ("sw_target_slots", 120.0))), store=store)
    us = _us_by(store, lambda b: (b.load.msg_packets, b.scheme))
    by_label = {loads[m].label(): (llama, m) for llama, m in llamas}
    out = {}
    for r in recs:
        llama, m = by_label[r["workload"]]
        inc = 100.0 * (r["cct"] / C.perm_bound_slots(m) - 1.0)
        C.emit(f"fig14_llama{llama}_{r['scheme']}", us[(m, r["scheme"])],
               cct_increase_pct=round(inc, 2),
               mean_cwnd=round(r["mean_cwnd"], 1))
        out[(llama, r["scheme"])] = inc
    return out


def tbl3(scale: C.Scale):
    """Queue-law fits q(m) = c*m^alpha (Table 3), from one campaign over the
    scheme x message-size grid."""
    ms = np.array([64, 256, 1024])
    expect = {"simple_rr": (0.7, 1.3), "jsq": (0.6, 1.3),
              "rsq": (0.25, 0.75), "host_pkt": (0.25, 0.75),
              "host_dr": (-0.2, 0.25), "ofan": (-0.2, 0.25)}
    recs, _, _ = _run_grid(sweep.Campaign(
        name="tbl3", schemes=tuple(expect),
        loads=tuple(sweep.WorkloadSpec("permutation", int(m),
                                       inter_pod_only=True, rng_seed=2)
                    for m in ms),
        trees=(scale.k,), seeds=(3,), prop_slots=C.PROP_SLOTS))
    qs = {(r["scheme"], r["workload"]): r["max_queue"] for r in recs}
    out = {}
    for name, (lo, hi) in expect.items():
        q = np.array([qs[(name, f"permutation-m{m}-xpod-r2")] for m in ms])
        alpha, c = theory.fit_power_law(ms, q)
        ok = lo <= alpha <= hi
        C.emit(f"tbl3_{name}", 0.0, alpha=round(alpha, 3),
               expected=f"[{lo}:{hi}]", ok=ok)
        out[name] = (alpha, ok)
    return out


from .sweep_bench import sweep_speedup  # noqa: E402  (registered below)
from .kernel_bench import kernel_microbench  # noqa: E402

ALL = {
    "fig1": fig1, "fig3": fig3, "fig4": fig4, "fig5": fig5, "fig6": fig6,
    "fig7": fig7, "fig8": fig8, "fig9": fig9, "fig10": fig10,
    "fig11": fig11, "fig12": fig12, "fig13": fig13, "fig14": fig14,
    "tbl3": tbl3, "sweep": sweep_speedup, "kernels": kernel_microbench,
}
