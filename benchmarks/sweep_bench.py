"""Campaign-engine speedup benchmark: megabatched grid vs older dispatch
patterns.

Runs one grid -- 4 LB schemes x 2 message sizes x replicate seeds on k=8
permutation workloads, only TWO compiled pipeline shapes: flow_ecmp,
host_pkt and host_dr all lower to the 'pre/pre' pipeline, switch_pkt
compiles rr_reset, and both message sizes land in one power-of-two packet
bucket so the megabatch planner pads them onto a single fused shape.  Four
ways:

  * **megabatch**: ``sweep.run_campaign`` on the fused runner; the planner
    emits ONE jitted dispatch per compiled shape (scheme, load and seed
    axes stacked onto one fused batch axis, ``shard_map``-sharded when
    several devices are visible);
  * **pr1**: the previous runner generation -- one seed-vmapped
    ``fastsim.simulate_batch`` call *per (scheme, load)* cell, ordered for
    compile-cache reuse.  Without shape bucketing every message size is its
    own compiled shape, so this path compiles twice per pipeline
    (``speedup_pr1`` is the megabatch-vs-PR1 headline this PR's tentpole
    is about);
  * **serial-warm**: one ``fastsim.simulate`` call per (scheme, seed) cell
    in a single process, compiles amortized by the in-process lru-cache;
  * **serial-isolated**: the per-point-job pattern the campaign subsystem
    replaces (fresh process per grid point, recompiling every time).
    Measured honestly by clearing the compile caches and sampling one cold
    point **per compiled shape actually present in the grid**, then
    extrapolating each shape's cold cost over its own point count.

A **loop-engine sample** rides along: a host_pkt + host_dr x seeds grid on
the slotted feedback engine, run once through the fused
``loopsim.simulate_megabatch`` dispatch (both schemes share the 'pre/pre'
slotted pipeline, so the planner emits ONE dispatch) and once as the serial
per-point ``loopsim.simulate`` loop, recorded under the ``"loop"`` key.

A **cross-k sample** (``"kfuse"`` key) measures tree-size fusion: one grid
sweeping fat-tree size with fixed schemes/loads, run once as the fused
campaign (every k pads to the bucket head: ONE dispatch per compiled
shape) and once as the per-k campaign pattern it replaces (one campaign
per tree size, each compiling its own pipeline shape).  Per-point CCTs are
verified identical before timing is reported.

A **loop-engine cross-k sample** (``"kfuse_loop"`` key) does the same for
the slotted engine's randomized switch schemes (rand + quantized JSQ) --
the family whose in-loop draws used to pin fused keys to raw ``k`` and now
rides counter streams (``core.entropy``): a (scheme x tree x seed) grid as
one fused dispatch per scheme vs one campaign per tree size.

A **faults sample** (``"faults"`` key) prices dynamic fault injection: a
mixed campaign (no-failure, static random failures, a 3-epoch link flap
schedule) fused onto one dispatch per compiled shape vs the serial
per-point ``fastsim.simulate(..., fault=...)`` loop, CCTs verified
identical first.

A **telemetry sample** (``"telemetry"`` key) measures the observability
layer's own cost: the timed megabatch run carries a live
``obs.TraceWriter`` (so ``megabatch_s`` *includes* tracing), and the
recorded span count, cumulative emit seconds and emit-to-wall fraction are
reported alongside the trace's padding-fill counters.  A probe subsection
re-runs a small slice with ``Campaign.probes`` on and verifies the
series-max-equals-``max_queue`` invariant before reporting the probed wall
time.

Per-point results are verified identical (exact CCT equality) between the
megabatched and serial paths before any timing is reported.  Results are
merged (not overwritten) into ``BENCH_sweep.json`` (``"schema": 2``) at the
repo root so the perf trajectory -- and sections written by other tools --
survive across PRs.

Smoke mode (``SWEEP_BENCH_SMOKE=1``, used by CI with
``--xla_force_host_platform_device_count=2``) shrinks the grid so the
multi-device sharded path is exercised on every PR in seconds.
"""
from __future__ import annotations

import json
import os
import pathlib
import time

import numpy as np

from repro.net.topology import FatTree
from repro.net import fastsim, loopsim
from repro.core import lb_schemes as lbs
from repro import sweep
from repro.obs import ProbeSpec, TraceWriter

from . import common as C

SCHEMES = ("host_pkt", "flow_ecmp", "host_dr", "switch_pkt")
LOOP_SCHEMES = ("host_pkt", "host_dr")   # both 'pre/pre': ONE fused dispatch
N_SEEDS = 8
MSGS = (64, 48)        # both land in one power-of-two packet-shape bucket
SMOKE = os.environ.get("SWEEP_BENCH_SMOKE", "") not in ("", "0")
BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / "BENCH_sweep.json"


def _clear_compile_caches():
    fastsim._build_run.cache_clear()
    loopsim._compiled.cache_clear()


def _loop_sample(k: int, tree: FatTree):
    """Loop-engine sample: a scheme x seed grid through the fused slotted
    megabatch (one dispatch: host_pkt and host_dr share the 'pre/pre'
    engine) vs the serial per-point ``loopsim.simulate`` loop, verified
    exactly equal before timing is reported."""
    seeds = tuple(range(2 if SMOKE else 4))
    load = sweep.WorkloadSpec("permutation", 12 if SMOKE else 48, rng_seed=1)
    campaign = sweep.Campaign(
        name="sweep_bench_loop", schemes=LOOP_SCHEMES, loads=(load,),
        trees=(k,), seeds=seeds, engine="loop", max_slots=20000,
        loop_opts=(("loss", "sack"),))
    p = sweep.plan(campaign)

    _clear_compile_caches()
    t0 = time.perf_counter()
    records, _ = sweep.run_campaign(campaign)
    mega_s = time.perf_counter() - t0

    _clear_compile_caches()
    wl = sweep.build_workload(tree, load)
    cfg = campaign.loop_config()
    t0 = time.perf_counter()
    serial = {(nm, s): loopsim.simulate(tree, wl, lbs.by_name(nm), cfg,
                                        seed=s).cct_slots
              for nm in LOOP_SCHEMES for s in seeds}
    serial_s = time.perf_counter() - t0

    batched = {(r["scheme"], r["seed"]): r["cct"] for r in records}
    mismatches = [key for key in serial if serial[key] != batched[key]]
    assert not mismatches, f"loop megabatch CCTs diverge: {mismatches}"

    # Isolated-job pattern: every grid point recompiles the slotted engine
    # (one cold point sampled, extrapolated over the grid).
    _clear_compile_caches()
    t0 = time.perf_counter()
    loopsim.simulate(tree, wl, lbs.by_name(LOOP_SCHEMES[0]), cfg,
                     seed=seeds[0])
    cold_s = time.perf_counter() - t0
    isolated_s = cold_s * campaign.n_points

    from repro.kernels.slot_step import ops as slot_ops
    return {
        "grid": {"k": k, "msg_packets": load.msg_packets,
                 "schemes": list(LOOP_SCHEMES), "n_seeds": len(seeds),
                 "points": campaign.n_points},
        "plan": {"n_dispatches": p.n_dispatches, "n_shapes": p.n_shapes},
        # Which slot-step implementation produced these numbers (lax vs
        # pallas), so the perf trajectory in BENCH_sweep.json stays legible.
        "impl": slot_ops.resolve_impl(campaign.loop_config().impl),
        "megabatch_s": round(mega_s, 3),
        "serial_warm_s": round(serial_s, 3),
        "serial_isolated_s": round(isolated_s, 3),
        "speedup_vs_warm": round(serial_s / mega_s, 2),
        "speedup_vs_isolated": round(isolated_s / mega_s, 2),
    }


def _kfuse_sample():
    """Cross-k fusion sample: a (scheme x tree size x seed) grid as ONE
    fused campaign (tree sizes share a k-bucket, so the planner emits one
    dispatch per compiled shape) vs the per-k campaign pattern tree sweeps
    used before tree-size bucketing (each k compiles its own shape)."""
    trees = (4, 6) if SMOKE else (4, 6, 8)
    seeds = tuple(range(2 if SMOKE else 4))
    schemes = ("host_pkt", "host_dr")
    load = sweep.WorkloadSpec("permutation", 8 if SMOKE else 32, rng_seed=1)

    fused_c = sweep.Campaign(name="sweep_bench_kfuse", schemes=schemes,
                             loads=(load,), trees=trees, seeds=seeds)
    p = sweep.plan(fused_c)
    assert p.n_dispatches == 1, p.describe()

    _clear_compile_caches()
    t0 = time.perf_counter()
    records, _ = sweep.run_campaign(fused_c)
    fused_s = time.perf_counter() - t0

    _clear_compile_caches()
    t0 = time.perf_counter()
    per_k_records = []
    for k in trees:
        recs, _ = sweep.run_campaign(sweep.Campaign(
            name="sweep_bench_kfuse", schemes=schemes, loads=(load,),
            trees=(k,), seeds=seeds))
        per_k_records.extend(recs)
    per_k_s = time.perf_counter() - t0

    fused_cct = {(r["scheme"], r["k"], r["seed"]): r["cct"] for r in records}
    per_k_cct = {(r["scheme"], r["k"], r["seed"]): r["cct"]
                 for r in per_k_records}
    assert fused_cct == per_k_cct, "cross-k fused CCTs diverge from per-k"

    return {
        "grid": {"trees": list(trees), "msg_packets": load.msg_packets,
                 "schemes": list(schemes), "n_seeds": len(seeds),
                 "points": fused_c.n_points},
        "plan": {"n_dispatches": p.n_dispatches, "n_shapes": p.n_shapes,
                 "k_pad": p.megabatches[0].k_pad},
        "fused_s": round(fused_s, 3),
        "per_k_s": round(per_k_s, 3),
        "speedup_vs_per_k": round(per_k_s / fused_s, 2),
    }


def _kfuse_loop_sample():
    """Loop-engine cross-k fusion for rand/JSQ switch schemes: each scheme's
    (tree size x seed) slice runs as ONE fused slotted dispatch at the
    k-bucket head vs the per-k campaign pattern those schemes were pinned
    to before counter-stream randomness.  CCTs verified identical first."""
    trees = (4, 6) if SMOKE else (4, 6, 8)
    seeds = tuple(range(1 if SMOKE else 2))
    schemes = ("rsq", "switch_pkt_ar")
    load = sweep.WorkloadSpec("permutation", 8 if SMOKE else 32, rng_seed=1)

    fused_c = sweep.Campaign(name="sweep_bench_kfuse_loop", schemes=schemes,
                             loads=(load,), trees=trees, seeds=seeds,
                             engine="loop", max_slots=20000)
    p = sweep.plan(fused_c)
    assert p.n_dispatches == p.n_shapes == len(schemes), p.describe()

    _clear_compile_caches()
    t0 = time.perf_counter()
    records, _ = sweep.run_campaign(fused_c)
    fused_s = time.perf_counter() - t0

    _clear_compile_caches()
    t0 = time.perf_counter()
    per_k_records = []
    for k in trees:
        recs, _ = sweep.run_campaign(sweep.Campaign(
            name="sweep_bench_kfuse_loop", schemes=schemes, loads=(load,),
            trees=(k,), seeds=seeds, engine="loop", max_slots=20000))
        per_k_records.extend(recs)
    per_k_s = time.perf_counter() - t0

    fused_cct = {(r["scheme"], r["k"], r["seed"]): r["cct"] for r in records}
    per_k_cct = {(r["scheme"], r["k"], r["seed"]): r["cct"]
                 for r in per_k_records}
    assert fused_cct == per_k_cct, ("loop cross-k fused CCTs diverge from "
                                    "per-k")

    from repro.kernels.slot_step import ops as slot_ops
    return {
        "grid": {"trees": list(trees), "msg_packets": load.msg_packets,
                 "schemes": list(schemes), "n_seeds": len(seeds),
                 "points": fused_c.n_points},
        "plan": {"n_dispatches": p.n_dispatches, "n_shapes": p.n_shapes,
                 "k_pad": p.megabatches[0].k_pad},
        "impl": slot_ops.resolve_impl(fused_c.loop_config().impl),
        "fused_s": round(fused_s, 3),
        "per_k_s": round(per_k_s, 3),
        "speedup_vs_per_k": round(per_k_s / fused_s, 2),
    }


def _faults_sample():
    """Dynamic-fault sample: a mixed campaign (no-failure, static random
    failures, and a 3-epoch link flap schedule) fused onto the campaign axis
    -- schedules ride the ``failure`` grid dimension, so the planner still
    emits one dispatch per compiled shape -- vs the serial per-point
    ``fastsim.simulate(..., fault=...)`` loop.  CCTs verified identical
    before timing is reported."""
    from repro.faults import FaultSchedule
    seeds = tuple(range(2 if SMOKE else 4))
    schemes = ("host_pkt", "host_dr")
    load = sweep.WorkloadSpec("permutation", 8 if SMOKE else 32, rng_seed=1)
    k = 4
    tree = FatTree(k)
    flap = FaultSchedule.flap(layer="ea", pod=0, i=0, j=1, t0=4, period=12,
                              cycles=1, host_react=0, switch_react=0)
    failures = (None, sweep.FailureSpec(0.08, 42), flap)

    campaign = sweep.Campaign(name="sweep_bench_faults", schemes=schemes,
                              loads=(load,), trees=(k,), seeds=seeds,
                              failures=failures)
    p = sweep.plan(campaign)
    assert p.n_dispatches == p.n_shapes, p.describe()

    _clear_compile_caches()
    t0 = time.perf_counter()
    records, _ = sweep.run_campaign(campaign)
    fused_s = time.perf_counter() - t0

    _clear_compile_caches()
    wl = sweep.build_workload(tree, load)
    cache = {}
    t0 = time.perf_counter()
    serial = {}
    for nm in schemes:
        for f in failures:
            links = (sweep.build_links(tree, f)
                     if isinstance(f, sweep.FailureSpec) else None)
            fz = f if isinstance(f, FaultSchedule) else None
            for s in seeds:
                res = fastsim.simulate(tree, wl, lbs.by_name(nm), seed=s,
                                       links=links, fault=fz)
                serial[(nm, f.label() if f else None, s)] = res.cct
    serial_s = time.perf_counter() - t0

    fused = {(r["scheme"], r["failure"], r["seed"]): r["cct"]
             for r in records}
    assert fused == serial, "fused fault campaign CCTs diverge from serial"

    return {
        "grid": {"k": k, "msg_packets": load.msg_packets,
                 "schemes": list(schemes), "n_seeds": len(seeds),
                 "failures": [f.label() if f else None for f in failures],
                 "flap_epochs": flap.n_epochs, "points": campaign.n_points},
        "plan": {"n_dispatches": p.n_dispatches, "n_shapes": p.n_shapes},
        "fused_s": round(fused_s, 3),
        "serial_s": round(serial_s, 3),
        "speedup_vs_serial": round(serial_s / fused_s, 2),
    }


def _planner_sample():
    """Cost-modeled planner sample: the mixed-k all_to_all grid whose
    quadratic per-k packet counts the greedy-2x heuristic pads
    pathologically, run once under each planner (CCTs verified identical
    first), plus a loop-engine timing sweep whose pow2-bucketed
    (prop_slots, ack_delay) axis shares compiled shapes.  Reports the
    model's predicted padded rows against the heuristic's alongside the
    measured walls."""
    import dataclasses
    trees = (4, 6) if SMOKE else (4, 6, 8)
    seeds = (0, 1)
    schemes = ("host_pkt", "host_dr")
    load = sweep.WorkloadSpec("all_to_all", 4 if SMOKE else 8)
    heur_c = sweep.Campaign(name="sweep_bench_planner", schemes=schemes,
                            loads=(load,), trees=trees, seeds=seeds)
    cost_c = dataclasses.replace(heur_c, planner="cost")
    p_h, p_c = sweep.plan(heur_c), sweep.plan(cost_c)
    padded = lambda p: sum(m.n_points * m.npk_pad for m in p.megabatches)

    _clear_compile_caches()
    t0 = time.perf_counter()
    rec_h, _ = sweep.run_campaign(heur_c)
    heur_s = time.perf_counter() - t0

    _clear_compile_caches()
    t0 = time.perf_counter()
    rec_c, _ = sweep.run_campaign(cost_c)
    cost_s = time.perf_counter() - t0

    key = lambda r: (r["scheme"], r["k"], r["seed"])
    assert ({key(r): r["cct"] for r in rec_h}
            == {key(r): r["cct"] for r in rec_c}), (
        "cost-planned CCTs diverge from heuristic plan")

    # Timing sweep on the slotted engine: (9,33) and (12,40) share pow2
    # buckets (16, 64) -- one compiled shape -- while (3,5) gets its own.
    timings = (((9, 33), (12, 40)) if SMOKE
               else ((9, 33), (12, 40), (3, 5)))
    tc = sweep.Campaign(
        name="sweep_bench_timing", schemes=("host_pkt",),
        loads=(sweep.WorkloadSpec("permutation", 8 if SMOKE else 16,
                                  rng_seed=1),),
        trees=(4,), seeds=seeds, engine="loop", max_slots=20000,
        timings=timings)
    tp = sweep.plan(tc)
    _clear_compile_caches()
    t0 = time.perf_counter()
    trecs, _ = sweep.run_campaign(tc)
    timing_fused_s = time.perf_counter() - t0

    tree = FatTree(4)
    wl = sweep.build_workload(tree, tc.loads[0])
    t0 = time.perf_counter()
    for r in trecs:
        tm = (r["prop_slots"], r["ack_delay"])
        res = loopsim.simulate(tree, wl, lbs.by_name(r["scheme"]),
                               tc.loop_config(timing=tm), seed=r["seed"])
        assert r["cct"] == float(res.cct_slots), (
            f"timing-sweep fused CCT diverges from serial at {tm}")
    timing_serial_s = time.perf_counter() - t0

    return {
        "grid": {"trees": list(trees), "msg_packets": load.msg_packets,
                 "schemes": list(schemes), "n_seeds": len(seeds),
                 "points": heur_c.n_points},
        "policy": p_c.policy.label if p_c.policy else "greedy2x/pow2",
        "heuristic": {"n_dispatches": p_h.n_dispatches,
                      "n_shapes": p_h.n_shapes,
                      "pkt_rows_padded": padded(p_h),
                      "wall_s": round(heur_s, 3)},
        "cost": {"n_dispatches": p_c.n_dispatches,
                 "n_shapes": p_c.n_shapes,
                 "pkt_rows_padded": padded(p_c),
                 "wall_s": round(cost_s, 3)},
        "padded_rows_saved": padded(p_h) - padded(p_c),
        "speedup_vs_heuristic": round(heur_s / cost_s, 2),
        "timing_sweep": {
            "timings": [list(t) for t in timings],
            "n_dispatches": tp.n_dispatches,
            "n_shapes": tp.n_shapes,
            "fused_s": round(timing_fused_s, 3),
            "serial_warm_s": round(timing_serial_s, 3),
            "speedup_vs_warm": round(timing_serial_s / timing_fused_s, 2),
        },
    }


def _probe_sample(campaign, records):
    """Probes-on re-run of the first scheme's slice: verifies the probe
    series' per-layer max reproduces the probe-free ``max_queue`` scalars,
    and reports the probed wall time (the marginal cost of carrying the
    series through the fused dispatch)."""
    import dataclasses
    probed_c = dataclasses.replace(
        campaign, schemes=campaign.schemes[:1],
        probes=ProbeSpec(stride=8, samples=128))
    _clear_compile_caches()
    t0 = time.perf_counter()
    probed, _ = sweep.run_campaign(probed_c)
    probed_s = time.perf_counter() - t0

    base = {(r["scheme"], r["workload"], r["seed"]): r for r in records}
    for r in probed:
        series = np.asarray(r["probe_queue"])
        ref = base[(r["scheme"], r["workload"], r["seed"])]
        assert float(series.max()) == ref["max_queue"], (
            f"probe series max {series.max()} != max_queue "
            f"{ref['max_queue']} for {r['scheme']}/s{r['seed']}")
    return {
        "stride": 8, "samples": 128, "points": probed_c.n_points,
        "probed_s": round(probed_s, 3),
        "series_shape": list(np.asarray(probed[0]["probe_queue"]).shape),
    }


def _telemetry_section(trace, batch_s, campaign, records):
    disp = [s for s in trace.spans if s.get("kind") == "dispatch"]
    real = sum(s["pkt_rows_real"] for s in disp)
    padded = sum(s["pkt_rows_padded"] for s in disp)
    return {
        "n_spans": len(trace.spans),
        "trace_emit_s": round(trace.emit_s, 5),
        "trace_overhead_frac": round(trace.emit_s / batch_s, 5),
        "pkt_rows_real": real,
        "pkt_rows_padded": padded,
        "pkt_fill": round(real / max(padded, 1), 4),
        "probe": _probe_sample(campaign, records),
    }


def _merge_bench_json(result):
    """schema-2 persistence: merge this run's sections into BENCH_sweep.json
    instead of clobbering the file, so sections owned by other producers
    (and any keys a future schema adds) survive."""
    existing = {}
    if BENCH_JSON.exists():
        try:
            existing = json.loads(BENCH_JSON.read_text())
        except (json.JSONDecodeError, OSError):
            existing = {}
    if not isinstance(existing, dict):
        existing = {}
    existing.update(result)
    existing["schema"] = 2
    BENCH_JSON.write_text(json.dumps(existing, indent=2) + "\n")


def sweep_speedup(scale: C.Scale):
    """Grid-completion wall time: megabatched campaign vs per-scheme batched
    (PR1) vs serial loops."""
    import jax
    k = 4 if SMOKE else scale.k
    n_seeds = 4 if SMOKE else N_SEEDS
    seeds = tuple(range(n_seeds))
    tree = FatTree(k)
    loads = tuple(sweep.WorkloadSpec("permutation", m, rng_seed=1)
                  for m in MSGS)
    wls = {ld: sweep.build_workload(tree, ld) for ld in loads}

    campaign = sweep.Campaign(
        name="sweep_bench", schemes=SCHEMES, loads=loads,
        trees=(k,), seeds=seeds, prop_slots=C.PROP_SLOTS)
    p = sweep.plan(campaign)
    n_points = campaign.n_points

    # ---- megabatched campaign (cold caches, includes its own compiles AND
    # a live dispatch trace, so batch_s prices telemetry honestly) ----------
    _clear_compile_caches()
    trace = TraceWriter()
    t0 = time.perf_counter()
    records, _ = sweep.run_campaign(campaign, trace=trace)
    batch_s = time.perf_counter() - t0

    # ---- PR1 pattern: one seed-vmapped dispatch per (scheme, load) --------
    _clear_compile_caches()
    t0 = time.perf_counter()
    pr1 = {}
    for name in SCHEMES:
        for ld in loads:
            for s, res in zip(seeds, fastsim.simulate_batch(
                    tree, wls[ld], lbs.by_name(name), seeds,
                    prop_slots=C.PROP_SLOTS)):
                pr1[(name, ld.label(), s)] = res.cct
    pr1_s = time.perf_counter() - t0

    # ---- serial-warm loop (cold caches, compiles amortized by lru-cache) --
    _clear_compile_caches()
    t0 = time.perf_counter()
    serial = {(name, ld.label(), s):
              fastsim.simulate(tree, wls[ld], lbs.by_name(name), seed=s,
                               prop_slots=C.PROP_SLOTS).cct
              for name in SCHEMES for ld in loads for s in seeds}
    serial_warm_s = time.perf_counter() - t0

    batched = {(r["scheme"], r["workload"], r["seed"]): r["cct"]
               for r in records}
    mismatches = [key for key in serial
                  if serial[key] != batched[key] or serial[key] != pr1[key]]
    assert not mismatches, f"batched CCTs diverge from serial: {mismatches}"

    # ---- serial-isolated pattern: one cold point per compiled shape -------
    serial_isolated_s = 0.0
    cold_shapes = []
    for mega in p.megabatches:
        rep = mega.members[0]               # representative point of the shape
        _clear_compile_caches()
        t0 = time.perf_counter()
        fastsim.simulate(tree, wls[rep.load], lbs.by_name(rep.scheme),
                         seed=rep.seeds[0], prop_slots=C.PROP_SLOTS)
        cold = time.perf_counter() - t0
        cold_shapes.append({"scheme": rep.scheme, "cold_s": round(cold, 3),
                            "points": mega.n_points})
        serial_isolated_s += cold * mega.n_points

    speedup = serial_isolated_s / batch_s
    speedup_warm = serial_warm_s / batch_s
    speedup_pr1 = pr1_s / batch_s
    result = {
        "grid": {"k": k, "msg_packets": list(MSGS), "schemes": list(SCHEMES),
                 "n_seeds": n_seeds, "points": n_points, "smoke": SMOKE},
        "plan": {"n_dispatches": p.n_dispatches, "n_shapes": p.n_shapes},
        "devices": len(jax.devices()),
        "megabatch_s": round(batch_s, 3),
        "pr1_per_scheme_s": round(pr1_s, 3),
        "serial_warm_s": round(serial_warm_s, 3),
        "serial_isolated_s": round(serial_isolated_s, 3),
        "isolated_cold_samples": cold_shapes,
        "speedup_vs_isolated": round(speedup, 2),
        "speedup_vs_warm": round(speedup_warm, 2),
        "speedup_vs_pr1": round(speedup_pr1, 2),
        "telemetry": _telemetry_section(trace, batch_s, campaign, records),
        "loop": _loop_sample(k, tree),
        "kfuse": _kfuse_sample(),
        "kfuse_loop": _kfuse_loop_sample(),
        "faults": _faults_sample(),
        "planner": _planner_sample(),
    }
    _merge_bench_json(result)
    C.emit("sweep_speedup", batch_s * 1e6 / n_points,
           batch_s=result["megabatch_s"], pr1_s=result["pr1_per_scheme_s"],
           serial_warm_s=result["serial_warm_s"],
           serial_isolated_s=result["serial_isolated_s"],
           isolated_measured=len(cold_shapes),
           speedup=result["speedup_vs_isolated"],
           speedup_warm=result["speedup_vs_warm"],
           speedup_pr1=result["speedup_vs_pr1"],
           loop_speedup=result["loop"]["speedup_vs_isolated"],
           loop_speedup_warm=result["loop"]["speedup_vs_warm"],
           loop_dispatches=result["loop"]["plan"]["n_dispatches"],
           kfuse_speedup=result["kfuse"]["speedup_vs_per_k"],
           kfuse_dispatches=result["kfuse"]["plan"]["n_dispatches"],
           kfuse_loop_speedup=result["kfuse_loop"]["speedup_vs_per_k"],
           kfuse_loop_dispatches=result["kfuse_loop"]["plan"]["n_dispatches"],
           faults_speedup=result["faults"]["speedup_vs_serial"],
           faults_dispatches=result["faults"]["plan"]["n_dispatches"],
           planner_policy=result["planner"]["policy"],
           planner_rows_saved=result["planner"]["padded_rows_saved"],
           planner_speedup=result["planner"]["speedup_vs_heuristic"],
           timing_dispatches=result["planner"]["timing_sweep"]
                                   ["n_dispatches"],
           trace_overhead_frac=result["telemetry"]["trace_overhead_frac"],
           probe_s=result["telemetry"]["probe"]["probed_s"],
           points=n_points, dispatches=p.n_dispatches, shapes=p.n_shapes)
    return result
