"""Campaign-engine speedup benchmark: seed-vmapped grid vs serial loops.

Runs one grid -- 4 LB schemes x 8 replicate seeds on a k=8 permutation
workload (32 points, but only TWO compiled pipeline shapes: flow_ecmp,
host_pkt and host_dr all lower to the 'pre/pre' pipeline) -- three ways:

  * **batched**: ``sweep.run_campaign``; the planner groups the grid into
    one seed-vmapped dispatch per scheme and orders batches so schemes
    sharing a pipeline shape reuse one jit compile;
  * **serial-warm**: one ``fastsim.simulate`` call per (scheme, seed) cell
    in a single process, so ``_build_run``'s lru-cache amortizes compiles
    across the loop -- the old in-process ``benchmarks/paper_figs.py``
    pattern;
  * **serial-isolated**: the per-point-job pattern the campaign subsystem
    replaces (one cluster job / fresh process per grid point, recompiling
    and re-dispatching every time).  Measured honestly by clearing the
    compile caches before each sampled point and extrapolating the
    per-point cold cost to the full grid; ``isolated_measured`` records how
    many points were actually run cold.

Per-point results are verified identical (exact CCT equality) between the
batched and serial paths before any timing is reported.

On accelerator backends the vmapped dispatch additionally fills the device
with the seed batch; on this repo's small CPU CI box the per-point device
time is sort-bound and nearly identical serial vs batched, so
``speedup_warm`` hovers near 1 while ``speedup`` (vs the isolated-job
pattern, the regime the campaign engine exists to kill) is the headline.
"""
from __future__ import annotations

import time

import numpy as np

from repro.net.topology import FatTree
from repro.net import workloads, fastsim
from repro.core import lb_schemes as lbs
from repro import sweep

from . import common as C

SCHEMES = ("host_pkt", "flow_ecmp", "host_dr", "switch_pkt")
N_SEEDS = 8
MSG = 64
N_COLD_SAMPLES = 2   # isolated-pattern points actually run (one per shape)


def _clear_compile_caches():
    fastsim._build_run.cache_clear()


def sweep_speedup(scale: C.Scale):
    """Grid-completion wall time: batched campaign vs serial loops."""
    k = scale.k
    seeds = tuple(range(N_SEEDS))
    tree = FatTree(k)
    wl = workloads.permutation(tree, MSG, np.random.default_rng(1))

    campaign = sweep.Campaign(
        name="sweep_bench", schemes=SCHEMES,
        loads=(sweep.WorkloadSpec("permutation", MSG, rng_seed=1),),
        trees=(k,), seeds=seeds, prop_slots=C.PROP_SLOTS)
    n_points = campaign.n_points

    # ---- batched campaign (cold caches, includes its own compiles) --------
    _clear_compile_caches()
    t0 = time.perf_counter()
    records, _ = sweep.run_campaign(campaign)
    batch_s = time.perf_counter() - t0

    # ---- serial-warm loop (cold caches, compiles amortized by lru-cache) --
    _clear_compile_caches()
    t0 = time.perf_counter()
    serial = {(name, s): fastsim.simulate(tree, wl, lbs.by_name(name),
                                          seed=s, prop_slots=C.PROP_SLOTS).cct
              for name in SCHEMES for s in seeds}
    serial_warm_s = time.perf_counter() - t0

    batched = {(r["scheme"], r["seed"]): r["cct"] for r in records}
    mismatches = [key for key in serial if serial[key] != batched[key]]
    assert not mismatches, f"batched CCTs diverge from serial: {mismatches}"

    # ---- serial-isolated pattern (cold compile per point, sampled) --------
    cold = []
    for name in ("host_pkt", "switch_pkt")[:N_COLD_SAMPLES]:
        _clear_compile_caches()
        t0 = time.perf_counter()
        fastsim.simulate(tree, wl, lbs.by_name(name), seed=0,
                         prop_slots=C.PROP_SLOTS)
        cold.append(time.perf_counter() - t0)
    serial_isolated_s = float(np.mean(cold)) * n_points

    speedup = serial_isolated_s / batch_s
    speedup_warm = serial_warm_s / batch_s
    C.emit("sweep_speedup", batch_s * 1e6 / n_points,
           batch_s=round(batch_s, 2),
           serial_warm_s=round(serial_warm_s, 2),
           serial_isolated_s=round(serial_isolated_s, 2),
           isolated_measured=N_COLD_SAMPLES,
           speedup=round(speedup, 2), speedup_warm=round(speedup_warm, 2),
           points=n_points, dispatches=len(SCHEMES), shapes=2)
    return {"batch_s": batch_s, "serial_warm_s": serial_warm_s,
            "serial_isolated_s": serial_isolated_s, "speedup": speedup,
            "speedup_warm": speedup_warm}
