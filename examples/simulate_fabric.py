"""Fabric what-if exploration: failures, convergence time, and transport.

Shows the full §5.2 failure study on one small topology: how host-adaptive
(REPS-style), switch-adaptive (quantized JSQ) and OFAN behave as routing
convergence time G varies -- the paper's headline operational question
("should operators rely on host-based LB or demand fast convergence from
switch vendors?").

    PYTHONPATH=src python examples/simulate_fabric.py
"""
import numpy as np

from repro.net.topology import FatTree, LinkState, rho_max
from repro.net import workloads, loopsim
from repro.core import lb_schemes as lbs


def main():
    tree = FatTree(4)
    rng = np.random.default_rng(42)
    links = LinkState.random_failures(tree, 0.08, rng)
    n_dead = int((~links.ea).sum() + (~links.ac).sum())
    print(f"fat-tree k=4 ({tree.n_hosts} hosts); {n_dead} failed links")

    wl = workloads.permutation(tree, 64, np.random.default_rng(1),
                               inter_pod_only=True)
    rho = rho_max(tree, links, wl.flow_src, wl.flow_dst)
    print(f"rho_max under failures: {rho:.3f} (Appendix A)\n")

    rtt = 87
    print(f"{'G':>10s} {'host AR (REPS)':>16s} {'switch AR':>12s} "
          f"{'OFAN':>8s}   (CCT slots; lower is better)")
    for g_label, g in [("0", 0), ("1 RTT", rtt), ("16 RTT", 16 * rtt),
                       ("infinite", None)]:
        row = []
        for name in ("host_pkt_ar", "switch_pkt_ar", "ofan"):
            cfg = loopsim.LoopConfig(max_slots=20000, rho=float(rho),
                                     rto_slots=250)
            res = loopsim.simulate(tree, wl, lbs.by_name(name), cfg, seed=0,
                                   links=links, g_converge=g)
            row.append(res.cct_slots)
        print(f"{g_label:>10s} {row[0]:16.0f} {row[1]:12.0f} {row[2]:8.0f}")

    print("\npaper takeaway: host AR tracks failures end-to-end and wins at "
          "large G; all converge once routing state is updated (G=0).")


if __name__ == "__main__":
    main()
