"""Fabric what-if exploration: failures, convergence time, and transport.

Shows the full §5.2 failure study on one small topology: how host-adaptive
(REPS-style), switch-adaptive (quantized JSQ) and OFAN behave as routing
convergence time G varies -- the paper's headline operational question
("should operators rely on host-based LB or demand fast convergence from
switch vendors?").

The study is ONE campaign spec (``repro.sweep``): the ``failures`` preset
fixes the topology, traffic, failure pattern and transport, and the G sweep
is the campaign's ``g_converge`` grid axis -- the whole what-if table comes
back from a single ``run_campaign`` call.  Adaptive host schemes need ACK
feedback, so this campaign runs on the slotted loop engine
(``engine='loop'``) -- and, like fast-engine campaigns, it executes as
fused megabatch dispatches: every G value of a scheme rides one batched
``lax.while_loop`` (G is a per-row operand of the compiled slotted engine),
so the whole 4-G-by-scheme table costs one dispatch per scheme shape.

    PYTHONPATH=src python examples/simulate_fabric.py
"""
import dataclasses

from repro.net.topology import FatTree, rho_max
from repro import sweep


def main():
    base = sweep.preset("failures")          # k=4, p_fail=0.08, loop engine
    k = base.trees[0]
    tree = FatTree(k)
    links = sweep.build_links(tree, base.failures[0])
    n_dead = int((~links.ea).sum() + (~links.ac).sum())
    print(f"fat-tree k={k} ({tree.n_hosts} hosts); {n_dead} failed links")

    wl = sweep.build_workload(tree, base.loads[0])
    rho = rho_max(tree, links, wl.flow_src, wl.flow_dst)
    print(f"rho_max under failures: {rho:.3f} (Appendix A)\n")

    rtt = 87
    g_labels = [("0", 0), ("1 RTT", rtt), ("16 RTT", 16 * rtt),
                ("infinite", None)]
    campaign = dataclasses.replace(
        base, name="failures_gsweep",
        g_converge=tuple(g for _, g in g_labels))
    records, _ = sweep.run_campaign(campaign)
    cct = {(r["g_converge"], r["scheme"]): r["cct"] for r in records}

    print(f"{'G':>10s} {'host AR (REPS)':>16s} {'switch AR':>12s} "
          f"{'OFAN':>8s}   (CCT slots; lower is better)")
    for g_label, g in g_labels:
        print(f"{g_label:>10s} {cct[(g, 'host_pkt_ar')]:16.0f} "
              f"{cct[(g, 'switch_pkt_ar')]:12.0f} {cct[(g, 'ofan')]:8.0f}")

    print("\npaper takeaway: host AR tracks failures end-to-end and wins at "
          "large G; all converge once routing state is updated (G=0).")


if __name__ == "__main__":
    main()
