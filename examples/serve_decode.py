"""Serving example: continuous batching over a small model.

Submits a stream of variable-length requests into a fixed pool of decode
slots; the batcher prefills into free slots and advances all active slots
per tick -- the production serving pattern (vLLM/MaxText-style) on top of
the zoo's prefill/decode API.

    PYTHONPATH=src python examples/serve_decode.py
"""
import time

import numpy as np
import jax

from repro.configs.base import get_config
from repro.models.registry import Model
from repro.serve import batching, serve_step


def main():
    model = Model(get_config("qwen1.5-4b", smoke=True))
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    cb = batching.ContinuousBatcher(model, params, n_slots=4, max_len=64)
    t0 = time.time()
    n_req = 8
    for rid in range(n_req):
        prompt = rng.integers(0, model.cfg.vocab,
                              (int(rng.integers(4, 12)),)).astype(np.int32)
        cb.submit(batching.Request(rid=rid, prompt=prompt,
                                   max_new_tokens=int(rng.integers(3, 8))))
    done = cb.run_to_completion()
    dt = time.time() - t0
    total_new = sum(len(r.out) for r in done.values())
    print(f"{len(done)}/{n_req} requests served, {total_new} tokens in "
          f"{dt:.1f}s ({total_new/dt:.1f} tok/s on 1 CPU core)")
    for rid in sorted(done):
        r = done[rid]
        print(f"  req {rid}: prompt[{len(r.prompt)}] -> {r.out}")
    assert len(done) == n_req


if __name__ == "__main__":
    main()
