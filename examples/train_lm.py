"""End-to-end training driver: train a ~100M-parameter dense LM for a few
hundred steps on the synthetic ngram stream and watch the loss fall.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

Uses the full production stack (sharded train step, AdamW, counter-based
data, async checkpoints via the resilient loop) on a 1-device mesh.  The
model is a bespoke ~100M config of the phi-4 family (not the reduced smoke
config).
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.models.registry import Model
from repro.models import sharding as sh
from repro.train import train_step as ts
from repro.train import data as data_mod
from repro.train import fault_tolerance as ft_mod


def config_100m():
    base = get_config("phi4-mini-3.8b")
    return dataclasses.replace(
        base, name="phi4-100m", n_layers=8, d_model=512, n_heads=8,
        n_kv_heads=4, head_dim=64, d_ff=1536, vocab=8192,
        dtype="float32", microbatch=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args(argv)

    cfg = config_100m()
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"{cfg.name}: {n_params/1e6:.1f}M params")

    tcfg = ts.TrainConfig(learning_rate=1e-3, warmup_steps=50)
    state = ts.make_train_state(model, params, tcfg)
    step = jax.jit(ts.build_train_step(model, tcfg), donate_argnums=(0,))

    dcfg = data_mod.DataConfig(vocab=cfg.vocab, seq_len=args.seq_len,
                               global_batch=args.global_batch)
    batches = lambda s: {"tokens": jnp.asarray(
        data_mod.batch_for_step(dcfg, s))}

    losses = []

    def cb(s, m, dt):
        losses.append(float(m["loss"]))
        if s % 20 == 0:
            print(f"step {s:4d}  loss {losses[-1]:.4f}  ({dt*1e3:.0f} ms)",
                  flush=True)

    loop = ft_mod.ResilientLoop(
        step, state, ft_mod.FTConfig(ckpt_dir=args.ckpt_dir, ckpt_every=100),
        health_cb=lambda m: print(f"[ft] {m}"))
    loop.run(batches, args.steps, cb)
    print(f"\nloss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({'improved' if losses[-1] < losses[0] else 'NO IMPROVEMENT'})")
    assert losses[-1] < losses[0], "training failed to reduce loss"


if __name__ == "__main__":
    main()
