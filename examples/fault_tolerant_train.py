"""Fault-tolerance demo: kill training mid-run, restart, resume bit-exactly.

    PYTHONPATH=src python examples/fault_tolerant_train.py

Phase 1 trains 60 steps (checkpoint every 20), then 'crashes'.
Phase 2 constructs a fresh loop pointing at the same checkpoint dir: it
restores step 60 and continues to 100.  A control run that does 100 steps
straight must produce bit-identical parameters -- the counter-based data
pipeline plus atomic checkpoints make restarts exact.
"""
import shutil

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.models.registry import Model
from repro.train import train_step as ts
from repro.train import data as data_mod
from repro.train import fault_tolerance as ft_mod

CKPT = "/tmp/repro_ft_demo"


def build():
    model = Model(get_config("mamba2-130m", smoke=True))
    params = model.init_params(jax.random.PRNGKey(0))
    tcfg = ts.TrainConfig(learning_rate=1e-3)
    state = ts.make_train_state(model, params, tcfg)
    step = jax.jit(ts.build_train_step(model, tcfg))
    dcfg = data_mod.DataConfig(vocab=model.cfg.vocab, seq_len=32,
                               global_batch=4)
    batches = lambda s: {"tokens": jnp.asarray(
        data_mod.batch_for_step(dcfg, s))}
    return step, state, batches


def main():
    shutil.rmtree(CKPT, ignore_errors=True)
    step, state0, batches = build()
    ftc = ft_mod.FTConfig(ckpt_dir=CKPT, ckpt_every=20)

    print("phase 1: train to step 60, then 'crash'")
    loop = ft_mod.ResilientLoop(step, state0, ftc,
                                health_cb=lambda m: print(f"  [ft] {m}"))
    loop.run(batches, 60)

    print("phase 2: restart from checkpoints, continue to 100")
    loop2 = ft_mod.ResilientLoop(step, state0, ftc,
                                 health_cb=lambda m: print(f"  [ft] {m}"))
    assert loop2.start_step == 60, loop2.start_step
    final_restarted = loop2.run(batches, 100)

    print("control: 100 steps straight through")
    shutil.rmtree(CKPT, ignore_errors=True)
    step, state0, batches = build()
    loop3 = ft_mod.ResilientLoop(step, state0,
                                 ft_mod.FTConfig(ckpt_dir=CKPT,
                                                 ckpt_every=1000))
    final_straight = loop3.run(batches, 100)

    diffs = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(
            a.astype(jnp.float32) - b.astype(jnp.float32)))),
        final_restarted["params"], final_straight["params"])
    worst = max(jax.tree_util.tree_leaves(diffs))
    print(f"max param divergence restart vs straight: {worst:.2e}")
    assert worst == 0.0, "restart was not bit-exact!"
    print("restart is bit-exact ✓")


if __name__ == "__main__":
    main()
