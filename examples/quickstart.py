"""Quickstart: the paper in five minutes on a laptop.

Reproduces the core result -- destination-based rotation (OFAN) achieves
O(1) queues and the best collective completion times, while spraying grows
as sqrt(m) and round-robin/ECMP grow linearly -- on a small fat tree, then
shows the trainer-side integration: an expert-parallel AllToAll scheduled as
DR rotation rounds.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.net.topology import FatTree
from repro.net import workloads, fastsim
from repro.core import lb_schemes as lbs
from repro.core import theory


def main():
    tree = FatTree(8)              # 128 hosts, the paper's default scale
    print(f"fat-tree k=8: {tree.n_hosts} hosts, "
          f"{tree.n_cores} cores, {tree.n_queues} queues\n")

    print("== queue scaling q(m) (paper Fig. 6 / Table 3) ==")
    print(f"{'scheme':16s}" + "".join(f" m={m:<6d}" for m in (64, 256, 1024))
          + " law")
    laws = {"flow_ecmp": "Theta(m)", "simple_rr": "Theta(m)",
            "jsq": "Theta(m)", "host_pkt": "sqrt(m)",
            "host_dr": "Theta(1)", "ofan": "Theta(1)"}
    for name, law in laws.items():
        row = []
        for m in (64, 256, 1024):
            wl = workloads.permutation(tree, m, np.random.default_rng(1),
                                       inter_pod_only=True)
            res = fastsim.simulate(tree, wl, lbs.by_name(name), seed=2)
            row.append(res.max_queue)
        print(f"{name:16s}" + "".join(f" {q:8.1f}" for q in row) + f" {law}")

    print("\n== collective completion time, m=256 (Fig. 1) ==")
    m = 256
    wl = workloads.permutation(tree, m, np.random.default_rng(1))
    # data-delivery bound: last packet out at m-1 slots + 6 hops of
    # serialization and propagation (the engines measure data CCT)
    net = theory.DEFAULT_NET
    t_d = net.frame_B * 8 / net.link_rate_bps / net.slot_s
    bound = (m - 1) + 6 * t_d + 6 * net.prop_slots
    for name in ("flow_ecmp", "subflow_mptcp", "host_pkt", "switch_pkt",
                 "switch_pkt_ar", "host_dr", "ofan"):
        res = fastsim.simulate(tree, wl, lbs.by_name(name), seed=0)
        print(f"{name:16s} CCT +{100 * (res.cct / bound - 1):6.1f}% over "
              f"lower bound")

    print("\n== the discipline in the trainer: MoE AllToAll schedules ==")
    from repro.collectives import planner
    for mb in (4 << 10, 64 << 20):
        plan = planner.plan_all_to_all(mb, 16, intra_pod=False)
        print(f"cross-pod a2a {mb >> 10:8d} KiB/pair -> {plan.impl:9s} "
              f"({plan.reason})")


if __name__ == "__main__":
    main()
